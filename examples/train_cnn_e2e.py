"""End-to-end driver (paper §3.2 kind): train the LeNet5-like CNN for a
few hundred steps with 4 workers × periodic averaging, exactly the
paper's recipe (momentum SGD lr .01 mu .9, x0.95/epoch decay, batch 8,
phase length 10, per-worker data permutations), with checkpointing and
train/test evaluation of the consensus model.

Run:  PYTHONPATH=src python examples/train_cnn_e2e.py [--steps 300]
"""
import argparse
import os

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs.paper import CNNConfig
from repro.core import AveragingSchedule, PhaseEngine
from repro.data import mnist_like
from repro.data.pipeline import WorkerSharder
from repro.models.cnn import cnn_error, cnn_loss, init_cnn
from repro.optim import Momentum, schedules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_cnn_ckpt")
    args = ap.parse_args()

    cfg = CNNConfig()
    images, labels = mnist_like(8192, seed=0)
    test_images, test_labels = mnist_like(1024, seed=1)
    M = cfg.num_workers
    sharder = WorkerSharder(len(images), M, seed=0, mode="permute")
    steps_per_epoch = len(images) // (M * cfg.batch_size)

    params = init_cnn(cfg, jax.random.PRNGKey(0))
    opt = Momentum(lr=schedules.exponential_epoch(
        cfg.lr, cfg.lr_decay_per_epoch, steps_per_epoch), mu=cfg.momentum)

    def loss_fn(p, batch, rng):
        return cnn_loss(cfg, p, batch), {}

    engine = PhaseEngine(loss_fn, opt,
                         AveragingSchedule("periodic", cfg.phase_len))

    def batches():
        for _ in range(args.steps):
            idx = sharder.next_indices(cfg.batch_size)
            yield {"images": jnp.asarray(images[idx]),
                   "labels": jnp.asarray(labels[idx])}

    test_err = jax.jit(lambda p: cnn_error(
        cfg, p, {"images": jnp.asarray(test_images),
                 "labels": jnp.asarray(test_labels)}))

    final, hist = engine.run(params, batches(), num_workers=M, seed=0,
                             record_every=25,
                             eval_fn=lambda p: float(test_err(p)))
    print(f"trained {args.steps} steps, {hist['averages']} averages")
    for (s, l), (_, e) in zip(hist["loss"], hist["eval"]):
        print(f"  step {s:4d}: train loss {l:.4f}  test err {e:.3f}")
    save_checkpoint(args.ckpt, final, step=args.steps)
    restored, step = load_checkpoint(args.ckpt, jax.tree.map(jnp.zeros_like,
                                                             final))
    assert step == args.steps
    print(f"checkpoint round-trip OK -> {args.ckpt}.npz "
          f"(final test err {float(test_err(restored)):.3f})")


if __name__ == "__main__":
    main()

"""Paper §3.1 reproduction (Figure 2 + Table 1 workflow) on the synthetic
convex suite: measures (σ², β², ρ) with the paper's procedure, then runs
the paper's grid-searched schedule comparison — the averaging-frequency
advantage correlates with ρ.

Run:  PYTHONPATH=src:. python examples/convex_averaging.py
"""
import jax
import jax.numpy as jnp

from benchmarks.bench_fig2_convex import grid_curves
from repro.core.variance_model import empirical_variance_fn, measure_beta2, rho
from repro.data import convex_dataset
from repro.models.convex import solve_optimum


def main():
    for name, sparsity, noise in [("sparse-highrho", 0.02, 0.005),
                                  ("dense-lowrho", 1.0, 2.0)]:
        X, y, _ = convex_dataset("ls", 1024, 128, sparsity=sparsity,
                                 noise=noise, seed=0)
        X, y = jnp.asarray(X), jnp.asarray(y)
        w_star = solve_optimum("ls", X, y)
        vfn = empirical_variance_fn("ls", X, y)
        b2, s2 = measure_beta2(vfn, w_star, key=jax.random.PRNGKey(0),
                               num_lines=4)
        r = rho(b2, s2, jnp.zeros(128), w_star)
        curves = grid_curves("ls", X, y, steps=2000,
                             phase_lens=(0, 128), lr_mults=(0.8, 3.0, 6.0))
        one = curves["oneshot"][-1][1]
        per = curves["periodic_128"][-1][1]
        print(f"{name:16s} sigma2={s2:9.3e} beta2={b2:9.3e} rho={r:9.3e} | "
              f"normalized subopt: oneshot={one:9.3e} periodic128={per:9.3e} "
              f"ratio={one / max(per, 1e-15):7.2f}x")
    print("large rho -> large periodic-averaging advantage (paper's claim).")


if __name__ == "__main__":
    main()

"""Paper §2.4 / Figure 1: one-shot averaging fails for non-convex
problems (PCA via Oja's rule and the quartic example); periodic averaging
fixes it.

Run:  PYTHONPATH=src:. python examples/nonconvex_pca.py
"""
import numpy as np

from benchmarks.bench_fig1_pca import pca_error_vs_avg_steps
from benchmarks.bench_quartic import run_quartic
from repro.configs.paper import PCAConfig, QuarticConfig


def main():
    print("== quartic f(w)=(w^2-1)^2  (paper: oneshot .922 / 0.1% .274 / "
          "10% .011)")
    for r in run_quartic(QuarticConfig(), [0.0, 0.001, 0.01, 0.1]):
        label = "one-shot" if r["avg_frac"] == 0 else f"{r['avg_frac']:.1%}"
        print(f"  averaging {label:>8s}: objective {r['objective']:.3f}")

    print("== PCA via Oja's rule (paper Fig 1)")
    cfg = PCAConfig(num_workers=24, num_samples=3000, alpha=0.02)
    for r in pca_error_vs_avg_steps(cfg, [0, 1000, 250, 50, 10]):
        print(f"  {r['num_avg_steps']:5d} averaging steps: "
              f"PC error {r['pc_error']:.4f}")
    print("more averaging -> lower PC error; one-shot is the worst point, "
          "matching the paper.")


if __name__ == "__main__":
    main()

"""Serving example: batched greedy generation with KV/state caches across
three architecture families (dense GQA, RG-LRU hybrid, RWKV SSM) — the
same decode path the decode_32k / long_500k dry-run shapes lower.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main as serve_main


def main():
    for arch in ["smollm-360m", "recurrentgemma-2b", "rwkv6-7b"]:
        serve_main(["--arch", arch, "--reduced", "--batch", "2",
                    "--prompt-len", "4", "--gen", "8"])


if __name__ == "__main__":
    main()

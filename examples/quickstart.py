"""Quickstart: periodic model averaging (the paper's technique) on a small
transformer LM, via the public API — compares one-shot / periodic /
minibatch schedules on identical data, each run as compiled averaging
phases (one dispatch per phase) by the PhaseEngine.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import AveragingSchedule, PhaseEngine
from repro.data import token_stream
from repro.models import init_params, lm_loss
from repro.optim import Momentum

WORKERS, STEPS, BATCH, SEQ = 4, 60, 4, 64


def batch_iter(cfg, seed):
    streams = [token_stream(cfg.vocab_size, BATCH, SEQ, seed=seed * 31 + i)
               for i in range(WORKERS)]
    for _ in range(STEPS):
        yield {"tokens": jnp.asarray(np.stack([next(s) for s in streams]))}


def main():
    cfg = dataclasses.replace(get_config("smollm-360m", reduced=True),
                              dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))

    def loss_fn(p, batch, rng):
        return lm_loss(cfg, p, batch)

    print(f"model: {cfg.name} ({cfg.num_params()/1e6:.1f}M params), "
          f"{WORKERS} workers, {STEPS} steps")
    results = {}
    for name, sch in {
        "oneshot": AveragingSchedule("oneshot"),
        "periodic_10": AveragingSchedule("periodic", 10),
        "minibatch": AveragingSchedule("minibatch"),
    }.items():
        engine = PhaseEngine(loss_fn, Momentum(lr=0.05, mu=0.9), sch)
        final, hist = engine.run(params, batch_iter(cfg, 7),
                                 num_workers=WORKERS, seed=0,
                                 record_every=10)
        # evaluate the consensus model on a held-out batch
        ev = next(batch_iter(cfg, 99))
        loss, _ = lm_loss(cfg, final, {"tokens": ev["tokens"][0]})
        results[name] = float(loss)
        print(f"  {name:12s}: {hist['averages']:3d} averages, "
              f"final consensus eval loss {float(loss):.4f}")
    assert results["periodic_10"] <= results["oneshot"] + 0.5
    print("done — periodic averaging tracks/beats one-shot, as the paper "
          "predicts for non-convex objectives.")


if __name__ == "__main__":
    main()

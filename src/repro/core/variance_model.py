"""Gradient-variance envelope estimation (paper §2.2 + §3.1).

The paper's model:  Δ(w) ≤ β² ||w - w*||² + σ²   (Eq. 5)
with ρ = β² ||w0 - w*||² / σ² predicting the benefit of frequent
averaging. The measurement procedure follows §3.1 exactly:

  1. find (approximately) the optimizer w*;
  2. Δ(w*) gives σ²;
  3. draw a random line through w*;
  4. measure Δ at points along the line;
  5. fit the quadratic curvature -> one β² estimate;
  6. repeat 3-5 and average.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def measure_sigma2(variance_fn, w_star):
    """variance_fn(w) -> Δ(w) (Definition 1). σ² = Δ(w*)."""
    return float(variance_fn(w_star))


def measure_beta2(variance_fn, w_star, *, key, num_lines: int = 8,
                  num_points: int = 9, radius: float = 1.0):
    """Average curvature of Δ along random lines through w*.

    Fits Δ(w* + t d) - σ² ≈ β² t² by least squares on t² (the paper takes
    9 measurements per line)."""
    sigma2 = measure_sigma2(variance_fn, w_star)
    dim = w_star.shape[0]
    betas = []
    for i in range(num_lines):
        key, sub = jax.random.split(key)
        d = jax.random.normal(sub, (dim,))
        d = d / jnp.linalg.norm(d)
        ts = np.linspace(-radius, radius, num_points)
        ts = ts[np.abs(ts) > 1e-12]
        deltas = np.array([float(variance_fn(w_star + t * d)) for t in ts])
        t2 = ts ** 2
        beta2 = float(np.sum(t2 * (deltas - sigma2)) / np.sum(t2 * t2))
        betas.append(max(beta2, 0.0))
    return float(np.mean(np.array(betas))), sigma2


def rho(beta2: float, sigma2: float, w0, w_star) -> float:
    """ρ = β² ||w0 - w*||² / σ² — large ρ ⇒ frequent averaging helps."""
    d2 = float(jnp.sum((w0 - w_star) ** 2))
    return beta2 * d2 / max(sigma2, 1e-30)


def predict_averaging_benefit(sigma2_workers, *, beta2: float = 0.0,
                              dist2: float = 0.0, alive=None,
                              lr: float | None = None,
                              steps: int | None = None,
                              momentum: float = 0.0,
                              drift2: float = 0.0,
                              curvature: float = 0.0) -> dict:
    """Predict what one averaging event buys from measured PER-WORKER
    gradient variances (paper §2.2, Lemma 1 asymptotics).

    Averaging n i.i.d.-noise workers divides the noise floor by n, so
    with ``sigma2_bar`` the mean alive-worker variance the predicted
    per-step variance drops ``sigma2_bar * (1 - 1/n)``. Heterogeneous
    (non-IID) shards raise the measured σ² — the model predicts a LARGER
    absolute benefit — while dead workers shrink n and with it the
    reduction factor. ``rho = β² d² / σ̄²`` (Eq. 5) large means the
    bias term dominates and frequent averaging helps beyond the noise
    floor.

    Returns a dict with ``n_alive``, ``sigma2_bar``, ``rho``,
    ``variance_reduction`` (the 1/n factor) and ``benefit`` (the
    absolute predicted variance drop). With ``lr`` and ``steps`` both
    given, the calibrated :func:`predict_post_resize_dispersion`
    magnitude fields (``predicted_dispersion`` etc.) are merged in —
    the quantitative K-step envelope, not just the direction.
    """
    if lr is not None and steps is not None:
        return predict_post_resize_dispersion(
            sigma2_workers, lr=lr, steps=steps, momentum=momentum,
            drift2=drift2, curvature=curvature, alive=alive)
    s2 = np.asarray(sigma2_workers, dtype=np.float64).reshape(-1)
    if alive is None:
        a = np.ones_like(s2)
    else:
        a = (np.asarray(alive, dtype=np.float64).reshape(-1) > 0)
        a = a.astype(np.float64)
        if a.shape != s2.shape:
            raise ValueError(f"alive {a.shape} vs sigma2 {s2.shape}")
    n = float(a.sum())
    if n < 1:
        raise ValueError("predict_averaging_benefit needs >=1 alive worker")
    sigma2_bar = float((s2 * a).sum() / n)
    return {
        "n_alive": n,
        "sigma2_bar": sigma2_bar,
        "rho": float(beta2) * float(dist2) / max(sigma2_bar, 1e-30),
        "variance_reduction": 1.0 / n,
        "benefit": sigma2_bar * (1.0 - 1.0 / n),
    }


def predict_post_resize_dispersion(sigma2_workers, *, lr: float,
                                   steps: int, momentum: float = 0.0,
                                   drift2: float = 0.0,
                                   curvature: float = 0.0,
                                   alive=None) -> dict:
    """Predict the Eq. 4 dispersion *magnitude* ``steps`` local steps
    after a consensus point (a resize warm-start, an averaging event)
    via the K-weighted drift budget of Parallel Restarted SGD
    (arXiv 1807.06629, Thm. 2's noise + divergence decomposition).

    Every worker starts the window at the shared consensus, so after K
    steps its deviation from the mean is a weighted sum of its own
    gradient noise plus the drift of its shard mean from the global
    objective. With heavy-ball momentum each past gradient g_j is still
    being applied at step K with total weight

        c_j = lr * (1 - mu^(K - j + 1)) / (1 - mu)

    (= lr for plain SGD). Independent per-step noise adds in quadrature
    and loses the 1/n mean-projection share; the per-shard drift is the
    same direction every step, so its weights add coherently:

        E disp ≈ Σ_j c_j² · σ̄² · (1 - 1/n)  +  (Σ_j c_j γ^(j-1))² · drift²

    — linear in K for the noise term, quadratic (at γ = 1) for the
    drift term, exactly the two regimes the K-step bounds trade off.
    ``drift2`` is the mean squared deviation of the per-shard mean
    gradients from their across-shard mean (0 for IID shards);
    ``sigma2_workers`` the per-worker σ² estimates *at the batch size
    used* (σ²_sample / batch). ``curvature`` is the local curvature λ
    of the shard objectives along the drift directions (a Rayleigh
    quotient d'Hd/d'd; 0 keeps the raw budget): each local step
    contracts the shard gradient by γ = 1 - lr·λ as the worker
    descends its own shard objective, so the coherent drift
    accumulation is geometric, not linear — without it the raw budget
    systematically over-predicts on curved objectives. Returns the
    :func:`predict_averaging_benefit` fields plus ``k``,
    ``noise_dispersion``, ``drift_dispersion`` and their sum
    ``predicted_dispersion``.
    """
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    if not 0.0 <= momentum < 1.0:
        raise ValueError(f"momentum must be in [0, 1), got {momentum}")
    gamma = 1.0 - float(lr) * float(curvature)
    if not 0.0 <= gamma <= 1.0:
        raise ValueError(
            f"lr * curvature = {float(lr) * float(curvature)} must be in "
            "[0, 1] — beyond it the one-step drift contraction "
            "1 - lr*curvature is not a contraction at all")
    base = predict_averaging_benefit(sigma2_workers, alive=alive)
    k = int(steps)
    mu = float(momentum)
    j = np.arange(1, k + 1, dtype=np.float64)
    if mu > 0.0:
        c = float(lr) * (1.0 - mu ** (k - j + 1.0)) / (1.0 - mu)
    else:
        c = np.full(k, float(lr))
    n = base["n_alive"]
    noise = float((c ** 2).sum()) * base["sigma2_bar"] * (1.0 - 1.0 / n)
    drift = float((c * gamma ** (j - 1.0)).sum()) ** 2 * float(drift2)
    base.update({
        "k": k,
        "noise_dispersion": noise,
        "drift_dispersion": drift,
        "predicted_dispersion": noise + drift,
    })
    return base


def empirical_variance_fn(kind: str, X, y):
    """Definition 1 for a dataset: jitted Δ(w)."""
    from repro.models.convex import gradient_variance

    @jax.jit
    def fn(w):
        return gradient_variance(kind, w, X, y)
    return fn

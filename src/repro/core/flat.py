"""Flat parameter plane: the whole worker model as ONE (M, P) buffer.

The phase engine's averaging events are pure worker-axis reductions —
mean over M, dispersion around that mean, an optional outer-optimizer
step on the mean. On a params *pytree* each of those is a separate tree
traversal (PR 1 paid 3–4 per event); on a contiguous ``(M, P)`` plane
they are one tiled pass over a single buffer, which is exactly the shape
``repro.kernels.avg_disp`` fuses.

:class:`FlatSpec` records the leaf layout (treedef, shapes, dtypes,
column offsets) so packing is invertible:

    spec  = FlatSpec.of(worker_params)        # leaves (M, *shape)
    plane = spec.pack(worker_params)          # (M, P) float32
    tree  = spec.unpack(plane)                # == worker_params bit-exact

The plane dtype is float32. float32 leaves are stored verbatim;
bfloat16/float16 leaves are stored as their exact float32 image (both
formats embed losslessly in float32) and rounded back on unpack, so the
pack→unpack roundtrip is bit-exact for every finite value and ±inf.
Integer / wider-than-32-bit leaves are not representable this way —
:func:`FlatSpec.supports` reports that, and the engine falls back to the
tree path for such trees.

``pack1``/``unpack1`` are the rank-(P,) variants for trees WITHOUT the
worker axis (consensus params, outer-optimizer state).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

_PACKABLE = (jnp.float32, jnp.bfloat16, jnp.float16)


def _packable(dtype) -> bool:
    return any(jnp.dtype(dtype) == jnp.dtype(d) for d in _PACKABLE)


@dataclass(frozen=True)
class FlatSpec:
    """Layout of a params pytree inside a flat float32 plane."""
    treedef: Any
    shapes: tuple          # per-leaf shapes WITHOUT the worker axis
    dtypes: tuple          # per-leaf original dtypes
    offsets: tuple         # per-leaf first column
    width: int             # P: total columns

    # ---- construction ----------------------------------------------------
    @classmethod
    def of(cls, tree, *, worker_axis: bool = True) -> "FlatSpec":
        """Build the spec from a (possibly abstract) pytree. With
        ``worker_axis`` the leading dim of every leaf is the worker axis
        and is excluded from the layout."""
        leaves, treedef = jax.tree.flatten(tree)
        shapes, dtypes, offsets = [], [], []
        off = 0
        for x in leaves:
            if not _packable(x.dtype):
                raise TypeError(
                    f"FlatSpec: dtype {x.dtype} has no exact float32 "
                    "image; use the tree path for this tree")
            shape = tuple(x.shape[1:] if worker_axis else x.shape)
            shapes.append(shape)
            dtypes.append(jnp.dtype(x.dtype))
            offsets.append(off)
            off += math.prod(shape)
        return cls(treedef, tuple(shapes), tuple(dtypes), tuple(offsets),
                   off)

    @staticmethod
    def supports(tree) -> bool:
        """True iff every leaf dtype embeds exactly in float32."""
        return all(_packable(x.dtype) for x in jax.tree.leaves(tree))

    # ---- (M, P) plane <-> worker tree ------------------------------------
    def pack(self, tree):
        """Leaves (M, *shape) -> (M, P) float32, columns in leaf order."""
        leaves = self.treedef.flatten_up_to(tree)
        m = leaves[0].shape[0] if leaves else 0
        cols = [jnp.asarray(x).astype(jnp.float32).reshape(m, -1)
                for x in leaves]
        return jnp.concatenate(cols, axis=1) if cols else \
            jnp.zeros((m, 0), jnp.float32)

    def unpack(self, plane):
        """(M, P) float32 -> leaves (M, *shape) in their original dtype."""
        m = plane.shape[0]
        leaves = [
            plane[:, o:o + math.prod(s)].reshape((m,) + s).astype(dt)
            for o, s, dt in zip(self.offsets, self.shapes, self.dtypes)]
        return jax.tree.unflatten(self.treedef, leaves)

    # ---- (P,) vector <-> consensus tree ----------------------------------
    def pack1(self, tree):
        """Leaves of exactly ``shape`` (no worker axis) -> (P,) float32."""
        leaves = self.treedef.flatten_up_to(tree)
        cols = [jnp.asarray(x).astype(jnp.float32).reshape(-1)
                for x in leaves]
        return jnp.concatenate(cols) if cols else jnp.zeros((0,),
                                                            jnp.float32)

    def unpack1(self, vec, *, dtypes=None):
        """(P,) float32 -> consensus tree. ``dtypes`` overrides the cast
        (e.g. ``jnp.float32`` for outer-optimizer velocity, which mirrors
        the param structure but stays float32)."""
        if dtypes is None:
            dtypes = self.dtypes
        elif not isinstance(dtypes, tuple):
            dtypes = (jnp.dtype(dtypes),) * len(self.shapes)
        leaves = [vec[o:o + math.prod(s)].reshape(s).astype(dt)
                  for o, s, dt in zip(self.offsets, self.shapes, dtypes)]
        return jax.tree.unflatten(self.treedef, leaves)

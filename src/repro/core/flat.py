"""Flat parameter plane: the whole worker model as ONE (M, P) buffer.

The phase engine's averaging events are pure worker-axis reductions —
mean over M, dispersion around that mean, an optional outer-optimizer
step on the mean. On a params *pytree* each of those is a separate tree
traversal (PR 1 paid 3–4 per event); on a contiguous ``(M, P)`` plane
they are one tiled pass over a single buffer, which is exactly the shape
``repro.kernels.avg_disp`` fuses.

:class:`FlatSpec` records the leaf layout (treedef, shapes, dtypes,
column offsets) so packing is invertible:

    spec  = FlatSpec.of(worker_params)        # leaves (M, *shape)
    plane = spec.pack(worker_params)          # (M, P) float32
    tree  = spec.unpack(plane)                # == worker_params bit-exact

The plane dtype is float32. float32 leaves are stored verbatim;
bfloat16/float16 leaves are stored as their exact float32 image (both
formats embed losslessly in float32) and rounded back on unpack, so the
pack→unpack roundtrip is bit-exact for every finite value and ±inf.
Integer / wider-than-32-bit leaves are not representable this way —
:func:`FlatSpec.supports` reports that, and the engine falls back to the
tree path for such trees.

``pack1``/``unpack1`` are the rank-(P,) variants for trees WITHOUT the
worker axis (consensus params, outer-optimizer state).

:class:`FlatOptSpec` extends the plane to the *optimizer state*: when an
optimizer's state is S structural copies of the params tree in float32
(Momentum velocity: S=1; AdamW moments: S=2; SGD: S=0), the state packs
into S extra ``(M, P)`` planes whose columns align 1:1 with the param
plane — the layout ``repro.kernels.opt_step`` fuses the local update
into. ``rounding_codes`` gives the per-column dtype codes that let a
plane-resident update round exactly like the pytree optimizers'
``.astype(p.dtype)`` after every step.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_PACKABLE = (jnp.float32, jnp.bfloat16, jnp.float16)

#: per-column dtype codes for plane-resident rounding (0 = float32
#: verbatim, 1 = round through bfloat16, 2 = round through float16)
ROUND_F32, ROUND_BF16, ROUND_F16 = 0, 1, 2


def _packable(dtype) -> bool:
    return any(jnp.dtype(dtype) == jnp.dtype(d) for d in _PACKABLE)


@dataclass(frozen=True)
class FlatSpec:
    """Layout of a params pytree inside a flat float32 plane."""
    treedef: Any
    shapes: tuple          # per-leaf shapes WITHOUT the worker axis
    dtypes: tuple          # per-leaf original dtypes
    offsets: tuple         # per-leaf first column
    width: int             # P: total columns

    # ---- construction ----------------------------------------------------
    @classmethod
    def of(cls, tree, *, worker_axis: bool = True) -> "FlatSpec":
        """Build the spec from a (possibly abstract) pytree. With
        ``worker_axis`` the leading dim of every leaf is the worker axis
        and is excluded from the layout."""
        leaves, treedef = jax.tree.flatten(tree)
        shapes, dtypes, offsets = [], [], []
        off = 0
        for x in leaves:
            if not _packable(x.dtype):
                raise TypeError(
                    f"FlatSpec: dtype {x.dtype} has no exact float32 "
                    "image; use the tree path for this tree")
            shape = tuple(x.shape[1:] if worker_axis else x.shape)
            shapes.append(shape)
            dtypes.append(jnp.dtype(x.dtype))
            offsets.append(off)
            off += math.prod(shape)
        return cls(treedef, tuple(shapes), tuple(dtypes), tuple(offsets),
                   off)

    @staticmethod
    def supports(tree) -> bool:
        """True iff every leaf dtype embeds exactly in float32."""
        return all(_packable(x.dtype) for x in jax.tree.leaves(tree))

    # ---- (M, P) plane <-> worker tree ------------------------------------
    def pack(self, tree):
        """Leaves (M, *shape) -> (M, P) float32, columns in leaf order."""
        leaves = self.treedef.flatten_up_to(tree)
        m = leaves[0].shape[0] if leaves else 0
        cols = [jnp.asarray(x).astype(jnp.float32).reshape(m, -1)
                for x in leaves]
        return jnp.concatenate(cols, axis=1) if cols else \
            jnp.zeros((m, 0), jnp.float32)

    def unpack(self, plane, *, dtypes=None):
        """(M, P) float32 -> leaves (M, *shape) in their original dtype.
        ``dtypes`` overrides the cast (e.g. ``jnp.float32`` for optimizer
        moments, which mirror the param structure but stay float32)."""
        if dtypes is None:
            dtypes = self.dtypes
        elif not isinstance(dtypes, tuple):
            dtypes = (jnp.dtype(dtypes),) * len(self.shapes)
        m = plane.shape[0]
        leaves = [
            plane[:, o:o + math.prod(s)].reshape((m,) + s).astype(dt)
            for o, s, dt in zip(self.offsets, self.shapes, dtypes)]
        return jax.tree.unflatten(self.treedef, leaves)

    # ---- per-column dtype rounding ----------------------------------------
    def rounding_codes(self):
        """(P,) float32 per-column rounding codes (``ROUND_*``), or None
        when every leaf is float32 (no rounding pass needed). The codes
        let a plane-resident optimizer update reproduce the pytree path's
        ``.astype(p.dtype)`` bit-exactly: a bf16/f16 leaf's columns are
        rounded through their dtype after every update, so the plane
        always holds the exact float32 image of the tree."""
        if all(dt == jnp.dtype(jnp.float32) for dt in self.dtypes):
            return None
        codes = np.zeros(self.width, np.float32)
        for o, s, dt in zip(self.offsets, self.shapes, self.dtypes):
            if dt == jnp.dtype(jnp.bfloat16):
                codes[o:o + math.prod(s)] = ROUND_BF16
            elif dt == jnp.dtype(jnp.float16):
                codes[o:o + math.prod(s)] = ROUND_F16
        return codes

    # ---- (P,) vector <-> consensus tree ----------------------------------
    def pack1(self, tree):
        """Leaves of exactly ``shape`` (no worker axis) -> (P,) float32."""
        leaves = self.treedef.flatten_up_to(tree)
        cols = [jnp.asarray(x).astype(jnp.float32).reshape(-1)
                for x in leaves]
        return jnp.concatenate(cols) if cols else jnp.zeros((0,),
                                                            jnp.float32)

    def unpack1(self, vec, *, dtypes=None):
        """(P,) float32 -> consensus tree. ``dtypes`` overrides the cast
        (e.g. ``jnp.float32`` for outer-optimizer velocity, which mirrors
        the param structure but stays float32)."""
        if dtypes is None:
            dtypes = self.dtypes
        elif not isinstance(dtypes, tuple):
            dtypes = (jnp.dtype(dtypes),) * len(self.shapes)
        leaves = [vec[o:o + math.prod(s)].reshape(s).astype(dt)
                  for o, s, dt in zip(self.offsets, self.shapes, dtypes)]
        return jax.tree.unflatten(self.treedef, leaves)


@dataclass(frozen=True)
class FlatOptSpec:
    """Layout of an optimizer-state pytree as S extra (M, P) planes.

    Applies when the state is S structural copies of the params tree —
    float32 leaves of the param shapes, grouped copy-by-copy in flatten
    order (Momentum velocity S=1; AdamW ``{"m": .., "v": ..}`` S=2; SGD
    ``()`` S=0). Each copy packs through the param :class:`FlatSpec`, so
    state column j describes the same parameter as param column j — the
    alignment ``repro.kernels.opt_step`` relies on. :meth:`of` returns
    None for states that don't align (the engine then falls back to the
    per-step pack/unpack path).
    """
    treedef: Any           # the full opt-state treedef
    num_planes: int        # S
    param: FlatSpec

    @classmethod
    def of(cls, param: FlatSpec, opt_state) -> "FlatOptSpec | None":
        leaves, treedef = jax.tree.flatten(opt_state)
        n = len(param.shapes)
        if n == 0:
            return None
        if not leaves:
            return cls(treedef, 0, param)
        if len(leaves) % n:
            return None
        s = len(leaves) // n
        for k in range(s):
            for j in range(n):
                x = leaves[k * n + j]
                if (jnp.dtype(x.dtype) != jnp.dtype(jnp.float32)
                        or tuple(x.shape[1:]) != param.shapes[j]):
                    return None
        return cls(treedef, s, param)

    def pack(self, opt_state) -> tuple:
        """State tree -> tuple of S (M, P) float32 planes."""
        leaves = self.treedef.flatten_up_to(opt_state)
        n = len(self.param.shapes)
        return tuple(
            self.param.pack(
                jax.tree.unflatten(self.param.treedef,
                                   leaves[k * n:(k + 1) * n]))
            for k in range(self.num_planes))

    def unpack(self, planes: tuple):
        """Tuple of S (M, P) planes -> state tree (float32 leaves)."""
        leaves = []
        for pl in planes:
            leaves.extend(jax.tree.leaves(
                self.param.unpack(pl, dtypes=jnp.float32)))
        return jax.tree.unflatten(self.treedef, leaves)

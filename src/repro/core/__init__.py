"""Core: the paper's contribution — periodic model averaging for parallel
SGD, its variance model, and its closed-form theory."""
from repro.core.averaging import (  # noqa: F401
    AveragingSchedule,
    OuterOptimizer,
    SchedState,
    average_all,
    average_inner,
    worker_dispersion,
)
from repro.core.compress import (  # noqa: F401
    WIRE_FORMATS,
    Compression,
    wire_row_bytes,
)
from repro.core.engine import (EngineState, PhaseEngine,  # noqa: F401
                               make_plane_step, make_worker_step, tree_stack)
from repro.core.flat import FlatOptSpec, FlatSpec  # noqa: F401
from repro.core.local_sgd import LocalSGD, consensus, replicate, unreplicate  # noqa: F401
from repro.core.theory import (  # noqa: F401
    lemma1_asymptotic_variance,
    simulate_quadratic,
)
from repro.core.variance_model import (  # noqa: F401
    measure_beta2,
    measure_sigma2,
    predict_averaging_benefit,
    predict_post_resize_dispersion,
    rho,
)
from repro.faults import FaultEvent, FaultPlan, FaultState  # noqa: F401
from repro.topology import Topology  # noqa: F401

"""Compiled phase engine: K local steps + averaging as ONE jitted program.

The paper's algorithm is phase-structured — M workers each take K
independent SGD steps (Eq. 3), then their models are averaged — yet a
naive runtime dispatches one jitted call per step, decides averaging on
the host, and blocks on ``float()`` metric reads. This module compiles
the whole phase instead:

    run_phase(state, batches)          # ONE dispatch per phase
      └─ jax.lax.scan over K steps     # batches prefetched as a stacked
           └─ vmap over M workers      #   (K, M, ...) device block
           └─ schedule.decision_code   # on-device: lax.switch applies
                none / inner / all averaging (+ outer optimizer)
      └─ loss + dispersion traces accumulated on-device, fetched once

All engine state (worker params, optimizer state, outer-optimizer state,
PRNG keys, step counter) lives in an :class:`EngineState` pytree that is
buffer-donated to ``run_phase``, so a phase updates parameters in place.
Averaging decisions — including the stochastic schedule's Bernoulli
draws — are pure functions of a single PRNG key and the step counter
(``fold_in(key, step)``), so runs are bitwise reproducible and resumable
from a checkpointed ``EngineState``.

Schedules lower to on-device control flow as follows:

  - oneshot     : statically no averaging branch at all
  - minibatch   : the all-average is unconditionally fused into each step
  - periodic(K) : ``step % K == 0`` predicate under ``lax.switch``
  - stochastic  : ``bernoulli(fold_in(key, step), ζ)`` under ``lax.switch``
  - hierarchical: two modulo predicates select none / inner / all

:meth:`PhaseEngine.run` is the production driver (one compiled dispatch
per phase); :meth:`PhaseEngine.run_host` keeps the legacy per-step
host-driven loop — same numerics, same decision stream — as the baseline
for `benchmarks/bench_engine.py` and the equivalence tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property, partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.averaging import (AveragingSchedule, OuterOptimizer,
                                  average_inner, worker_dispersion)


# --------------------------------------------------------------------------
# Worker-axis utilities (leading axis = worker index on every leaf)
# --------------------------------------------------------------------------

def replicate(tree, num_workers: int):
    """Give every leaf a leading worker axis (all workers start at w_0,
    as the paper prescribes)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (num_workers,) + x.shape), tree)


def unreplicate(tree):
    return jax.tree.map(lambda x: x[0], tree)


def consensus(tree):
    """The paper's final estimate: the average of the workers."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), tree)


def tree_stack(trees):
    """Stack a list of per-step batches into one (K, ...) device block."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def make_worker_step(loss_fn: Callable, optimizer) -> Callable:
    """The ONE vmapped local-SGD step (paper Eq. 3) every runtime path
    shares: LocalSGD, the phase engine's scan body, and the launch/dryrun
    train steps.

    loss_fn(params, batch, rng) -> (loss, aux); optimizer is an
    init/apply pair from repro.optim. Returns
    step_fn(worker_params, opt_state, batch, step, rngs=None)
    -> (worker_params, opt_state, per-worker losses, aux).
    """
    def one(params, ostate, batch, rng, step):
        (loss, aux), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, rng)
        params, ostate = optimizer.apply(params, grads, ostate, step)
        return params, ostate, loss, aux

    def step_fn(worker_params, opt_state, batch, step, rngs=None):
        if rngs is None:  # rng-free losses (launch/dryrun abstract paths)
            return jax.vmap(lambda p, s, b: one(p, s, b, None, step))(
                worker_params, opt_state, batch)
        return jax.vmap(lambda p, s, b, r: one(p, s, b, r, step))(
            worker_params, opt_state, batch, rngs)

    return step_fn


class EngineState(NamedTuple):
    """Everything a phase consumes and produces; donated to run_phase."""
    worker_params: Any   # leaves (M, ...)
    opt_state: Any       # leaves (M, ...)
    outer_state: Any     # (prev_avg, velocity) trees, or () without outer
    key: Any             # data-rng key, split once per step
    dec_key: Any         # schedule-decision root key (constant)
    step: Any            # int32 scalar, steps completed


@dataclass(frozen=True, eq=False)  # eq=False: hash by identity for jit
class PhaseEngine:
    """loss_fn(params, batch, rng) -> (loss, aux); optimizer from
    repro.optim (init/apply pair).

    ``scan_unroll`` is forwarded to ``lax.scan``: XLA:CPU runs while-loop
    bodies with reduced intra-op threading, so compute-heavy losses (e.g.
    convolutions) on CPU backends benefit from ``scan_unroll=True`` (full
    unroll: longer compiles, per-step speed of eager dispatch). On real
    accelerator meshes leave the default rolled scan."""
    loss_fn: Callable
    optimizer: Any
    schedule: AveragingSchedule
    outer: OuterOptimizer | None = None
    scan_unroll: int | bool = 1

    @cached_property
    def worker_step(self):
        return make_worker_step(self.loss_fn, self.optimizer)

    # ---- state -----------------------------------------------------------
    def init(self, params, num_workers: int, seed: int = 0) -> EngineState:
        wp = replicate(params, num_workers)
        opt_state = jax.vmap(self.optimizer.init)(wp)
        outer_state = ()
        if self.outer is not None:
            avg = consensus(wp)
            outer_state = (avg, self.outer.init(avg))
        key, dec_key = jax.random.split(jax.random.PRNGKey(seed))
        return EngineState(wp, opt_state, outer_state, key, dec_key,
                           jnp.zeros((), jnp.int32))

    # ---- the compiled phase ---------------------------------------------
    def _apply_all_average(self, wp, outer_state, num_workers):
        avg = consensus(wp)
        if self.outer is not None:
            prev_avg, vel = outer_state
            avg, vel = self.outer.apply(prev_avg, avg, vel)
            outer_state = (avg, vel)
        return replicate(avg, num_workers), outer_state

    @partial(jax.jit, static_argnums=0, donate_argnums=1)
    def run_phase(self, state: EngineState, batches):
        """One compiled dispatch: scan K steps over a stacked (K, M, ...)
        batch block, averaging fused per the schedule. Returns the new
        state and per-step traces {loss, dispersion, avg_code} — the only
        host transfer a phase needs."""
        num_workers = jax.tree.leaves(state.worker_params)[0].shape[0]
        sched = self.schedule

        def body(carry, batch):
            wp, opt_state, outer_state, key, step = carry
            step = step + 1
            key, sub = jax.random.split(key)
            rngs = jax.random.split(sub, num_workers)
            wp, opt_state, losses, _ = self.worker_step(
                wp, opt_state, batch, step, rngs)
            code = sched.decision_code(step, state.dec_key)
            if sched.kind == "oneshot":
                disp = jnp.zeros((), jnp.float32)
            elif sched.kind == "minibatch":
                disp = worker_dispersion(wp).astype(jnp.float32)
                wp, outer_state = self._apply_all_average(
                    wp, outer_state, num_workers)
            else:
                def none_branch(args):
                    wp, ost = args
                    return wp, ost, jnp.zeros((), jnp.float32)

                def inner_branch(args):
                    wp, ost = args
                    disp = worker_dispersion(wp).astype(jnp.float32)
                    return (average_inner(wp, max(sched.inner_groups, 1)),
                            ost, disp)

                def all_branch(args):
                    wp, ost = args
                    disp = worker_dispersion(wp).astype(jnp.float32)
                    wp, ost = self._apply_all_average(wp, ost, num_workers)
                    return wp, ost, disp

                wp, outer_state, disp = jax.lax.switch(
                    code, [none_branch, inner_branch, all_branch],
                    (wp, outer_state))
            return ((wp, opt_state, outer_state, key, step),
                    (jnp.mean(losses), disp, code))

        carry0 = (state.worker_params, state.opt_state, state.outer_state,
                  state.key, state.step)
        (wp, opt_state, outer_state, key, step), (loss, disp, code) = \
            jax.lax.scan(body, carry0, batches, unroll=self.scan_unroll)
        new_state = EngineState(wp, opt_state, outer_state, key,
                                state.dec_key, step)
        return new_state, {"loss": loss, "dispersion": disp,
                           "avg_code": code}

    def default_phase_len(self) -> int:
        """Compile-size heuristic: align phase blocks with the schedule's
        natural period (correctness never depends on the block size —
        decisions are per-step, on-device)."""
        s = self.schedule
        if s.kind == "periodic":
            return max(1, min(s.phase_len, 512))
        if s.kind == "hierarchical":
            return max(1, min(s.inner_phase_len, 512))
        if s.kind == "stochastic":
            return int(min(max(1.0 / max(s.zeta, 1e-12), 8), 128))
        return 64  # oneshot / minibatch: any block size

    # ---- drivers ---------------------------------------------------------
    def run(self, params, batches, *, num_workers: int, seed: int = 0,
            record_every: int = 0, eval_fn=None, worker_eval_fn=None,
            phase_len: int | None = None):
        """Production driver: one run_phase dispatch per block of steps.

        batches: iterable of per-step worker batches (leading axis M).
        eval_fn(consensus_params) / worker_eval_fn(worker_params) run on
        host every ``record_every`` steps (phase blocks are cut so record
        boundaries coincide with phase ends). Returns (final averaged
        params, history dict).
        """
        state = self.init(params, num_workers, seed)
        block = phase_len or self.default_phase_len()
        needs_eval = record_every and (eval_fn or worker_eval_fn)
        hist = {"loss": [], "dispersion": [], "averages": 0, "eval": [],
                "worker_eval": []}
        it = iter(batches)
        t, done = 0, False
        while not done:
            take = block
            if needs_eval:
                take = min(take, record_every - t % record_every)
            chunk = []
            while len(chunk) < take:
                try:
                    chunk.append(next(it))
                except StopIteration:
                    done = True
                    break
            if not chunk:
                break
            state, trace = self.run_phase(state, tree_stack(chunk))
            trace = jax.device_get(trace)
            for i in range(len(chunk)):
                t += 1
                if trace["avg_code"][i]:
                    hist["dispersion"].append(
                        (t, float(trace["dispersion"][i])))
                    hist["averages"] += 1
                if record_every and t % record_every == 0:
                    hist["loss"].append((t, float(trace["loss"][i])))
            if needs_eval and t % record_every == 0:
                if eval_fn is not None:
                    hist["eval"].append(
                        (t, eval_fn(consensus(state.worker_params))))
                if worker_eval_fn is not None:
                    hist["worker_eval"].append(
                        (t, worker_eval_fn(state.worker_params)))
        return consensus(state.worker_params), hist

    # ---- legacy host-driven loop (benchmark baseline / equivalence) ------
    @partial(jax.jit, static_argnums=0)
    def _host_step(self, wp, opt_state, batch, step, rngs):
        wp, opt_state, losses, _ = self.worker_step(wp, opt_state, batch,
                                                    step, rngs)
        return wp, opt_state, jnp.mean(losses)

    @partial(jax.jit, static_argnums=(0, 3))
    def _host_average(self, wp, outer_state, scope: str):
        num_workers = jax.tree.leaves(wp)[0].shape[0]
        disp = worker_dispersion(wp).astype(jnp.float32)
        if scope == "inner":
            return (average_inner(wp, max(self.schedule.inner_groups, 1)),
                    outer_state, disp)
        wp, outer_state = self._apply_all_average(wp, outer_state,
                                                  num_workers)
        return wp, outer_state, disp

    def run_host(self, params, batches, *, num_workers: int, seed: int = 0,
                 record_every: int = 0, eval_fn=None):
        """Per-step host-driven loop: one jit dispatch per step, the
        averaging decision read on host, blocking ``float()`` metric
        reads. Numerically identical to :meth:`run` (same per-step rng
        splits, same fold_in decision stream) — kept as the dispatch-bound
        baseline the engine is benchmarked against."""
        state = self.init(params, num_workers, seed)
        wp, opt_state, outer_state = (state.worker_params, state.opt_state,
                                      state.outer_state)
        key = state.key
        hist = {"loss": [], "dispersion": [], "averages": 0, "eval": [],
                "worker_eval": []}
        step = 0
        for batch in batches:
            step += 1
            key, sub = jax.random.split(key)
            rngs = jax.random.split(sub, num_workers)
            wp, opt_state, loss = self._host_step(
                wp, opt_state, batch, jnp.asarray(step, jnp.int32), rngs)
            code = int(self.schedule.decision_code(step, state.dec_key))
            if code:
                wp, outer_state, disp = self._host_average(
                    wp, outer_state, "inner" if code == 1 else "all")
                hist["dispersion"].append((step, float(disp)))
                hist["averages"] += 1
            if record_every and step % record_every == 0:
                hist["loss"].append((step, float(loss)))
                if eval_fn is not None:
                    hist["eval"].append((step, eval_fn(consensus(wp))))
        return consensus(wp), hist

"""Compiled phase engine: K local steps + averaging as ONE jitted program.

The paper's algorithm is phase-structured — M workers each take K
independent SGD steps (Eq. 3), then their models are averaged — yet a
naive runtime dispatches one jitted call per step, decides averaging on
the host, and blocks on ``float()`` metric reads. This module compiles
the whole phase instead:

    run_phase(state, batches)          # ONE dispatch per phase
      └─ jax.lax.scan over K steps     # batches gathered on-device from
           └─ vmap over M workers      #   index blocks, or prefetched as
           └─ schedule.decision_state  #   a staged (K, M, ...) block
                none / inner / all averaging (+ outer optimizer)
      └─ loss + dispersion traces accumulated on-device, fetched once

All engine state (worker params, optimizer state, outer-optimizer state,
PRNG keys, step counter) lives in an :class:`EngineState` pytree that is
buffer-donated to ``run_phase``, so a phase updates parameters in place.
Averaging decisions — including the stochastic schedule's Bernoulli
draws — are pure functions of a single PRNG key and the step counter
(``fold_in(key, step)``), so runs are bitwise reproducible and resumable
from a checkpointed ``EngineState``.

Two device-residency layers sit on top of the PR 1 scan:

- **Flat parameter plane** (default): inside a phase the scan carries
  the workers as one contiguous ``(M, P)`` float32 plane
  (:class:`repro.core.flat.FlatSpec`; bit-exact pack/unpack), so every
  averaging event is a single fused pass — worker mean (global or
  per-group), Eq. 4 dispersion, broadcast, and the outer-optimizer
  momentum step — instead of 3–4 params-pytree traversals
  (``repro.kernels.avg_disp`` on TPU, its jnp twin on CPU). Trees with
  dtypes that have no exact float32 image fall back to the tree path.
- **On-device data plane**: :meth:`run` accepts a
  :class:`repro.data.pipeline.DeviceDataset` — the dataset lives on
  device, the driver ships (K, M, B) int32 index blocks, and the scan
  body gathers batches with ``jnp.take`` — zero per-phase host staging.
  Streaming iterables are staged by a double-buffered
  :class:`repro.data.pipeline.Prefetcher` thread instead.

Schedules lower to on-device control flow as follows:

  - oneshot     : statically no averaging branch at all
  - minibatch   : the all-average is unconditionally fused into each step
  - periodic(K) : ``step % K == 0`` predicate under ``lax.switch``
  - stochastic  : ``bernoulli(fold_in(key, step), ζ)`` under ``lax.switch``
  - hierarchical: two modulo predicates select none / inner / all
  - adaptive_threshold / adaptive_budget: the fused step passes emit the
    Eq. 4 dispersion EVERY step; ``AveragingSchedule.decision_state`` —
    a pure transition on the :class:`repro.core.averaging.SchedState`
    carried in the scan and in :class:`EngineState` — turns it into the
    none / all decision under the same ``lax.switch``

Because the fused passes always measure the dispersion, the per-step
``dispersion`` trace is the true Eq. 4 diagnostic on EVERY step (it used
to read 0.0 between averaging events), in all four paths: flat-native,
flat, tree, and the host loop — and in both sharded collectives (psum
mode pays one extra psum of the per-shard squared sums per step).

A :class:`repro.topology.Topology` generalizes the "all"-scope event
from the full mean to one doubly-stochastic mixing-matrix application
``plane <- W @ plane`` (ring / torus / hypercube / random gossip pairs /
disconnected), fused into the same passes; ``full`` and ``groups``
topologies lower to the existing mean / block-mean code bit-exactly.

:meth:`PhaseEngine.run` is the production driver (one compiled dispatch
per phase); :meth:`PhaseEngine.run_host` keeps the legacy per-step
host-driven loop — same numerics, same decision stream — as the baseline
for `benchmarks/bench_engine.py` and the equivalence tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import cached_property, partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.averaging import (AveragingSchedule, OuterOptimizer,
                                  SchedState, average_inner,
                                  worker_dispersion)
from repro.core.compress import Compression, encode_decode, row_uniforms
from repro.core.flat import FlatOptSpec, FlatSpec
from repro.data.pipeline import DeviceDataset, Prefetcher
from repro import faults as faults_mod
from repro.faults import FaultPlan, FaultState
from repro.kernels.avg_disp import (avg_disp, avg_disp_outer,
                                    compressed_mix, mix_disp)
from repro.kernels.opt_step import opt_step
from repro.telemetry import metrics as tele_metrics
from repro.telemetry.events import init_history, make_record
from repro.kernels.ref import (avg_disp_outer_ref, avg_disp_ref,
                               compressed_avg_ref, compressed_mix_ref,
                               mix_disp_ref, opt_step_ref,
                               plane_average_ref, plane_update_ref,
                               round_to_codes)
from repro.topology import MIX_KINDS, Topology, comm_bytes, mix_tree


# --------------------------------------------------------------------------
# Worker-axis utilities (leading axis = worker index on every leaf)
# --------------------------------------------------------------------------

def replicate(tree, num_workers: int):
    """Give every leaf a leading worker axis (all workers start at w_0,
    as the paper prescribes)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (num_workers,) + x.shape), tree)


def unreplicate(tree):
    return jax.tree.map(lambda x: x[0], tree)


def consensus(tree):
    """The paper's final estimate: the average of the workers."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), tree)


def tree_stack(trees):
    """Stack a list of per-step batches into one (K, ...) device block."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def make_worker_step(loss_fn: Callable, optimizer) -> Callable:
    """The ONE vmapped local-SGD step (paper Eq. 3) every runtime path
    shares: LocalSGD, the phase engine's scan body, and the launch/dryrun
    train steps.

    loss_fn(params, batch, rng) -> (loss, aux); optimizer is an
    init/apply pair from repro.optim. Returns
    step_fn(worker_params, opt_state, batch, step, rngs=None)
    -> (worker_params, opt_state, per-worker losses, aux).
    """
    def one(params, ostate, batch, rng, step):
        (loss, aux), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, rng)
        params, ostate = optimizer.apply(params, grads, ostate, step)
        return params, ostate, loss, aux

    def step_fn(worker_params, opt_state, batch, step, rngs=None):
        if rngs is None:  # rng-free losses (launch/dryrun abstract paths)
            return jax.vmap(lambda p, s, b: one(p, s, b, None, step))(
                worker_params, opt_state, batch)
        return jax.vmap(lambda p, s, b, r: one(p, s, b, r, step))(
            worker_params, opt_state, batch, rngs)

    return step_fn


def make_plane_step(loss_fn: Callable, spec: FlatSpec) -> Callable:
    """The flat-native local step: losses and gradients straight on the
    (M, P) plane. Each worker row is unpacked to a params *view*
    (``FlatSpec.unpack1``) only inside the traced loss — the plane is
    the only carried representation — and the per-leaf gradients come
    back as one plane row via a single ``pack1`` concatenation (the
    efficient transpose of the unpack: differentiating through the row
    slices instead would build each leaf's cotangent as a full-width
    pad-and-add).

    Returns grads_fn(plane, batch, rngs) -> (losses (M,), aux,
    grad plane (M, P) f32). ``rngs=None`` supports rng-free losses
    (launch/dryrun abstract paths)."""
    def one(row, batch, rng):
        params = spec.unpack1(row)
        (loss, aux), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, rng)
        return loss, aux, spec.pack1(grads)

    def grads_fn(plane, batch, rngs=None):
        if rngs is None:
            return jax.vmap(lambda r, b: one(r, b, None))(plane, batch)
        return jax.vmap(one)(plane, batch, rngs)

    return grads_fn


class EngineState(NamedTuple):
    """Everything a phase consumes and produces; donated to run_phase."""
    worker_params: Any   # leaves (M, ...)
    opt_state: Any       # leaves (M, ...)
    outer_state: Any     # (prev_avg, velocity) trees, or () without outer
    key: Any             # data-rng key, split once per step
    dec_key: Any         # schedule-decision root key (constant)
    step: Any            # int32 scalar, steps completed
    sched: Any = ()      # SchedState (adaptive-schedule carry), or ()
    resid: Any = ()      # (M, P) f32 error-feedback residual plane
    #                    # (compressed communication), or ()
    fault: Any = ()      # FaultState (alive/staleness rows, fault
    #                    # injection — repro.faults), or ()


@dataclass(frozen=True, eq=False)  # eq=False: hash by identity for jit
class PhaseEngine:
    """loss_fn(params, batch, rng) -> (loss, aux); optimizer from
    repro.optim (init/apply pair).

    ``scan_unroll`` is forwarded to ``lax.scan``: XLA:CPU runs while-loop
    bodies with reduced intra-op threading, so compute-heavy losses (e.g.
    convolutions) on CPU backends benefit from ``scan_unroll=True`` (full
    unroll: longer compiles, per-step speed of eager dispatch). On real
    accelerator meshes leave the default rolled scan.

    ``flat`` selects the (M, P) flat-plane scan carry (default; falls
    back to the tree carry for trees FlatSpec cannot embed). With
    ``fused_opt`` (default) and an optimizer that speaks the plane
    protocol (SGD/Momentum/AdamW: ``plane_kind``/``plane_hypers``/
    ``plane_scalars`` + a ``FlatOptSpec``-alignable state), the scan is
    *flat-native*: optimizer state rides as extra (M, P) planes, grads
    come from one vjp through the unpacked view, and every step is one
    fused ``opt_step`` pass (update + optional average + Eq. 4
    dispersion + broadcast) — zero per-step pack/unpack.
    ``kernel_impl`` picks the fused implementation: "auto" (jnp
    reference on CPU, Pallas/Mosaic elsewhere), "ref", or "pallas".

    ``mesh`` shards the phase over a device mesh via ``shard_map``: the
    plane's worker axis M is split over the mesh's worker axes
    (``shard_axes``; defaults to ("pod","data") ∩ mesh axes) and every
    averaging event becomes a cross-shard collective — ``collective=
    "psum"`` (production: O(P) bytes/device) or ``"gather"``
    (full-gather validation mode: bit-identical to the unsharded engine
    for SGD/Momentum; see ``_phase_sharded``). Sharded runs require the
    flat-native path.

    ``topology`` (a :class:`repro.topology.Topology`) generalizes the
    "all"-scope averaging event from the full worker mean to one
    application of the topology's doubly-stochastic mixing matrix,
    ``plane <- W @ plane`` — each worker keeps its own mixed row.
    ``full`` and ``groups`` lower to the existing fused mean /
    group-mean paths (bit-identical to running without a topology /
    to the ``inner_groups`` block mean); the sparse kinds (ring,
    torus, hypercube, gossip_pairs, disconnected) run the fused mix
    pass in every engine path, ``gossip_pairs`` sampling a fresh
    random matching per event as a pure function of (dec_key, step)
    — reproducible and checkpoint/resume-safe with no extra state.
    The outer optimizer steps on the consensus mean, which partial
    mixing never forms, so it requires ``full`` (or no) topology.

    ``compression`` (a :class:`repro.core.compress.Compression`) sets
    the wire precision of every averaging/mixing event: the event
    operator acts on the quantized image ``q`` of the post-update
    plane, with an error-feedback residual carried as one more (M, P)
    plane in ``EngineState.resid`` (checkpoint layout v3). ``f32`` is
    the identity and lowers to the uncompressed paths bit-exactly; the
    quantizing formats require params FlatSpec can embed (every engine
    path encodes on the flat plane) and exclude the outer optimizer,
    whose consensus step needs the exact mean.

    ``faults`` (a :class:`repro.faults.FaultPlan`) makes worker
    failure a scenario axis: a :class:`repro.faults.FaultState`
    ``(alive, staleness)`` carry rides the scan like ``SchedState``
    (checkpoint layout v4), scripted crashes/rejoins are pure
    functions of the step and stochastic straggles of
    ``fold_in(dec_key, salt, step, row)``, so every path, shard and
    resume replays identical fault streams. Dead rows are masked out
    of every event (``faults.degraded_matrix`` renormalizes mixing
    matrices over the alive rows), stragglers skip their local update
    but still receive the event, rejoiners warm-start from the alive
    average with optimizer planes and residual rows zeroed, and the
    final estimate is the alive-worker consensus. A trivial plan (no
    events, zero straggle probability) lowers to the no-fault paths
    bit-exactly; the outer optimizer is excluded (its consensus step
    assumes a fixed membership).

    ``telemetry`` adds the on-device metrics plane
    (:mod:`repro.telemetry.metrics`): a fixed-layout f32 accumulator
    rides the scan carry — per-phase loss/dispersion sums and maxes,
    event counts, nominal ``topology.comm_bytes`` wire bytes, and
    alive/straggle occupancy from the fault streams — and is flushed
    to the host ONCE per phase with the existing trace fetch. The
    accumulator is created inside the phase (never part of
    ``EngineState`` or the checkpoint layout), it only READS values
    the step already computes, and the trained state never consumes
    it, so telemetry on vs off is bit-identical in every path.
    :meth:`run` flushes it into structured records when handed a
    ``sink`` (:class:`repro.telemetry.events.TelemetrySink`)."""
    loss_fn: Callable
    optimizer: Any
    schedule: AveragingSchedule
    outer: OuterOptimizer | None = None
    scan_unroll: int | bool = 1
    flat: bool = True
    kernel_impl: str = "auto"
    fused_opt: bool = True
    mesh: Any = None
    shard_axes: tuple = ()
    collective: str = "psum"
    topology: Topology | None = None
    compression: Compression | None = None
    faults: FaultPlan | None = None
    telemetry: bool = False

    @cached_property
    def worker_step(self):
        return make_worker_step(self.loss_fn, self.optimizer)

    # ---- state -----------------------------------------------------------
    def _check_workers(self, num_workers: int):
        """``average_inner`` reshapes the worker axis into inner_groups
        contiguous groups; a non-dividing group count would surface
        mid-trace as an opaque reshape error — fail eagerly here, where
        M is first known."""
        g = self.schedule.inner_groups
        if self.schedule.kind == "hierarchical" and num_workers % g:
            raise ValueError(
                f"hierarchical inner averaging splits the worker axis "
                f"into inner_groups={g} contiguous groups, but "
                f"num_workers={num_workers} is not divisible by it — "
                "pick inner_groups dividing the worker count")
        t = self.topology
        if t is not None:
            if t.num_workers != num_workers:
                raise ValueError(
                    f"topology '{t.kind}' was built for "
                    f"{t.num_workers} workers but the engine runs "
                    f"{num_workers} — build the Topology with the run's "
                    "worker count")
            if self.outer is not None and t.kind != "full":
                raise ValueError(
                    f"the outer optimizer steps on the consensus mean, "
                    f"which topology '{t.kind}' never forms (partial "
                    "mixing keeps per-worker rows) — use topology "
                    "'full', or drop the outer optimizer")
        if self._comp() is not None and self.outer is not None:
            raise ValueError(
                "the outer optimizer steps on the exact consensus mean, "
                f"which the '{self.compression.wire}' wire format never "
                "ships — use the f32 wire, or drop the outer optimizer")
        fp = self.faults
        if fp is not None:
            if fp.num_workers != num_workers:
                raise ValueError(
                    f"FaultPlan was built for {fp.num_workers} workers "
                    f"but the engine runs {num_workers} — build the plan "
                    "with the run's worker count")
            if self._faults() is not None and self.outer is not None:
                raise ValueError(
                    "the outer optimizer steps on the full-membership "
                    "consensus mean, which a fault plan (crashes / "
                    "stragglers changing the alive set) never preserves "
                    "— drop the outer optimizer, or run without faults")

    def _faults(self) -> FaultPlan | None:
        """The active (non-trivial) fault plan, or None. A plan with no
        events and zero straggle probability IS the no-fault engine —
        lowering it here keeps that configuration bit-exact by
        construction (mirrors ``_comp``'s f32 lowering)."""
        fp = self.faults
        if fp is None or fp.is_trivial:
            return None
        return fp

    def _comp(self) -> Compression | None:
        """The active (non-identity) compression, or None. The ``f32``
        wire IS the existing uncompressed path — lowering it here keeps
        that configuration bit-exact by construction."""
        c = self.compression
        if c is None or c.is_identity:
            return None
        return c

    def _check_compressible(self, worker_params):
        if self._comp() is not None and not FlatSpec.supports(worker_params):
            raise ValueError(
                "compressed communication encodes averaging events on "
                "the flat (M, P) plane, but this params tree has leaves "
                "FlatSpec cannot embed in float32 — use the f32 wire "
                "for such trees")

    def _mix_topology(self) -> Topology | None:
        """The topology whose events need the generic ``W @ plane``
        mix, or None when events lower to the existing fused mean /
        group-mean paths (no topology, ``full``, or ``groups`` — the
        block-diagonal W is exactly the ``inner_groups`` block mean)."""
        t = self.topology
        if t is None or t.kind not in MIX_KINDS:
            return None
        return t

    def _all_groups(self) -> int:
        """Group count of an "all"-scope mean event: 1 (global mean)
        unless the ``groups`` topology narrows it to its block mean."""
        t = self.topology
        if t is not None and t.kind == "groups":
            return t.groups
        return 1

    def _event_W(self, step, dec_key):
        """This event's mixing matrix (f32 (M, M)), or None when events
        take the mean path. Deterministic topologies embed W as a trace
        constant; ``gossip_pairs`` samples the per-event matching from
        ``fold_in`` on (dec_key, step) — the same pure-function recipe
        as the stochastic schedule, so every engine path, phase
        blocking, shard and checkpoint/resume replays identical
        matchings."""
        t = self._mix_topology()
        if t is None:
            return None
        return t.mixing_matrix(step, dec_key)

    def init(self, params, num_workers: int, seed: int = 0) -> EngineState:
        self._check_workers(num_workers)
        wp = replicate(params, num_workers)
        self._check_compressible(wp)
        opt_state = jax.vmap(self.optimizer.init)(wp)
        outer_state = ()
        if self.outer is not None:
            avg = consensus(wp)
            outer_state = (avg, self.outer.init(avg))
        resid = ()
        if self._comp() is not None:
            resid = jnp.zeros((num_workers, FlatSpec.of(wp).width),
                              jnp.float32)
        fault = ()
        if self._faults() is not None:
            fault = faults_mod.init_fault_state(num_workers)
        key, dec_key = jax.random.split(jax.random.PRNGKey(seed))
        return EngineState(wp, opt_state, outer_state, key, dec_key,
                           jnp.zeros((), jnp.int32),
                           self.schedule.init_sched_state(), resid, fault)

    def _sched_event_cost(self, p: int, num_workers: int):
        """The per-event bytes-per-worker cost the ``adaptive_bytes``
        schedule spends its budget in: comm_degree messages of one
        (P,) row at the wire precision. None for every other kind."""
        if self.schedule.kind != "adaptive_bytes":
            return None
        topo = self.topology or Topology.full(num_workers)
        wire = self.compression.wire if self.compression else "f32"
        return float(comm_bytes(topo, 1, p, wire))

    def _event_bytes(self, p: int, num_workers: int):
        """Telemetry pricing of one averaging event: (all-scope, inner)
        nominal wire bytes ONE worker ships — the same
        ``topology.comm_bytes`` currency the ``adaptive_bytes`` budget
        spends; inner (group-mean) events ship within-group traffic."""
        from repro.core.compress import wire_row_bytes
        topo = self.topology or Topology.full(num_workers)
        wire = self.compression.wire if self.compression else "f32"
        eb_all = float(comm_bytes(topo, 1, p, wire))
        g = max(self.schedule.inner_groups, 1)
        eb_inner = float(
            max(num_workers // g - 1, 0) * wire_row_bytes(p, wire))
        return eb_all, eb_inner

    def _tele_occupancy(self, fp, step, dec_key, num_workers: int):
        """Per-step (n_alive, n_straggle) for the metrics accumulator —
        pure full-plane functions of the scripted fault streams, so
        every path and every shard computes the identical scalars with
        no extra collective (constants without a fault plan)."""
        if fp is None:
            return jnp.float32(num_workers), jnp.float32(0.0)
        a_full = fp.alive_at(step)
        s_full = fp.straggle_mask(
            dec_key, step, jnp.arange(fp.num_workers, dtype=jnp.int32))
        return jnp.sum(a_full), jnp.sum(a_full * s_full)

    # ---- fused flat averaging -------------------------------------------
    def _use_pallas(self) -> bool:
        if self.kernel_impl == "pallas":
            return True
        if self.kernel_impl == "ref":
            return False
        return jax.default_backend() != "cpu"

    def _flat_average(self, plane, outer_c, scope: str, W=None,
                      alive=None):
        """ONE fused pass over the (M, P) plane: mean (global or
        per-group), Eq. 4 dispersion, broadcast, and — for the all-scope
        with an outer optimizer — the outer momentum step. With a
        mixing topology the all-scope event is the fused
        ``W @ plane`` gossip mix instead (no broadcast). ``alive``
        ((M,) f32, fault mode) masks every variant over the alive
        rows; the outer optimizer is excluded under faults."""
        pallas = self._use_pallas()
        if scope == "inner":
            groups = max(self.schedule.inner_groups, 1)
            if pallas:
                plane, disp = avg_disp(plane, groups=groups, alive=alive)
            else:
                plane, disp = avg_disp_ref(plane, groups=groups,
                                           alive=alive)
            return plane, outer_c, disp
        if W is not None:
            mix = mix_disp if pallas else mix_disp_ref
            plane, disp = mix(plane, W, alive=alive)
            return plane, outer_c, disp
        if self.outer is not None and outer_c != ():
            prev, vel = outer_c
            fused = avg_disp_outer if pallas else avg_disp_outer_ref
            plane, prev, vel, disp = fused(
                plane, prev, vel, lr=self.outer.lr,
                momentum=self.outer.momentum, nesterov=self.outer.nesterov)
            return plane, (prev, vel), disp
        groups = self._all_groups()
        if pallas:
            plane, disp = avg_disp(plane, groups=groups, alive=alive)
        else:
            plane, disp = avg_disp_ref(plane, groups=groups, alive=alive)
        return plane, outer_c, disp

    # ---- flat-native fused step (+ averaging) ---------------------------
    def _opt_spec(self, spec: FlatSpec, opt_state) -> FlatOptSpec | None:
        """The FlatOptSpec for flat-native scans, or None when the
        optimizer or its state can't ride the plane."""
        if not self.fused_opt or getattr(self.optimizer, "plane_kind",
                                         None) is None:
            return None
        return FlatOptSpec.of(spec, opt_state)

    def _event_uniforms(self, spec, m, step, dec_key, row0=None):
        """The int8 stochastic-rounding uniforms for this event's rows
        (global rows ``row0..row0+m``; ``row0=0`` unsharded), or None
        for the deterministic formats."""
        comp = self._comp()
        if comp is None or not comp.stochastic:
            return None
        rows = jnp.arange(m, dtype=jnp.int32)
        if row0 is not None:
            rows = row0 + rows
        return row_uniforms(dec_key, step, rows, spec.width)

    def _compressed_plane_event(self, spec, plane, resid, scope: str,
                                step, dec_key, W=None, alive=None):
        """One compressed averaging/mixing event on the (M, P) plane:
        error-feedback encode of the post-update plane, the event
        operator (mean / group mean / ``W @``) on the decoded ``q``,
        residual update — fused (``kernels.avg_disp.compressed_mix``)
        on accelerators, the jnp twins on CPU. ``alive`` masks the
        event over the alive rows (dead rows ship no bytes and keep
        their stale residual). Returns (plane, residual, dispersion)."""
        comp = self._comp()
        codes = spec.rounding_codes()
        u = self._event_uniforms(spec, plane.shape[0], step, dec_key)
        kw = dict(wire=comp.wire, u=u, codes=codes,
                  error_feedback=comp.error_feedback, alive=alive)
        groups = (max(self.schedule.inner_groups, 1) if scope == "inner"
                  else self._all_groups())
        if self._use_pallas():
            return compressed_mix(
                plane, resid, mode=("mix" if W is not None else
                                    "group" if groups > 1 else "mean"),
                groups=groups, W=W, **kw)
        if W is not None:
            return compressed_mix_ref(plane, resid, W, **kw)
        return compressed_avg_ref(plane, resid, groups=groups, **kw)

    def _fused_step_average(self, spec, plane, gplane, planes, outer_c,
                            scalars, scope: str, W=None, resid=(),
                            step=None, dec_key=None, alive=None,
                            umask=None):
        """ONE fused pass: local optimizer update on the plane (+ state
        planes) and, per ``scope``, the averaging event — mean (global
        or per-group), Eq. 4 dispersion, broadcast, or (with a mixing
        topology) the ``W @ plane`` gossip mix. The all-scope with an
        outer optimizer chains the fused update into the fused
        avg+outer-momentum kernel (two passes total on those rare
        steps). With active compression the event acts on the encoded
        ``q`` of the post-update plane and the error-feedback
        ``resid`` plane updates in the same pass. Returns
        (plane, planes, outer_c, resid, disp)."""
        codes = spec.rounding_codes()
        kw = dict(kind=self.optimizer.plane_kind, codes=codes,
                  **self.optimizer.plane_hypers())
        if alive is not None:
            kw.update(alive=alive, umask=umask)
        fused = opt_step if self._use_pallas() else opt_step_ref
        comp = self._comp()
        if comp is not None and scope != "none":
            u = self._event_uniforms(spec, plane.shape[0], step, dec_key)
            groups = self._all_groups()
            mode = ("mix" if W is not None
                    else "group" if groups > 1 else "mean")
            plane, planes, resid, disp = fused(
                plane, gplane, planes, scalars, mode=mode, W=W,
                groups=groups, wire=comp.wire, resid=resid, u=u,
                error_feedback=comp.error_feedback, **kw)
            return plane, planes, outer_c, resid, disp
        if scope == "none":
            plane, planes, disp = fused(plane, gplane, planes, scalars,
                                        mode="none", **kw)
            return plane, planes, outer_c, resid, disp
        if W is not None:
            plane, planes, disp = fused(plane, gplane, planes, scalars,
                                        mode="mix", W=W, **kw)
            return plane, planes, outer_c, resid, disp
        if self.outer is not None and outer_c != ():
            plane, planes, _ = fused(plane, gplane, planes, scalars,
                                     mode="none", **kw)
            prev, vel = outer_c
            # mixed-dtype trees need the ref twin: the Pallas outer
            # kernel has no rounding-codes path
            if codes is None and self._use_pallas():
                of = avg_disp_outer
            else:
                of = partial(avg_disp_outer_ref, codes=codes)
            plane, prev, vel, disp = of(
                plane, prev, vel, lr=self.outer.lr,
                momentum=self.outer.momentum, nesterov=self.outer.nesterov)
            return plane, planes, (prev, vel), resid, disp
        groups = self._all_groups()
        plane, planes, disp = fused(plane, gplane, planes, scalars,
                                    mode="group" if groups > 1 else "mean",
                                    groups=groups, **kw)
        return plane, planes, outer_c, resid, disp

    def _plane_avg_event(self, spec, plane, outer_c, scope: str, W=None,
                         alive=None):
        """Averaging event alone (no optimizer update) on the plane —
        used by the switch branches of rare-averaging schedules, where
        the update is hoisted before the switch so XLA can fuse it with
        the gradient computation. Mixed-dtype trees round the broadcast
        mean / mixed rows (and the outer-optimizer's gradient target
        and update) through the leaf dtypes (``rounding_codes``),
        matching the tree operators' ``.astype``. ``alive`` masks the
        event over the alive rows (fault mode)."""
        codes = spec.rounding_codes()
        if codes is None:
            return self._flat_average(plane, outer_c, scope, W=W,
                                      alive=alive)
        if scope == "all" and W is not None:
            plane, disp = mix_disp_ref(plane, W, codes=codes, alive=alive)
            return plane, outer_c, disp
        if scope == "all" and self.outer is not None and outer_c != ():
            prev, vel = outer_c
            plane, prev, vel, disp = avg_disp_outer_ref(
                plane, prev, vel, lr=self.outer.lr,
                momentum=self.outer.momentum,
                nesterov=self.outer.nesterov, codes=codes)
            return plane, (prev, vel), disp
        groups = (max(self.schedule.inner_groups, 1)
                  if scope == "inner" else self._all_groups())
        plane, disp = plane_average_ref(plane, groups=groups, codes=codes,
                                        alive=alive)
        return plane, outer_c, disp

    def _flat_native_step(self, spec, plane, gplane, planes, outer_c,
                          scalars, step, sst, dec_key, resid=(),
                          fmask=None, dscale=None):
        """One flat-native step: fused update(+average) for the
        every-step schedules, update-then-switched-average for the rare
        ones. The fused update always emits the Eq. 4 dispersion of the
        post-update plane, which feeds the stateful schedule decision
        (``AveragingSchedule.decision_state``) and the per-step trace.
        With active compression the error-feedback ``resid`` plane
        threads through the event (untouched on non-event steps).
        ``fmask`` (fault mode) is the ``(mix, umask)`` pair for this
        step: rows outside ``umask`` skip the update, events and the
        dispersion mask over the mixing cohort ``mix`` (alive rows not
        inside a solo window). ``dscale`` is the straggle-aware
        dispersion discount forwarded to the schedule decision. Returns
        (plane, state planes, outer_c, resid, sched state, dispersion,
        decision code)."""
        sched = self.schedule
        alive, umask = fmask if fmask is not None else (None, None)
        ec = self._sched_event_cost(spec.width, plane.shape[0])
        if sched.kind == "minibatch":
            # the all-average is unconditional — fuse it into the update
            # pass; the (static) decision still advances the sched state
            plane, planes, outer_c, resid, disp = self._fused_step_average(
                spec, plane, gplane, planes, outer_c, scalars, "all",
                W=self._event_W(step, dec_key), resid=resid, step=step,
                dec_key=dec_key, alive=alive, umask=umask)
            code, sst = sched.decision_state(step, sst, disp, dec_key,
                                             event_cost=ec,
                                             disp_scale=dscale)
            return plane, planes, outer_c, resid, sst, disp, code
        plane, planes, outer_c, resid, disp = self._fused_step_average(
            spec, plane, gplane, planes, outer_c, scalars, "none",
            resid=resid, alive=alive, umask=umask)
        code, sst = sched.decision_state(step, sst, disp, dec_key,
                                         event_cost=ec,
                                         disp_scale=dscale)
        if sched.kind == "oneshot":
            return plane, planes, outer_c, resid, sst, disp, code
        comp = self._comp()

        def none_branch(args):
            return args[0], args[1], args[2]

        def inner_branch(args):
            if comp is not None:
                pl_, r_, _ = self._compressed_plane_event(
                    spec, args[0], args[2], "inner", step, dec_key,
                    alive=alive)
                return pl_, args[1], r_
            return self._plane_avg_event(spec, args[0], args[1],
                                         "inner",
                                         alive=alive)[:2] + (args[2],)

        def all_branch(args):
            W = self._event_W(step, dec_key)
            if comp is not None:
                pl_, r_, _ = self._compressed_plane_event(
                    spec, args[0], args[2], "all", step, dec_key, W=W,
                    alive=alive)
                return pl_, args[1], r_
            return self._plane_avg_event(spec, args[0], args[1], "all",
                                         W=W,
                                         alive=alive)[:2] + (args[2],)

        plane, outer_c, resid = jax.lax.switch(
            code, [none_branch, inner_branch, all_branch],
            (plane, outer_c, resid))
        return plane, planes, outer_c, resid, sst, disp, code

    # ---- tree-path averaging (flat=False, and FlatSpec fallback) ---------
    def _apply_all_average(self, wp, outer_state, num_workers):
        avg = consensus(wp)
        if self.outer is not None:
            prev_avg, vel = outer_state
            avg, vel = self.outer.apply(prev_avg, avg, vel)
            outer_state = (avg, vel)
        return replicate(avg, num_workers), outer_state

    def _tree_average(self, wp, outer_c, scope: str, num_workers: int,
                      W=None, alive=None):
        if alive is not None:
            disp = faults_mod.masked_dispersion_tree(
                wp, alive).astype(jnp.float32)
            if scope == "inner":
                wp = faults_mod.masked_average_all_tree(
                    wp, alive, groups=max(self.schedule.inner_groups, 1))
                return wp, outer_c, disp
            if W is not None:
                return faults_mod.masked_mix_tree(wp, W, alive), \
                    outer_c, disp
            g = self._all_groups()
            wp = faults_mod.masked_average_all_tree(wp, alive,
                                                    groups=max(g, 1))
            return wp, outer_c, disp
        disp = worker_dispersion(wp).astype(jnp.float32)
        if scope == "inner":
            return (average_inner(wp, max(self.schedule.inner_groups, 1)),
                    outer_c, disp)
        if W is not None:
            return mix_tree(wp, W), outer_c, disp
        g = self._all_groups()
        if g > 1:
            return average_inner(wp, g), outer_c, disp
        wp, outer_c = self._apply_all_average(wp, outer_c, num_workers)
        return wp, outer_c, disp

    # ---- the compiled phase ---------------------------------------------
    def _phase(self, state: EngineState, xs, fetch):
        """Trace the whole phase: scan the K entries of ``xs``
        (pre-staged batches, or index blocks that ``fetch`` gathers
        on-device), averaging fused per the schedule. Returns the new
        state and per-step traces {loss, dispersion, avg_code} — the only
        host transfer a phase needs.

        Three carries, picked per (flat, optimizer) support:
          flat-native — params AND optimizer state as (M, P) planes,
            grads via one vjp through the unpacked view, every step one
            fused opt_step pass (zero per-step pack/unpack);
          flat        — params plane with per-step pack/unpack around the
            tree-mapped optimizer (optimizers without plane support);
          tree        — params pytree carry (dtypes FlatSpec can't
            embed)."""
        num_workers = jax.tree.leaves(state.worker_params)[0].shape[0]
        self._check_workers(num_workers)
        self._check_compressible(state.worker_params)
        sched = self.schedule
        comp = self._comp()
        use_flat = self.flat and FlatSpec.supports(state.worker_params)
        # compressed events encode on the plane even in the tree carry
        # (pack/unpack around the event only — events are rare)
        spec = (FlatSpec.of(state.worker_params)
                if use_flat or comp is not None else None)
        opt_spec = self._opt_spec(spec, state.opt_state) if use_flat else None
        flat_native = opt_spec is not None
        p_width = (spec.width if spec is not None else
                   sum(x.size // num_workers
                       for x in jax.tree.leaves(state.worker_params)))
        ec = self._sched_event_cost(p_width, num_workers)
        tm = tele_metrics if self.telemetry else None
        eb_all, eb_inner = (self._event_bytes(p_width, num_workers)
                            if tm is not None else (0.0, 0.0))

        if use_flat:
            carry_p = spec.pack(state.worker_params)
            carry_s = (opt_spec.pack(state.opt_state) if flat_native
                       else state.opt_state)
            carry_o = ()
            if self.outer is not None and state.outer_state != ():
                prev_avg, vel = state.outer_state
                carry_o = (spec.pack1(prev_avg), spec.pack1(vel))
            average = self._flat_average
        else:
            carry_p = state.worker_params
            carry_s = state.opt_state
            carry_o = state.outer_state
            average = partial(self._tree_average, num_workers=num_workers)
        grads_fn = (make_plane_step(self.loss_fn, spec) if flat_native
                    else None)
        fp = self._faults()

        def comp_event(wp_c, resid, scope, step, W=None, alive=None):
            # encode -> event -> decode on the plane; tree carries pack
            # around the (rare) event only
            plane = wp_c if use_flat else spec.pack(wp_c)
            plane, resid, _ = self._compressed_plane_event(
                spec, plane, resid, scope, step, state.dec_key, W=W,
                alive=alive)
            return (plane if use_flat else spec.unpack(plane)), resid

        def warm_start(wp_c, opt_c, resid, alive_prev, rejoined):
            # rejoining rows take the current alive average, with
            # optimizer state and error-feedback residual zeroed —
            # static under fp.has_rejoin, so crash-only plans trace
            # nothing extra
            if use_flat:
                glob = faults_mod.masked_mean(wp_c, alive_prev)
                codes = spec.rounding_codes()
                if codes is not None:
                    glob = round_to_codes(glob, codes)
                wp_c = faults_mod.select_rows(
                    jnp.broadcast_to(glob[None], wp_c.shape), wp_c,
                    rejoined)
            else:
                wp_c = faults_mod.warm_start_tree(wp_c, alive_prev,
                                                  rejoined)
            if flat_native:
                opt_c = tuple(faults_mod.zero_rows(s, rejoined)
                              for s in opt_c)
            else:
                opt_c = faults_mod.zero_rows_tree(opt_c, rejoined)
            if comp is not None:
                resid = faults_mod.zero_rows(resid, rejoined)
            return wp_c, opt_c, resid

        def body(carry, xs_t):
            wp_c, opt_c, outer_c, key, step, sst, resid, fst, acc = carry
            step = step + 1
            key, sub = jax.random.split(key)
            rngs = jax.random.split(sub, num_workers)
            batch = fetch(xs_t)
            alive = umask = dscale = None
            if fp is not None:
                alive_prev = fst.alive
                fst, _, alive, umask, rejoined = fp.transition(
                    fst, step, state.dec_key)
                if fp.has_rejoin:
                    # the warm-start consensus is the PREVIOUS step's
                    # mixing cohort: mid-curriculum (solo) rows train
                    # but their unrepresentative iterates stay out of it
                    wp_c, opt_c, resid = warm_start(
                        wp_c, opt_c, resid,
                        fp.mix_at(alive_prev, step - 1), rejoined)
                if sched.straggle_aware:
                    dscale = fp.disp_scale(alive, state.dec_key, step)
            if flat_native:
                losses, _, gplane = grads_fn(wp_c, batch, rngs)
                scal = self.optimizer.plane_scalars(step)
                wp_c, opt_c, outer_c, resid, sst, disp, code = \
                    self._flat_native_step(
                        spec, wp_c, gplane, opt_c, outer_c, scal, step,
                        sst, state.dec_key, resid=resid,
                        fmask=None if fp is None else (alive, umask),
                        dscale=dscale)
            else:
                wp = spec.unpack(wp_c) if use_flat else wp_c
                wp_new, opt_new, losses, _ = self.worker_step(
                    wp, opt_c, batch, step, rngs)
                if fp is not None:
                    # dead/straggling rows keep params AND optimizer
                    # state (zeroed grads would still advance momentum)
                    if use_flat:
                        wp_new_c = spec.pack(wp_new)
                        wp_c = faults_mod.select_rows(wp_new_c, wp_c,
                                                      umask)
                    else:
                        wp_c = faults_mod.select_rows_tree(wp_new, wp,
                                                           umask)
                    opt_c = faults_mod.select_rows_tree(opt_new, opt_c,
                                                        umask)
                else:
                    opt_c = opt_new
                    wp_c = spec.pack(wp_new) if use_flat else wp_new
                # the Eq. 4 dispersion is measured EVERY step (post
                # update, pre average): the stateful decision consumes
                # it and the trace records the true diagnostic on
                # non-averaging steps too
                if fp is not None:
                    disp = (faults_mod.masked_dispersion(wp_c, alive)
                            if use_flat else
                            faults_mod.masked_dispersion_tree(wp_c,
                                                              alive))
                elif use_flat:
                    glob = jnp.mean(wp_c, axis=0)
                    disp = (jnp.sum(jnp.square(wp_c - glob[None]))
                            / num_workers)
                else:
                    disp = worker_dispersion(wp_c)
                code, sst = sched.decision_state(step, sst, disp,
                                                 state.dec_key,
                                                 event_cost=ec,
                                                 disp_scale=dscale)
                if sched.kind == "oneshot":
                    pass
                elif sched.kind == "minibatch":
                    W = self._event_W(step, state.dec_key)
                    if comp is not None:
                        wp_c, resid = comp_event(wp_c, resid, "all",
                                                 step, W=W, alive=alive)
                    else:
                        wp_c, outer_c, _ = average(wp_c, outer_c, "all",
                                                   W=W, alive=alive)
                else:
                    def none_branch(args):
                        return args

                    def inner_branch(args):
                        if comp is not None:
                            pl_, r_ = comp_event(args[0], args[2],
                                                 "inner", step,
                                                 alive=alive)
                            return pl_, args[1], r_
                        return average(args[0], args[1], "inner",
                                       alive=alive)[:2] + (args[2],)

                    def all_branch(args):
                        W = self._event_W(step, state.dec_key)
                        if comp is not None:
                            pl_, r_ = comp_event(args[0], args[2],
                                                 "all", step, W=W,
                                                 alive=alive)
                            return pl_, args[1], r_
                        return average(args[0], args[1], "all",
                                       W=W, alive=alive)[:2] + (args[2],)

                    wp_c, outer_c, resid = jax.lax.switch(
                        code, [none_branch, inner_branch, all_branch],
                        (wp_c, outer_c, resid))
            loss_t = (jnp.mean(losses) if fp is None
                      else jnp.sum(losses * alive) / jnp.sum(alive))
            if tm is not None:
                n_alive, n_straggle = self._tele_occupancy(
                    fp, step, state.dec_key, num_workers)
                acc = tm.accumulate(
                    acc, loss=loss_t, disp=disp, code=code,
                    event_bytes_all=eb_all, event_bytes_inner=eb_inner,
                    n_alive=n_alive, n_straggle=n_straggle)
            return ((wp_c, opt_c, outer_c, key, step, sst, resid, fst,
                     acc),
                    (loss_t, disp.astype(jnp.float32), code))

        sst0 = (state.sched if isinstance(state.sched, SchedState)
                else sched.init_sched_state())
        fst0 = (state.fault if isinstance(state.fault, FaultState)
                else (faults_mod.init_fault_state(num_workers)
                      if fp is not None else ()))
        # the metrics accumulator is reconstructed fresh every phase —
        # never part of EngineState, never checkpointed
        acc0 = tm.init_metrics() if tm is not None else ()
        carry0 = (carry_p, carry_s, carry_o, state.key, state.step, sst0,
                  state.resid, fst0, acc0)
        (wp_c, opt_c, outer_c, key, step, sst, resid, fst, acc), \
            (loss, disp, code) = \
            jax.lax.scan(body, carry0, xs, unroll=self.scan_unroll)

        if use_flat:
            wp = spec.unpack(wp_c)
            opt_state = opt_spec.unpack(opt_c) if flat_native else opt_c
            outer_state = state.outer_state
            if carry_o != ():
                outer_state = (spec.unpack1(outer_c[0]),
                               spec.unpack1(outer_c[1], dtypes=jnp.float32))
        else:
            wp, opt_state, outer_state = wp_c, opt_c, outer_c
        new_state = EngineState(wp, opt_state, outer_state, key,
                                state.dec_key, step, sst, resid, fst)
        trace = {"loss": loss, "dispersion": disp, "avg_code": code}
        if tm is not None:
            trace["metrics"] = acc
        return new_state, trace

    # ---- sharded phase (shard_map over the mesh worker axes) -------------
    def _worker_axes(self) -> tuple:
        from repro.sharding.specs import mesh_worker_axes
        return tuple(self.shard_axes) or mesh_worker_axes(self.mesh)

    def _num_shards(self) -> int:
        n = 1
        for a in self._worker_axes():
            n *= self.mesh.shape[a]
        return n

    def _shard_index(self):
        """Flat index of this shard along the worker axes (row-major)."""
        idx = jnp.zeros((), jnp.int32)
        for a in self._worker_axes():
            idx = idx * self.mesh.shape[a] + jax.lax.axis_index(a)
        return idx

    def _psum_avg_event(self, spec, plane, outer_c, scope: str, glob,
                        ml: int, W=None, alive=None, alive_full=None):
        """Cross-shard averaging event (no optimizer update) on this
        shard's (M_l, P) rows. ``glob`` is the (already psum'd) global
        worker mean — computed once per step OUTSIDE the switch, where
        the always-on dispersion needs it anyway, so the all-scope
        broadcast (and the outer step) is shard-local here. Group
        (inner) averages all_gather the rows instead (group boundaries
        need not align with shard boundaries), and so does a mixing
        topology's ``W @ plane`` event: ONE all_gather of the (M_l, P)
        row shards per event, then this shard's W rows contract the
        full plane — O(M·P) bytes, only on event steps."""
        codes = spec.rounding_codes()
        ax = self._worker_axes()
        if scope == "all" and W is not None:
            if alive is not None:
                W = faults_mod.degraded_matrix(W.astype(jnp.float32),
                                               alive_full)
            full = jax.lax.all_gather(plane, ax, axis=0, tiled=True)
            rows = jax.lax.dynamic_slice_in_dim(
                W, self._shard_index() * ml, ml, 0)
            out = jnp.dot(rows, full, preferred_element_type=jnp.float32)
            if codes is not None:
                out = round_to_codes(out, codes)
            if alive is not None:
                out = faults_mod.select_rows(out, plane, alive)
            return out, outer_c
        if scope == "inner" or (scope == "all" and self._all_groups() > 1):
            groups = (max(self.schedule.inner_groups, 1)
                      if scope == "inner" else self._all_groups())
            full = jax.lax.all_gather(plane, ax, axis=0, tiled=True)
            full, _ = plane_average_ref(full, groups=groups, codes=codes,
                                        alive=alive_full)
            out = jax.lax.dynamic_slice_in_dim(
                full, self._shard_index() * ml, ml, 0)
            return out, outer_c
        if codes is not None:
            glob = round_to_codes(glob, codes)
        if alive is not None:
            # ``glob`` is the alive-masked mean (psum'd by the caller);
            # dead rows keep their last parameters
            return (faults_mod.select_rows(
                jnp.broadcast_to(glob[None], plane.shape), plane, alive),
                outer_c)
        if self.outer is not None and outer_c != ():
            prev, vel = outer_c
            g = prev - glob
            vel = self.outer.momentum * vel + g
            step = (self.outer.momentum * vel + g if self.outer.nesterov
                    else vel)
            upd = prev - self.outer.lr * step
            if codes is not None:
                upd = round_to_codes(upd, codes)
            return jnp.broadcast_to(upd[None], plane.shape), (upd, vel)
        return jnp.broadcast_to(glob[None], plane.shape), outer_c

    def _psum_compressed_event(self, spec, plane, resid, scope: str, step,
                               dec_key, ml: int, m_global: int, W=None,
                               alive=None, alive_full=None):
        """Compressed cross-shard averaging event on this shard's
        (M_l, P) rows. Encoding is row-local (per-row scales, per-row
        fold_in uniforms keyed by the GLOBAL row id ``i0 + arange``), so
        each shard produces exactly the rows a single device would; the
        error-feedback residual update ``v - q`` stays shard-local and
        never crosses the wire. Mean events psum the per-shard sums of
        the ENCODED rows — that psum is the bytes-on-the-wire win the
        wire format buys. Mixing / group events all_gather q instead
        (boundary-crossing contractions need the full encoded plane)."""
        comp = self._comp()
        codes = spec.rounding_codes()
        ax = self._worker_axes()
        rows = self._shard_index() * ml + jnp.arange(ml, dtype=jnp.int32)
        u = (row_uniforms(dec_key, step, rows, spec.width)
             if comp.stochastic else None)
        q, r_new = encode_decode(plane, resid, wire=comp.wire, u=u,
                                 error_feedback=comp.error_feedback)
        resid = (r_new if alive is None
                 else faults_mod.select_rows(r_new, resid, alive))
        if scope == "all" and W is not None:
            if alive is not None:
                W = faults_mod.degraded_matrix(W.astype(jnp.float32),
                                               alive_full)
            full = jax.lax.all_gather(q, ax, axis=0, tiled=True)
            wrows = jax.lax.dynamic_slice_in_dim(
                W, self._shard_index() * ml, ml, 0)
            out = jnp.dot(wrows, full, preferred_element_type=jnp.float32)
        elif scope == "inner" or (scope == "all"
                                  and self._all_groups() > 1):
            groups = (max(self.schedule.inner_groups, 1)
                      if scope == "inner" else self._all_groups())
            full = jax.lax.all_gather(q, ax, axis=0, tiled=True)
            if alive is not None:
                full = faults_mod.masked_group_mean(full, alive_full,
                                                    groups)
            else:
                g = jnp.mean(
                    full.reshape(groups, m_global // groups, -1), axis=1)
                full = jnp.repeat(g, m_global // groups, axis=0)
            out = jax.lax.dynamic_slice_in_dim(
                full, self._shard_index() * ml, ml, 0)
        else:
            if alive is not None:
                glob = (jax.lax.psum(
                    jnp.sum(q * alive[:, None], axis=0), ax)
                    / jax.lax.psum(jnp.sum(alive), ax))
            else:
                glob = jax.lax.psum(jnp.sum(q, axis=0), ax) / m_global
            out = jnp.broadcast_to(glob[None], plane.shape)
        if codes is not None:
            out = round_to_codes(out, codes)
        if alive is not None:
            out = faults_mod.select_rows(out, plane, alive)
        return out, resid

    def _flat_native_step_psum(self, spec, plane, gplane, planes, outer_c,
                               scalars, step, sst, dec_key,
                               m_global: int, ml: int, resid=(),
                               fmask=None, dscale=None):
        """psum-mode flat-native step: shard-local plane update (hoisted
        before the switch), then the always-on Eq. 4 dispersion — ONE
        psum of the per-shard column sums gives the global mean, one
        more psums the per-shard squared-distance sums — feeding the
        stateful schedule decision, then the cross-shard averaging
        event per the decision code. Returns (plane, state planes,
        outer_c, resid, sched state, dispersion, code)."""
        sched = self.schedule
        comp = self._comp()
        ax = self._worker_axes()
        alive_full, alive, umask = (fmask if fmask is not None
                                    else (None, None, None))
        upd, new_planes = plane_update_ref(
            plane, gplane, planes, scalars, kind=self.optimizer.plane_kind,
            codes=spec.rounding_codes(), **self.optimizer.plane_hypers())
        if fmask is None:
            plane, planes = upd, new_planes
            glob = jax.lax.psum(jnp.sum(plane, axis=0), ax) / m_global
            disp = jax.lax.psum(
                jnp.sum(jnp.square(plane - glob[None])), ax) / m_global
        else:
            # dead / straggling rows keep params AND state planes
            plane = faults_mod.select_rows(upd, plane, umask)
            planes = tuple(faults_mod.select_rows(n, o, umask)
                           for n, o in zip(new_planes, planes))
            n_alive = jax.lax.psum(jnp.sum(alive), ax)
            glob = jax.lax.psum(
                jnp.sum(plane * alive[:, None], axis=0), ax) / n_alive
            disp = jax.lax.psum(
                jnp.sum(jnp.square(plane - glob[None]) * alive[:, None]),
                ax) / n_alive
        ec = self._sched_event_cost(spec.width, m_global)
        code, sst = sched.decision_state(step, sst, disp, dec_key,
                                         event_cost=ec,
                                         disp_scale=dscale)
        if sched.kind == "oneshot":
            return plane, planes, outer_c, resid, sst, disp, code
        if sched.kind == "minibatch":
            W = self._event_W(step, dec_key)
            if comp is not None:
                plane, resid = self._psum_compressed_event(
                    spec, plane, resid, "all", step, dec_key, ml,
                    m_global, W=W, alive=alive, alive_full=alive_full)
            else:
                plane, outer_c = self._psum_avg_event(
                    spec, plane, outer_c, "all", glob, ml, W=W,
                    alive=alive, alive_full=alive_full)
            return plane, planes, outer_c, resid, sst, disp, code

        def none_branch(args):
            return args

        def inner_branch(args):
            if comp is not None:
                pl_, r_ = self._psum_compressed_event(
                    spec, args[0], args[2], "inner", step, dec_key, ml,
                    m_global, alive=alive, alive_full=alive_full)
                return pl_, args[1], r_
            return self._psum_avg_event(
                spec, args[0], args[1], "inner", glob, ml,
                alive=alive, alive_full=alive_full) + (args[2],)

        def all_branch(args):
            W = self._event_W(step, dec_key)
            if comp is not None:
                pl_, r_ = self._psum_compressed_event(
                    spec, args[0], args[2], "all", step, dec_key, ml,
                    m_global, W=W, alive=alive, alive_full=alive_full)
                return pl_, args[1], r_
            return self._psum_avg_event(
                spec, args[0], args[1], "all", glob, ml, W=W,
                alive=alive, alive_full=alive_full) + (args[2],)

        plane, outer_c, resid = jax.lax.switch(
            code, [none_branch, inner_branch, all_branch],
            (plane, outer_c, resid))
        return plane, planes, outer_c, resid, sst, disp, code

    def _phase_sharded(self, state: EngineState, xs, fetch, m_global: int):
        """The phase body as run on ONE shard under shard_map.

        ``collective="psum"`` (production): the local (M_l, P) slice of
        the plane scans through K fused local steps; averaging events
        are the only cross-shard communication (one psum of column
        sums). Local shapes differ from the unsharded engine's, so XLA
        may vectorize per-worker reductions differently — results agree
        to f32 roundoff, not bitwise.

        ``collective="gather"`` (validation): every step all_gathers the
        plane rows, state planes and batch, runs the unsharded fused
        step on the full worker set, and keeps this shard's row slice —
        full-shape compute on identical values, so the run reproduces
        the single-device engine bit-for-bit for the paper's SGD /
        Momentum recipes (mul-add update math; validated across all 5
        schedules in tests/test_sharded.py). AdamW's div/sqrt and deep
        matmul losses may still differ in final ulps (XLA fuses them
        differently inside the shard_map context) — those agree to f32
        roundoff. The price: redundant compute and O(M·P) gather bytes
        per step; use gather to validate a mesh, psum to scale."""
        sched = self.schedule
        self._check_workers(m_global)
        assert self.flat and FlatSpec.supports(state.worker_params), \
            "sharded runs require the flat (M, P) plane carry"
        assert self.collective in ("psum", "gather"), self.collective
        spec = FlatSpec.of(state.worker_params)
        opt_spec = self._opt_spec(spec, state.opt_state)
        assert opt_spec is not None, \
            "sharded runs need a plane-protocol optimizer (SGD/Momentum/" \
            "AdamW) and fused_opt=True"
        self._check_compressible(state.worker_params)
        comp = self._comp()
        ml = jax.tree.leaves(state.worker_params)[0].shape[0]
        carry_p = spec.pack(state.worker_params)
        carry_s = opt_spec.pack(state.opt_state)
        carry_o = ()
        if self.outer is not None and state.outer_state != ():
            prev_avg, vel = state.outer_state
            carry_o = (spec.pack1(prev_avg), spec.pack1(vel))
        grads_fn = make_plane_step(self.loss_fn, spec)
        ax = self._worker_axes()
        i0 = self._shard_index() * ml
        exact = self.collective == "gather"
        fp = self._faults()
        tm = tele_metrics if self.telemetry else None
        eb_all, eb_inner = (self._event_bytes(spec.width, m_global)
                            if tm is not None else (0.0, 0.0))

        def body(carry, xs_t):
            wp_c, opt_c, outer_c, key, step, sst, resid, fst, acc = carry
            step = step + 1
            key, sub = jax.random.split(key)
            rngs = jax.random.split(sub, m_global)
            batch = fetch(xs_t)
            scal = self.optimizer.plane_scalars(step)
            if exact:
                wp_full = jax.lax.all_gather(wp_c, ax, axis=0, tiled=True)
                opt_full = tuple(
                    jax.lax.all_gather(s, ax, axis=0, tiled=True)
                    for s in opt_c)
                batch = jax.tree.map(
                    lambda b: jax.lax.all_gather(b, ax, axis=0, tiled=True),
                    batch)
                resid_full = (jax.lax.all_gather(resid, ax, axis=0,
                                                 tiled=True)
                              if comp is not None else resid)
                fmask = None
                dscale = None
                if fp is not None:
                    # fault rows gather like resid: the transition and
                    # warm start run on the FULL worker set, so the step
                    # reproduces the single-device fault stream bitwise
                    fst_full = FaultState(
                        jax.lax.all_gather(fst.alive, ax, axis=0,
                                           tiled=True),
                        jax.lax.all_gather(fst.staleness, ax, axis=0,
                                           tiled=True))
                    alive_prev = fst_full.alive
                    fst_full, _, alive_f, umask_f, rejoined_f = \
                        fp.transition(fst_full, step, state.dec_key)
                    if fp.has_rejoin:
                        glob_p = faults_mod.masked_mean(
                            wp_full, fp.mix_at(alive_prev, step - 1))
                        codes = spec.rounding_codes()
                        if codes is not None:
                            glob_p = round_to_codes(glob_p, codes)
                        wp_full = faults_mod.select_rows(
                            jnp.broadcast_to(glob_p[None], wp_full.shape),
                            wp_full, rejoined_f)
                        opt_full = tuple(
                            faults_mod.zero_rows(s, rejoined_f)
                            for s in opt_full)
                        if comp is not None:
                            resid_full = faults_mod.zero_rows(
                                resid_full, rejoined_f)
                    fst = FaultState(
                        jax.lax.dynamic_slice_in_dim(
                            fst_full.alive, i0, ml, 0),
                        jax.lax.dynamic_slice_in_dim(
                            fst_full.staleness, i0, ml, 0))
                    fmask = (alive_f, umask_f)
                    if sched.straggle_aware:
                        dscale = fp.disp_scale(alive_f, state.dec_key,
                                               step)
                losses, _, gplane = grads_fn(wp_full, batch, rngs)
                wp_full, opt_full, outer_c, resid_full, sst, disp, code = \
                    self._flat_native_step(spec, wp_full, gplane, opt_full,
                                           outer_c, scal, step, sst,
                                           state.dec_key, resid=resid_full,
                                           fmask=fmask, dscale=dscale)
                loss_t = (jnp.mean(losses) if fp is None else
                          jnp.sum(losses * alive_f) / jnp.sum(alive_f))
                wp_c = jax.lax.dynamic_slice_in_dim(wp_full, i0, ml, 0)
                opt_c = tuple(
                    jax.lax.dynamic_slice_in_dim(s, i0, ml, 0)
                    for s in opt_full)
                if comp is not None:
                    resid = jax.lax.dynamic_slice_in_dim(
                        resid_full, i0, ml, 0)
            else:
                fmask = None
                dscale = None
                if fp is not None:
                    alive_prev = fst.alive
                    fst, alive_fl, alive_l, umask_l, rejoined_l = \
                        fp.transition(fst, step, state.dec_key,
                                      row0=i0, num_rows=ml)
                    if fp.has_rejoin:
                        aprev = fp.mix_at(alive_prev, step - 1,
                                          row0=i0, num_rows=ml)
                        glob_p = (jax.lax.psum(jnp.sum(
                            wp_c * aprev[:, None], axis=0), ax)
                            / jax.lax.psum(jnp.sum(aprev), ax))
                        codes = spec.rounding_codes()
                        if codes is not None:
                            glob_p = round_to_codes(glob_p, codes)
                        wp_c = faults_mod.select_rows(
                            jnp.broadcast_to(glob_p[None], wp_c.shape),
                            wp_c, rejoined_l)
                        opt_c = tuple(faults_mod.zero_rows(s, rejoined_l)
                                      for s in opt_c)
                        if comp is not None:
                            resid = faults_mod.zero_rows(resid, rejoined_l)
                    fmask = (alive_fl, alive_l, umask_l)
                    if sched.straggle_aware:
                        dscale = fp.disp_scale(alive_fl, state.dec_key,
                                               step)
                rngs = jax.lax.dynamic_slice_in_dim(rngs, i0, ml, 0)
                losses, _, gplane = grads_fn(wp_c, batch, rngs)
                wp_c, opt_c, outer_c, resid, sst, disp, code = \
                    self._flat_native_step_psum(spec, wp_c, gplane, opt_c,
                                                outer_c, scal, step, sst,
                                                state.dec_key, m_global,
                                                ml, resid=resid,
                                                fmask=fmask, dscale=dscale)
                loss_t = (jax.lax.psum(jnp.sum(losses), ax) / m_global
                          if fp is None else
                          jax.lax.psum(jnp.sum(losses * alive_l), ax)
                          / jax.lax.psum(jnp.sum(alive_l), ax))
            if tm is not None:
                # loss_t / disp / code are already GLOBAL in both
                # collectives, and the fault occupancy comes from pure
                # full-plane streams — each shard accumulates the
                # identical vector, no extra collective
                n_alive, n_straggle = self._tele_occupancy(
                    fp, step, state.dec_key, m_global)
                acc = tm.accumulate(
                    acc, loss=loss_t, disp=disp, code=code,
                    event_bytes_all=eb_all, event_bytes_inner=eb_inner,
                    n_alive=n_alive, n_straggle=n_straggle)
            return ((wp_c, opt_c, outer_c, key, step, sst, resid, fst,
                     acc),
                    (loss_t, disp.astype(jnp.float32), code))

        sst0 = (state.sched if isinstance(state.sched, SchedState)
                else sched.init_sched_state())
        fst0 = (state.fault if isinstance(state.fault, FaultState)
                else (faults_mod.init_fault_state(ml)
                      if fp is not None else ()))
        acc0 = tm.init_metrics() if tm is not None else ()
        carry0 = (carry_p, carry_s, carry_o, state.key, state.step, sst0,
                  state.resid, fst0, acc0)
        (wp_c, opt_c, outer_c, key, step, sst, resid, fst, acc), \
            (loss, disp, code) = \
            jax.lax.scan(body, carry0, xs, unroll=self.scan_unroll)

        wp = spec.unpack(wp_c)
        opt_state = opt_spec.unpack(opt_c)
        outer_state = state.outer_state
        if carry_o != ():
            outer_state = (spec.unpack1(outer_c[0]),
                           spec.unpack1(outer_c[1], dtypes=jnp.float32))
        new_state = EngineState(wp, opt_state, outer_state, key,
                                state.dec_key, step, sst, resid, fst)
        trace = {"loss": loss, "dispersion": disp, "avg_code": code}
        if tm is not None:
            trace["metrics"] = acc
        return new_state, trace

    def _state_specs(self, state: EngineState):
        ax = P(self._worker_axes())
        return EngineState(
            jax.tree.map(lambda _: ax, state.worker_params),
            jax.tree.map(lambda _: ax, state.opt_state),
            jax.tree.map(lambda _: P(), state.outer_state),
            P(), P(), P(),
            jax.tree.map(lambda _: P(), state.sched),
            jax.tree.map(lambda _: ax, state.resid),
            jax.tree.map(lambda _: ax, state.fault))

    def _trace_specs(self):
        specs = {"loss": P(), "dispersion": P(), "avg_code": P()}
        if self.telemetry:
            # identical on every shard (global inputs, pure streams):
            # replicated out spec, same as the loss/dispersion traces
            specs["metrics"] = P()
        return specs

    def shard_state(self, state: EngineState) -> EngineState:
        """Place an EngineState onto the mesh: worker-axis leaves split
        over the worker axes (``repro.sharding.specs.plane_sharding``
        layout), the rest replicated."""
        from repro.sharding.specs import engine_state_sharding
        return jax.device_put(
            state, engine_state_sharding(self.mesh, state,
                                         axes=self._worker_axes()))

    @partial(jax.jit, static_argnums=0, donate_argnums=1)
    def run_phase(self, state: EngineState, batches):
        """One compiled dispatch over a pre-staged (K, M, ...) batch
        block."""
        if self.mesh is None:
            return self._phase(state, batches, lambda b: b)
        m = jax.tree.leaves(state.worker_params)[0].shape[0]
        assert m % self._num_shards() == 0, (m, self._num_shards())
        sspec = self._state_specs(state)
        ax = self._worker_axes()
        return shard_map(
            lambda s, xs: self._phase_sharded(s, xs, lambda b: b, m),
            mesh=self.mesh,
            in_specs=(sspec, jax.tree.map(lambda _: P(None, ax), batches)),
            out_specs=(sspec, self._trace_specs()),
            check_rep=False)(state, batches)

    @partial(jax.jit, static_argnums=0, donate_argnums=1)
    def run_phase_indexed(self, state: EngineState, dataset, idx_block):
        """One compiled dispatch over a (K, M, B) int32 index block:
        batches are gathered from the device-resident ``dataset``
        INSIDE the scan (``jnp.take``), so the host ships only
        indices."""
        def fetch_from(ds):
            return lambda idx: jax.tree.map(
                lambda a: jnp.take(a, idx, axis=0), ds)
        if self.mesh is None:
            return self._phase(state, idx_block, fetch_from(dataset))
        m = jax.tree.leaves(state.worker_params)[0].shape[0]
        assert m % self._num_shards() == 0, (m, self._num_shards())
        sspec = self._state_specs(state)
        ax = self._worker_axes()
        return shard_map(
            lambda s, ds, idx: self._phase_sharded(
                s, idx, fetch_from(ds), m),
            mesh=self.mesh,
            in_specs=(sspec, jax.tree.map(lambda _: P(), dataset),
                      jax.tree.map(lambda _: P(None, ax), idx_block)),
            out_specs=(sspec, self._trace_specs()),
            check_rep=False)(state, dataset, idx_block)

    def default_phase_len(self) -> int:
        """Compile-size heuristic: align phase blocks with the schedule's
        natural period (correctness never depends on the block size —
        decisions are per-step, on-device)."""
        s = self.schedule
        if s.kind == "periodic":
            return max(1, min(s.phase_len, 512))
        if s.kind == "hierarchical":
            return max(1, min(s.inner_phase_len, 512))
        if s.kind == "stochastic":
            return int(min(max(1.0 / max(s.zeta, 1e-12), 8), 128))
        if s.kind == "adaptive_budget":
            return int(min(max(s.budget_horizon / max(s.comm_budget, 1), 8),
                           128))
        # oneshot / minibatch / adaptive_threshold: any block size
        return 64

    # ---- drivers ---------------------------------------------------------
    def run(self, params, data, *, num_workers: int, seed: int = 0,
            record_every: int = 0, eval_fn=None, worker_eval_fn=None,
            phase_len: int | None = None, steps: int | None = None,
            prefetch: bool = True, state: EngineState | None = None,
            return_state: bool = False, sink=None):
        """Production driver: one run_phase dispatch per block of steps.

        data: an iterable of per-step worker batches (leading axis M) —
        staged to device by a background :class:`Prefetcher` thread
        (``prefetch=False`` stages synchronously; in-memory list/tuple
        sources skip the prefetch thread automatically, and a
        :class:`DeviceDataset` always takes the indexed on-device path,
        so only true streams ever pay for staging) — or a
        :class:`DeviceDataset`, in which case batches are gathered
        on-device from index blocks and ``steps`` bounds the run (it
        defaults to the dataset's precomputed index list, if any).
        eval_fn(consensus_params) / worker_eval_fn(worker_params) run on
        host every ``record_every`` steps (phase blocks are cut so record
        boundaries coincide with phase ends). Returns (final averaged
        params, history dict).

        The history records ``loss`` and ``disp_trace`` — the true
        per-step Eq. 4 dispersion, measured after the local update and
        before any averaging — every ``record_every`` steps, and
        ``dispersion`` (the same pre-average diagnostic) at every
        averaging event, plus the event count ``averages``.

        ``return_state`` appends the final :class:`EngineState` to the
        return tuple (for ``repro.checkpoint.save_engine_state``).
        ``state`` resumes a checkpointed :class:`EngineState`
        (``repro.checkpoint.load_engine_state``) instead of initializing
        from ``params``: step numbering, PRNG streams and averaging
        decisions continue exactly where the checkpoint stopped, and
        ``steps`` counts steps to run in THIS call. The returned history
        covers only this call.

        ``sink`` (a :class:`repro.telemetry.events.TelemetrySink`;
        requires ``PhaseEngine(telemetry=True)``) receives one
        ``phase_metrics`` record per compiled dispatch — flushed from
        the on-device accumulator that rode this phase's scan, on the
        SAME once-per-phase host fetch as the traces — plus an
        ``averaging_event`` per event step and a ``fault_event`` per
        scripted crash/rejoin the phase covered.
        """
        self._check_workers(num_workers)
        if sink is not None and not self.telemetry:
            raise ValueError(
                "run(sink=...) flushes the on-device metrics "
                "accumulator, which this engine does not carry — "
                "construct it with PhaseEngine(..., telemetry=True)")
        if state is None:
            state = self.init(params, num_workers, seed)
        if self.mesh is not None:
            state = self.shard_state(state)
        t0 = int(state.step)
        block = phase_len or self.default_phase_len()
        needs_eval = bool(record_every and (eval_fn or worker_eval_fn))
        hist = init_history()
        total = None if steps is None else t0 + steps

        def take_at(t):
            take = block
            if needs_eval:
                take = min(take, record_every - t % record_every)
            if total is not None:
                take = min(take, total - t)
            return take

        def unshard(tree):
            # a mesh-sharded worker axis is reassembled on the default
            # device so reductions over it (consensus) lower exactly
            # like the single-device engine's
            if self.mesh is None:
                return tree
            return jax.tree.map(lambda x: jnp.asarray(jax.device_get(x)),
                                tree)

        def cons(wp):
            # under a fault plan the consensus is over alive workers
            # only — dead rows hold stale (or warm-start) parameters
            if (self._faults() is not None
                    and isinstance(state.fault, FaultState)):
                alive = jnp.asarray(jax.device_get(state.fault.alive))
                # mid-curriculum (solo) rows stay out of the consensus,
                # exactly as they stay out of averaging events
                alive = self._faults().mix_at(alive, int(state.step))
                return faults_mod.masked_mean_tree(wp, alive)
            return consensus(wp)

        def consume(t, k, trace, tw0=None):
            # THE once-per-phase host sync: traces AND (telemetry mode)
            # the metrics accumulator come back in this one fetch
            trace = jax.device_get(trace)
            wall = 0.0 if tw0 is None else time.perf_counter() - tw0
            t_first = t
            n_loss, n_disp = len(hist["loss"]), len(hist["disp_trace"])
            events = []
            for i in range(k):
                t += 1
                code = int(trace["avg_code"][i])
                if code:
                    d = float(trace["dispersion"][i])
                    hist["dispersion"].append((t, d))
                    hist["averages"] += 1
                    events.append((t, d, code))
                if record_every and t % record_every == 0:
                    hist["loss"].append((t, float(trace["loss"][i])))
                    hist["disp_trace"].append(
                        (t, float(trace["dispersion"][i])))
            if needs_eval and t % record_every == 0:
                if eval_fn is not None:
                    hist["eval"].append(
                        (t, eval_fn(cons(unshard(
                            state.worker_params)))))
                if worker_eval_fn is not None:
                    hist["worker_eval"].append(
                        (t, worker_eval_fn(unshard(state.worker_params))))
            if sink is not None:
                for t_ev, d_ev, c_ev in events:
                    sink.emit(make_record(
                        "averaging_event", step=t_ev, dispersion=d_ev,
                        scope="inner" if c_ev == 1 else "all"))
                fp = self._faults()
                if fp is not None:
                    for ev in fp.events_in(t_first, t):
                        sink.emit(make_record(
                            "fault_event", step=ev.step, kind=ev.kind,
                            worker=ev.worker))
                flushed = tele_metrics.flush_metrics(trace["metrics"])
                sink.emit(make_record(
                    "phase_metrics", t0=t_first + 1, t1=t, wall_s=wall,
                    steps_per_s=(k / wall if wall > 0 else None),
                    loss_trace=hist["loss"][n_loss:],
                    disp_trace=hist["disp_trace"][n_disp:], **flushed))
            return t

        if isinstance(data, DeviceDataset):
            assert data.num_workers == num_workers, \
                (data.num_workers, num_workers)
            remaining = steps if steps is not None else data.num_steps
            assert remaining is not None, \
                "DeviceDataset with a sampler needs steps="
            if data.num_steps is not None:
                # like a streaming source, a precomputed index list ends
                # the run when exhausted
                remaining = min(remaining, data.num_steps)
            total = t0 + remaining
            t = t0
            while t < total:
                take = take_at(t)
                tw0 = time.perf_counter()
                idx = jnp.asarray(data.index_block(take))
                state, trace = self.run_phase_indexed(state, data.arrays,
                                                      idx)
                t = consume(t, take, trace, tw0)
            final = cons(unshard(state.worker_params))
            return (final, hist, state) if return_state else (final,
                                                              hist)

        def staged_blocks():
            it = iter(data)
            t, done = t0, False
            while not done:
                take = take_at(t)
                if take <= 0:
                    return
                chunk = []
                while len(chunk) < take:
                    try:
                        chunk.append(next(it))
                    except StopIteration:
                        done = True
                        break
                if not chunk:
                    return
                t += len(chunk)
                yield len(chunk), tree_stack(chunk)

        # a materialized in-memory source gains nothing from background
        # staging — the prefetch thread only contends with dispatch
        prefetch = prefetch and not isinstance(data, (list, tuple))
        pf = Prefetcher(staged_blocks()) if prefetch else None
        t = t0
        try:
            for k, staged in (pf if pf is not None else staged_blocks()):
                tw0 = time.perf_counter()
                state, trace = self.run_phase(state, staged)
                t = consume(t, k, trace, tw0)
        finally:
            if pf is not None:
                pf.close()
        final = cons(unshard(state.worker_params))
        return (final, hist, state) if return_state else (final, hist)

    # ---- legacy host-driven loop (benchmark baseline / equivalence) ------
    @partial(jax.jit, static_argnums=0)
    def _host_step(self, wp, opt_state, batch, step, rngs, sst, dec_key,
                   ec=None):
        """One host-loop step: the vmapped local update, the always-on
        Eq. 4 dispersion (post update, pre average) and the stateful
        schedule decision in one dispatch; the host reads the decision
        code and conditionally dispatches the averaging event."""
        wp, opt_state, losses, _ = self.worker_step(wp, opt_state, batch,
                                                    step, rngs)
        disp = worker_dispersion(wp).astype(jnp.float32)
        code, sst = self.schedule.decision_state(step, sst, disp, dec_key,
                                                 event_cost=ec)
        return wp, opt_state, jnp.mean(losses), disp, code, sst

    def _run_host_faults(self, params, batches, *, num_workers: int,
                         seed: int = 0, record_every: int = 0,
                         eval_fn=None, worker_eval_fn=None):
        """Host-driven loop under a fault plan: one :meth:`run_phase`
        dispatch per step, decisions and metrics read on host.

        Unlike the no-fault host loop, this path does NOT re-derive the
        step from tree ops: masked-update graphs large enough to carry
        the fault transition compile with different FMA contraction
        than the scan bodies (which sub-expressions LLVM fuses depends
        on the whole surrounding graph), drifting a second
        implementation one ulp per step no matter how the ops are
        ordered. Driving the SAME compiled phase one step at a time
        keeps the host loop's per-step dispatch granularity and host
        decision reads while making bit-identity with :meth:`run` hold
        by construction; the independent-implementation check under
        faults is the flat-native / flat / tree triple, which tier-1
        asserts bitwise."""
        state = self.init(params, num_workers, seed)
        hist = init_history()

        def cons(state):
            alive = jnp.asarray(jax.device_get(state.fault.alive))
            alive = self._faults().mix_at(alive, int(state.step))
            return faults_mod.masked_mean_tree(state.worker_params,
                                               alive)

        step = 0
        for batch in batches:
            step += 1
            state, trace = self.run_phase(state, tree_stack([batch]))
            trace = jax.device_get(trace)
            disp = float(trace["dispersion"][0])
            if int(trace["avg_code"][0]):
                hist["dispersion"].append((step, disp))
                hist["averages"] += 1
            if record_every and step % record_every == 0:
                hist["loss"].append((step, float(trace["loss"][0])))
                hist["disp_trace"].append((step, disp))
                if eval_fn is not None:
                    hist["eval"].append((step, eval_fn(cons(state))))
                if worker_eval_fn is not None:
                    hist["worker_eval"].append(
                        (step, worker_eval_fn(state.worker_params)))
        return cons(state), hist

    @partial(jax.jit, static_argnums=(0, 5))
    def _host_compressed_average(self, wp, resid, dec_key, step,
                                 scope: str, W=None):
        """Host-loop compressed averaging event: pack to the plane,
        encode -> event -> decode with the error-feedback residual,
        unpack. Same plane math as the fused in-scan event, so the host
        loop stays the bitwise baseline for :meth:`run`."""
        spec = FlatSpec.of(wp)
        plane, resid, _ = self._compressed_plane_event(
            spec, spec.pack(wp), resid, scope, step, dec_key, W=W)
        return spec.unpack(plane), resid

    @partial(jax.jit, static_argnums=(0, 3))
    def _host_average(self, wp, outer_state, scope: str, W=None):
        num_workers = jax.tree.leaves(wp)[0].shape[0]
        if scope == "inner":
            return (average_inner(wp, max(self.schedule.inner_groups, 1)),
                    outer_state)
        if W is not None:
            return mix_tree(wp, W), outer_state
        g = self._all_groups()
        if g > 1:
            return average_inner(wp, g), outer_state
        wp, outer_state = self._apply_all_average(wp, outer_state,
                                                  num_workers)
        return wp, outer_state

    def run_host(self, params, batches, *, num_workers: int, seed: int = 0,
                 record_every: int = 0, eval_fn=None, worker_eval_fn=None):
        """Per-step host-driven loop: one jit dispatch per step, the
        averaging decision read on host, blocking ``float()`` metric
        reads. Numerically identical to :meth:`run` (same per-step rng
        splits, same fold_in decision stream, same stateful-schedule
        transition on the same per-step dispersion) — kept as the
        dispatch-bound baseline the engine is benchmarked against. The
        history dict has the same keys and semantics as :meth:`run`'s,
        including ``disp_trace`` and ``worker_eval``. Under a fault
        plan the loop delegates to :meth:`_run_host_faults`, which
        keeps the per-step dispatch shape but drives the shared
        compiled phase."""
        self._check_workers(num_workers)
        if self._faults() is not None:
            return self._run_host_faults(
                params, batches, num_workers=num_workers, seed=seed,
                record_every=record_every, eval_fn=eval_fn,
                worker_eval_fn=worker_eval_fn)
        state = self.init(params, num_workers, seed)
        wp, opt_state, outer_state = (state.worker_params, state.opt_state,
                                      state.outer_state)
        key, sst, resid = state.key, state.sched, state.resid
        p_width = sum(x.size // num_workers
                      for x in jax.tree.leaves(wp))
        ec = self._sched_event_cost(p_width, num_workers)
        hist = init_history()
        step = 0
        for batch in batches:
            step += 1
            key, sub = jax.random.split(key)
            rngs = jax.random.split(sub, num_workers)
            wp, opt_state, loss, disp, code, sst = self._host_step(
                wp, opt_state, batch, jnp.asarray(step, jnp.int32),
                rngs, sst, state.dec_key, ec)
            code = int(code)
            if code:
                W = (self._event_W(jnp.asarray(step, jnp.int32),
                                   state.dec_key) if code == 2 else None)
                scope = "inner" if code == 1 else "all"
                if self._comp() is not None:
                    wp, resid = self._host_compressed_average(
                        wp, resid, state.dec_key,
                        jnp.asarray(step, jnp.int32), scope, W)
                else:
                    wp, outer_state = self._host_average(
                        wp, outer_state, scope, W)
                hist["dispersion"].append((step, float(disp)))
                hist["averages"] += 1
            if record_every and step % record_every == 0:
                hist["loss"].append((step, float(loss)))
                hist["disp_trace"].append((step, float(disp)))
                if eval_fn is not None:
                    hist["eval"].append((step, eval_fn(consensus(wp))))
                if worker_eval_fn is not None:
                    hist["worker_eval"].append(
                        (step, worker_eval_fn(wp)))
        return consensus(wp), hist

"""Compiled phase engine: K local steps + averaging as ONE jitted program.

The paper's algorithm is phase-structured — M workers each take K
independent SGD steps (Eq. 3), then their models are averaged — yet a
naive runtime dispatches one jitted call per step, decides averaging on
the host, and blocks on ``float()`` metric reads. This module compiles
the whole phase instead:

    run_phase(state, batches)          # ONE dispatch per phase
      └─ jax.lax.scan over K steps     # batches gathered on-device from
           └─ vmap over M workers      #   index blocks, or prefetched as
           └─ schedule.decision_code   #   a staged (K, M, ...) block
                none / inner / all averaging (+ outer optimizer)
      └─ loss + dispersion traces accumulated on-device, fetched once

All engine state (worker params, optimizer state, outer-optimizer state,
PRNG keys, step counter) lives in an :class:`EngineState` pytree that is
buffer-donated to ``run_phase``, so a phase updates parameters in place.
Averaging decisions — including the stochastic schedule's Bernoulli
draws — are pure functions of a single PRNG key and the step counter
(``fold_in(key, step)``), so runs are bitwise reproducible and resumable
from a checkpointed ``EngineState``.

Two device-residency layers sit on top of the PR 1 scan:

- **Flat parameter plane** (default): inside a phase the scan carries
  the workers as one contiguous ``(M, P)`` float32 plane
  (:class:`repro.core.flat.FlatSpec`; bit-exact pack/unpack), so every
  averaging event is a single fused pass — worker mean (global or
  per-group), Eq. 4 dispersion, broadcast, and the outer-optimizer
  momentum step — instead of 3–4 params-pytree traversals
  (``repro.kernels.avg_disp`` on TPU, its jnp twin on CPU). Trees with
  dtypes that have no exact float32 image fall back to the tree path.
- **On-device data plane**: :meth:`run` accepts a
  :class:`repro.data.pipeline.DeviceDataset` — the dataset lives on
  device, the driver ships (K, M, B) int32 index blocks, and the scan
  body gathers batches with ``jnp.take`` — zero per-phase host staging.
  Streaming iterables are staged by a double-buffered
  :class:`repro.data.pipeline.Prefetcher` thread instead.

Schedules lower to on-device control flow as follows:

  - oneshot     : statically no averaging branch at all
  - minibatch   : the all-average is unconditionally fused into each step
  - periodic(K) : ``step % K == 0`` predicate under ``lax.switch``
  - stochastic  : ``bernoulli(fold_in(key, step), ζ)`` under ``lax.switch``
  - hierarchical: two modulo predicates select none / inner / all

:meth:`PhaseEngine.run` is the production driver (one compiled dispatch
per phase); :meth:`PhaseEngine.run_host` keeps the legacy per-step
host-driven loop — same numerics, same decision stream — as the baseline
for `benchmarks/bench_engine.py` and the equivalence tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property, partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.averaging import (AveragingSchedule, OuterOptimizer,
                                  average_inner, worker_dispersion)
from repro.core.flat import FlatSpec
from repro.data.pipeline import DeviceDataset, Prefetcher
from repro.kernels.avg_disp import avg_disp, avg_disp_outer
from repro.kernels.ref import avg_disp_outer_ref, avg_disp_ref


# --------------------------------------------------------------------------
# Worker-axis utilities (leading axis = worker index on every leaf)
# --------------------------------------------------------------------------

def replicate(tree, num_workers: int):
    """Give every leaf a leading worker axis (all workers start at w_0,
    as the paper prescribes)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (num_workers,) + x.shape), tree)


def unreplicate(tree):
    return jax.tree.map(lambda x: x[0], tree)


def consensus(tree):
    """The paper's final estimate: the average of the workers."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), tree)


def tree_stack(trees):
    """Stack a list of per-step batches into one (K, ...) device block."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def make_worker_step(loss_fn: Callable, optimizer) -> Callable:
    """The ONE vmapped local-SGD step (paper Eq. 3) every runtime path
    shares: LocalSGD, the phase engine's scan body, and the launch/dryrun
    train steps.

    loss_fn(params, batch, rng) -> (loss, aux); optimizer is an
    init/apply pair from repro.optim. Returns
    step_fn(worker_params, opt_state, batch, step, rngs=None)
    -> (worker_params, opt_state, per-worker losses, aux).
    """
    def one(params, ostate, batch, rng, step):
        (loss, aux), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, rng)
        params, ostate = optimizer.apply(params, grads, ostate, step)
        return params, ostate, loss, aux

    def step_fn(worker_params, opt_state, batch, step, rngs=None):
        if rngs is None:  # rng-free losses (launch/dryrun abstract paths)
            return jax.vmap(lambda p, s, b: one(p, s, b, None, step))(
                worker_params, opt_state, batch)
        return jax.vmap(lambda p, s, b, r: one(p, s, b, r, step))(
            worker_params, opt_state, batch, rngs)

    return step_fn


class EngineState(NamedTuple):
    """Everything a phase consumes and produces; donated to run_phase."""
    worker_params: Any   # leaves (M, ...)
    opt_state: Any       # leaves (M, ...)
    outer_state: Any     # (prev_avg, velocity) trees, or () without outer
    key: Any             # data-rng key, split once per step
    dec_key: Any         # schedule-decision root key (constant)
    step: Any            # int32 scalar, steps completed


@dataclass(frozen=True, eq=False)  # eq=False: hash by identity for jit
class PhaseEngine:
    """loss_fn(params, batch, rng) -> (loss, aux); optimizer from
    repro.optim (init/apply pair).

    ``scan_unroll`` is forwarded to ``lax.scan``: XLA:CPU runs while-loop
    bodies with reduced intra-op threading, so compute-heavy losses (e.g.
    convolutions) on CPU backends benefit from ``scan_unroll=True`` (full
    unroll: longer compiles, per-step speed of eager dispatch). On real
    accelerator meshes leave the default rolled scan.

    ``flat`` selects the (M, P) flat-plane scan carry (default; falls
    back to the tree carry for trees FlatSpec cannot embed).
    ``kernel_impl`` picks the fused averaging implementation: "auto"
    (jnp reference on CPU, Pallas/Mosaic elsewhere), "ref", or
    "pallas"."""
    loss_fn: Callable
    optimizer: Any
    schedule: AveragingSchedule
    outer: OuterOptimizer | None = None
    scan_unroll: int | bool = 1
    flat: bool = True
    kernel_impl: str = "auto"

    @cached_property
    def worker_step(self):
        return make_worker_step(self.loss_fn, self.optimizer)

    # ---- state -----------------------------------------------------------
    def init(self, params, num_workers: int, seed: int = 0) -> EngineState:
        wp = replicate(params, num_workers)
        opt_state = jax.vmap(self.optimizer.init)(wp)
        outer_state = ()
        if self.outer is not None:
            avg = consensus(wp)
            outer_state = (avg, self.outer.init(avg))
        key, dec_key = jax.random.split(jax.random.PRNGKey(seed))
        return EngineState(wp, opt_state, outer_state, key, dec_key,
                           jnp.zeros((), jnp.int32))

    # ---- fused flat averaging -------------------------------------------
    def _use_pallas(self) -> bool:
        if self.kernel_impl == "pallas":
            return True
        if self.kernel_impl == "ref":
            return False
        return jax.default_backend() != "cpu"

    def _flat_average(self, plane, outer_c, scope: str):
        """ONE fused pass over the (M, P) plane: mean (global or
        per-group), Eq. 4 dispersion, broadcast, and — for the all-scope
        with an outer optimizer — the outer momentum step."""
        pallas = self._use_pallas()
        if scope == "inner":
            groups = max(self.schedule.inner_groups, 1)
            if pallas:
                plane, disp = avg_disp(plane, groups=groups)
            else:
                plane, disp = avg_disp_ref(plane, groups=groups)
            return plane, outer_c, disp
        if self.outer is not None and outer_c != ():
            prev, vel = outer_c
            fused = avg_disp_outer if pallas else avg_disp_outer_ref
            plane, prev, vel, disp = fused(
                plane, prev, vel, lr=self.outer.lr,
                momentum=self.outer.momentum, nesterov=self.outer.nesterov)
            return plane, (prev, vel), disp
        if pallas:
            plane, disp = avg_disp(plane)
        else:
            plane, disp = avg_disp_ref(plane)
        return plane, outer_c, disp

    # ---- tree-path averaging (flat=False, and FlatSpec fallback) ---------
    def _apply_all_average(self, wp, outer_state, num_workers):
        avg = consensus(wp)
        if self.outer is not None:
            prev_avg, vel = outer_state
            avg, vel = self.outer.apply(prev_avg, avg, vel)
            outer_state = (avg, vel)
        return replicate(avg, num_workers), outer_state

    def _tree_average(self, wp, outer_c, scope: str, num_workers: int):
        disp = worker_dispersion(wp).astype(jnp.float32)
        if scope == "inner":
            return (average_inner(wp, max(self.schedule.inner_groups, 1)),
                    outer_c, disp)
        wp, outer_c = self._apply_all_average(wp, outer_c, num_workers)
        return wp, outer_c, disp

    # ---- the compiled phase ---------------------------------------------
    def _phase(self, state: EngineState, xs, fetch):
        """Trace the whole phase: scan the K entries of ``xs``
        (pre-staged batches, or index blocks that ``fetch`` gathers
        on-device), averaging fused per the schedule. Returns the new
        state and per-step traces {loss, dispersion, avg_code} — the only
        host transfer a phase needs."""
        num_workers = jax.tree.leaves(state.worker_params)[0].shape[0]
        sched = self.schedule
        use_flat = self.flat and FlatSpec.supports(state.worker_params)

        if use_flat:
            spec = FlatSpec.of(state.worker_params)
            carry_p = spec.pack(state.worker_params)
            carry_o = ()
            if self.outer is not None and state.outer_state != ():
                prev_avg, vel = state.outer_state
                carry_o = (spec.pack1(prev_avg), spec.pack1(vel))
            average = self._flat_average
        else:
            spec = None
            carry_p = state.worker_params
            carry_o = state.outer_state
            average = partial(self._tree_average, num_workers=num_workers)

        def body(carry, xs_t):
            wp_c, opt_state, outer_c, key, step = carry
            step = step + 1
            key, sub = jax.random.split(key)
            rngs = jax.random.split(sub, num_workers)
            batch = fetch(xs_t)
            wp = spec.unpack(wp_c) if use_flat else wp_c
            wp, opt_state, losses, _ = self.worker_step(
                wp, opt_state, batch, step, rngs)
            wp_c = spec.pack(wp) if use_flat else wp
            code = sched.decision_code(step, state.dec_key)
            if sched.kind == "oneshot":
                disp = jnp.zeros((), jnp.float32)
            elif sched.kind == "minibatch":
                wp_c, outer_c, disp = average(wp_c, outer_c, "all")
            else:
                def none_branch(args):
                    wp_c, oc = args
                    return wp_c, oc, jnp.zeros((), jnp.float32)

                def inner_branch(args):
                    return average(*args, "inner")

                def all_branch(args):
                    return average(*args, "all")

                wp_c, outer_c, disp = jax.lax.switch(
                    code, [none_branch, inner_branch, all_branch],
                    (wp_c, outer_c))
            return ((wp_c, opt_state, outer_c, key, step),
                    (jnp.mean(losses), disp.astype(jnp.float32), code))

        carry0 = (carry_p, state.opt_state, carry_o, state.key, state.step)
        (wp_c, opt_state, outer_c, key, step), (loss, disp, code) = \
            jax.lax.scan(body, carry0, xs, unroll=self.scan_unroll)

        if use_flat:
            wp = spec.unpack(wp_c)
            outer_state = state.outer_state
            if carry_o != ():
                outer_state = (spec.unpack1(outer_c[0]),
                               spec.unpack1(outer_c[1], dtypes=jnp.float32))
        else:
            wp, outer_state = wp_c, outer_c
        new_state = EngineState(wp, opt_state, outer_state, key,
                                state.dec_key, step)
        return new_state, {"loss": loss, "dispersion": disp,
                           "avg_code": code}

    @partial(jax.jit, static_argnums=0, donate_argnums=1)
    def run_phase(self, state: EngineState, batches):
        """One compiled dispatch over a pre-staged (K, M, ...) batch
        block."""
        return self._phase(state, batches, lambda b: b)

    @partial(jax.jit, static_argnums=0, donate_argnums=1)
    def run_phase_indexed(self, state: EngineState, dataset, idx_block):
        """One compiled dispatch over a (K, M, B) int32 index block:
        batches are gathered from the device-resident ``dataset``
        INSIDE the scan (``jnp.take``), so the host ships only
        indices."""
        def fetch(idx):
            return jax.tree.map(lambda a: jnp.take(a, idx, axis=0), dataset)
        return self._phase(state, idx_block, fetch)

    def default_phase_len(self) -> int:
        """Compile-size heuristic: align phase blocks with the schedule's
        natural period (correctness never depends on the block size —
        decisions are per-step, on-device)."""
        s = self.schedule
        if s.kind == "periodic":
            return max(1, min(s.phase_len, 512))
        if s.kind == "hierarchical":
            return max(1, min(s.inner_phase_len, 512))
        if s.kind == "stochastic":
            return int(min(max(1.0 / max(s.zeta, 1e-12), 8), 128))
        return 64  # oneshot / minibatch: any block size

    # ---- drivers ---------------------------------------------------------
    def run(self, params, data, *, num_workers: int, seed: int = 0,
            record_every: int = 0, eval_fn=None, worker_eval_fn=None,
            phase_len: int | None = None, steps: int | None = None,
            prefetch: bool = True):
        """Production driver: one run_phase dispatch per block of steps.

        data: an iterable of per-step worker batches (leading axis M) —
        staged to device by a background :class:`Prefetcher` thread
        (``prefetch=False`` stages synchronously) — or a
        :class:`DeviceDataset`, in which case batches are gathered
        on-device from index blocks and ``steps`` bounds the run (it
        defaults to the dataset's precomputed index list, if any).
        eval_fn(consensus_params) / worker_eval_fn(worker_params) run on
        host every ``record_every`` steps (phase blocks are cut so record
        boundaries coincide with phase ends). Returns (final averaged
        params, history dict).
        """
        state = self.init(params, num_workers, seed)
        block = phase_len or self.default_phase_len()
        needs_eval = bool(record_every and (eval_fn or worker_eval_fn))
        hist = {"loss": [], "dispersion": [], "averages": 0, "eval": [],
                "worker_eval": []}

        def take_at(t):
            take = block
            if needs_eval:
                take = min(take, record_every - t % record_every)
            if steps is not None:
                take = min(take, steps - t)
            return take

        def consume(t, k, trace):
            trace = jax.device_get(trace)
            for i in range(k):
                t += 1
                if trace["avg_code"][i]:
                    hist["dispersion"].append(
                        (t, float(trace["dispersion"][i])))
                    hist["averages"] += 1
                if record_every and t % record_every == 0:
                    hist["loss"].append((t, float(trace["loss"][i])))
            if needs_eval and t % record_every == 0:
                if eval_fn is not None:
                    hist["eval"].append(
                        (t, eval_fn(consensus(state.worker_params))))
                if worker_eval_fn is not None:
                    hist["worker_eval"].append(
                        (t, worker_eval_fn(state.worker_params)))
            return t

        if isinstance(data, DeviceDataset):
            assert data.num_workers == num_workers, \
                (data.num_workers, num_workers)
            total = steps if steps is not None else data.num_steps
            assert total is not None, \
                "DeviceDataset with a sampler needs steps="
            if data.num_steps is not None:
                # like a streaming source, a precomputed index list ends
                # the run when exhausted
                total = min(total, data.num_steps)
            steps = total
            t = 0
            while t < total:
                take = take_at(t)
                idx = jnp.asarray(data.index_block(take))
                state, trace = self.run_phase_indexed(state, data.arrays,
                                                      idx)
                t = consume(t, take, trace)
            return consensus(state.worker_params), hist

        def staged_blocks():
            it = iter(data)
            t, done = 0, False
            while not done:
                take = take_at(t)
                if take <= 0:
                    return
                chunk = []
                while len(chunk) < take:
                    try:
                        chunk.append(next(it))
                    except StopIteration:
                        done = True
                        break
                if not chunk:
                    return
                t += len(chunk)
                yield len(chunk), tree_stack(chunk)

        blocks = Prefetcher(staged_blocks()) if prefetch \
            else staged_blocks()
        t = 0
        try:
            for k, staged in blocks:
                state, trace = self.run_phase(state, staged)
                t = consume(t, k, trace)
        finally:
            if isinstance(blocks, Prefetcher):
                blocks.close()
        return consensus(state.worker_params), hist

    # ---- legacy host-driven loop (benchmark baseline / equivalence) ------
    @partial(jax.jit, static_argnums=0)
    def _host_step(self, wp, opt_state, batch, step, rngs):
        wp, opt_state, losses, _ = self.worker_step(wp, opt_state, batch,
                                                    step, rngs)
        return wp, opt_state, jnp.mean(losses)

    @partial(jax.jit, static_argnums=(0, 3))
    def _host_average(self, wp, outer_state, scope: str):
        num_workers = jax.tree.leaves(wp)[0].shape[0]
        disp = worker_dispersion(wp).astype(jnp.float32)
        if scope == "inner":
            return (average_inner(wp, max(self.schedule.inner_groups, 1)),
                    outer_state, disp)
        wp, outer_state = self._apply_all_average(wp, outer_state,
                                                  num_workers)
        return wp, outer_state, disp

    def run_host(self, params, batches, *, num_workers: int, seed: int = 0,
                 record_every: int = 0, eval_fn=None, worker_eval_fn=None):
        """Per-step host-driven loop: one jit dispatch per step, the
        averaging decision read on host, blocking ``float()`` metric
        reads. Numerically identical to :meth:`run` (same per-step rng
        splits, same fold_in decision stream) — kept as the dispatch-bound
        baseline the engine is benchmarked against. The history dict has
        the same keys and semantics as :meth:`run`'s, including
        ``worker_eval``."""
        state = self.init(params, num_workers, seed)
        wp, opt_state, outer_state = (state.worker_params, state.opt_state,
                                      state.outer_state)
        key = state.key
        hist = {"loss": [], "dispersion": [], "averages": 0, "eval": [],
                "worker_eval": []}
        step = 0
        for batch in batches:
            step += 1
            key, sub = jax.random.split(key)
            rngs = jax.random.split(sub, num_workers)
            wp, opt_state, loss = self._host_step(
                wp, opt_state, batch, jnp.asarray(step, jnp.int32), rngs)
            code = int(self.schedule.decision_code(step, state.dec_key))
            if code:
                wp, outer_state, disp = self._host_average(
                    wp, outer_state, "inner" if code == 1 else "all")
                hist["dispersion"].append((step, float(disp)))
                hist["averages"] += 1
            if record_every and step % record_every == 0:
                hist["loss"].append((step, float(loss)))
                if eval_fn is not None:
                    hist["eval"].append((step, eval_fn(consensus(wp))))
                if worker_eval_fn is not None:
                    hist["worker_eval"].append(
                        (step, worker_eval_fn(wp)))
        return consensus(wp), hist

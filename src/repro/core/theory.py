"""Closed-form results from the paper + the simulations that validate them.

- Lemma 1: asymptotic variance of the worker average under stochastic
  averaging with rate ζ on f(w) = c w²/2 with gradient noise
  ∇f̃(w) = c w - b̃ w - h̃,  Var b̃ = β², Var h̃ = σ².
- Eq. (4): the coarse-model worker-dispersion bound that *cannot* see any
  benefit from averaging (paper Example 2).
- The (Q, P) recursion from Appendix A, iterated exactly, plus a Monte
  Carlo simulator — both used by tests/benchmarks to check Lemma 1.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np


def lemma1_eta(zeta: float, alpha: float, c: float) -> float:
    if zeta >= 1.0:
        return np.inf
    return zeta / ((1.0 - zeta) * alpha * (2.0 * c - alpha * c * c))


def lemma1_asymptotic_variance(alpha: float, c: float, beta2: float,
                               sigma2: float, M: int, zeta: float) -> float:
    """lim_t Var( (1/M) Σ_i w_{i,t} ).  ζ=0 → one-shot regime,
    ζ=1 → minibatch regime (η→∞ handled by its limit)."""
    eta = lemma1_eta(zeta, alpha, c)
    if np.isinf(eta):
        factor = 1.0 / M
    else:
        factor = (1.0 + eta / M) / (1.0 + eta)
    denom = 2.0 * c - alpha * c * c - alpha * beta2 * factor
    if denom <= 0:
        return np.inf  # divergent regime
    return alpha * sigma2 / (M * denom)


def qp_recursion(alpha, c, beta2, sigma2, M, zeta, steps, q0=0.0, p0=0.0):
    """Exact expected-value iteration of Appendix A:
      no-avg:  Q' = (1-αc)² Q + α²β²P/M + α²σ²/M
               P' = ((1-αc)² + α²β²) P + α²σ²
      avg:     Q' = Q ; P' = Q
      mixed with probability ζ via total expectation.
    Returns trajectory of Q (variance of the average)."""
    a2 = (1.0 - alpha * c) ** 2
    q, p = q0, p0
    out = np.empty(steps)
    for t in range(steps):
        qn = a2 * q + alpha ** 2 * beta2 * p / M + alpha ** 2 * sigma2 / M
        pn = (a2 + alpha ** 2 * beta2) * p + alpha ** 2 * sigma2
        q = (1 - zeta) * qn + zeta * q
        p = (1 - zeta) * pn + zeta * q  # after averaging P collapses to Q
        # NOTE: paper's coupled update uses pre-update Q for the avg branch;
        # for the fixed point it is equivalent.
        out[t] = q
    return out


def simulate_quadratic(alpha, c, beta2, sigma2, M, zeta, steps, *,
                       reps=2000, seed=0, w0_std=0.0):
    """Monte-Carlo of the §2.3 process: ``reps`` independent systems of M
    workers; returns Var over reps of the worker-average at the end."""
    key = jax.random.PRNGKey(seed)
    kb, kh, kz, k0 = jax.random.split(key, 4)
    b = jax.random.normal(kb, (steps, reps, M)) * np.sqrt(beta2)
    h = jax.random.normal(kh, (steps, reps, M)) * np.sqrt(sigma2)
    avg = jax.random.uniform(kz, (steps, reps)) < zeta
    w_init = jax.random.normal(k0, (reps, M)) * w0_std

    def step(w, inp):
        bt, ht, at = inp
        w = (1.0 - alpha * c) * w + alpha * (bt * w + ht)
        wbar = jnp.mean(w, axis=1, keepdims=True)
        w = jnp.where(at[:, None], wbar, w)
        return w, None

    w, _ = jax.lax.scan(step, w_init, (b, h, avg))
    wbar = jnp.mean(w, axis=1)
    return float(jnp.var(wbar))


def coarse_dispersion_bound(alpha, sigma2, L, c, k):
    """Eq. (4): E||w_ik - w̄_k||² ≤ ασ²/(2L-αc²) [1-(1-2αL+αc²... )^k].
    The point (Example 2): it does not depend on when averaging happened."""
    denom = 2.0 * L - alpha * c * c
    rate = 1.0 - 2.0 * alpha * L + (alpha * c) ** 2
    return alpha * sigma2 / denom * (1.0 - rate ** k)


# --------------------------------------------------------------------------
# Gossip-topology hooks (repro.topology): what the mixing spectrum says
# about the Eq. 4 dispersion
# --------------------------------------------------------------------------

def mixing_contraction(spectral_gap: float) -> float:
    """Per-event dispersion contraction of one mixing-matrix event.

    Splitting worker states into consensus + deviation, a symmetric
    doubly-stochastic W maps the deviation through its spectrum on the
    consensus-orthogonal subspace, so ONE event multiplies the Eq. 4
    dispersion by at most λ₂² = (1 - spectral_gap)²
    (:attr:`repro.topology.Topology.spectral_gap` = 1 - SLEM): 0 for
    the full mean (dispersion collapses, the paper's operator), 1 for
    a disconnected graph (events change nothing)."""
    lam2 = 1.0 - spectral_gap
    return lam2 * lam2


def mixed_dispersion_fixed_point(alpha, sigma2, L, c, k,
                                 spectral_gap: float) -> float:
    """Eq. (4) generalized to a gossip topology: the steady-state
    PRE-event dispersion when a mixing event with the given spectral
    gap fires every ``k`` steps.

    Between events the coarse model grows dispersion per Eq. 4's
    recursion (k steps from D add g(k) = coarse_dispersion_bound(k)
    and decay the remainder by rate^k); each event contracts it by
    ρ = (1 - gap)² (:func:`mixing_contraction`). The pre-event fixed
    point is

        D* = g(k) / (1 - ρ · rate^k)

    Limits anchor the axis: gap=1 (full averaging) recovers Eq. 4's
    schedule-independent bound g(k) exactly — the coarse model's
    Example 2 point that it *cannot* see any benefit from averaging —
    and gap=0 (disconnected) recovers the k→∞ envelope ασ²/(2L-αc²),
    as if no event ever fired."""
    rho = mixing_contraction(spectral_gap)
    rate = 1.0 - 2.0 * alpha * L + (alpha * c) ** 2
    g = coarse_dispersion_bound(alpha, sigma2, L, c, k)
    return g / (1.0 - rho * rate ** k)


# --------------------------------------------------------------------------
# Example 1 (homogeneous quadratics): averaging-frequency invariance
# --------------------------------------------------------------------------

def run_homogeneous_quadratic(P, qs, w0, alpha, steps, M, phase_len, seed=0):
    """SGD on f_j(w) = ½wᵀPw + wᵀq_j with common Hessian P. Per Example 1,
    the final worker-average is IDENTICAL for any averaging schedule given
    the same sample draws. Returns the final average (used by tests)."""
    key = jax.random.PRNGKey(seed)
    m = qs.shape[0]
    idx = jax.random.randint(key, (steps, M), 0, m)
    w = jnp.broadcast_to(w0[None], (M,) + w0.shape)

    def body(w, t_idx):
        t, ix = t_idx
        g = w @ P.T + qs[ix]
        w = w - alpha * g
        do_avg = (phase_len > 0) & ((t + 1) % max(phase_len, 1) == 0)
        wbar = jnp.mean(w, axis=0, keepdims=True)
        w = jnp.where(do_avg, jnp.broadcast_to(wbar, w.shape), w)
        return w, None

    w, _ = jax.lax.scan(body, w, (jnp.arange(steps), idx))
    return jnp.mean(w, axis=0)

"""Local-SGD runtime: M workers × independent steps × periodic averaging.

This is the paper's algorithm (Eq. 3 + phase-end averaging) as a
production training strategy:

    worker_params = replicate(params, M)        # leading worker axis
    for step in 1..T:
        worker_params, opt_state = local_step(...)   # vmap over workers,
                                                     # NO cross-worker comm
        if schedule.wants_average(step):
            worker_params = average(...)             # one all-reduce

On a mesh, the worker axis is sharded over ("data",) or ("pod","data"),
so ``local_step`` contains zero cross-worker collectives and ``average``
is exactly one parameter all-reduce — the statistical/hardware-efficiency
trade-off of the paper becomes explicit, inspectable communication.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.averaging import (AveragingSchedule, OuterOptimizer,
                                  average_all, average_inner,
                                  worker_dispersion)


def replicate(tree, num_workers: int):
    """Give every leaf a leading worker axis (all workers start at w_0,
    as the paper prescribes)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (num_workers,) + x.shape), tree)


def unreplicate(tree):
    return jax.tree.map(lambda x: x[0], tree)


def consensus(tree):
    """The paper's final estimate: the average of the workers."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), tree)


@dataclass(frozen=True, eq=False)  # eq=False: hash by identity for jit
class LocalSGD:
    """loss_fn(params, batch, rng) -> (loss, metrics); optimizer from
    repro.optim (init/apply pair)."""
    loss_fn: Callable
    optimizer: Any
    schedule: AveragingSchedule
    outer: OuterOptimizer | None = None

    # ---- jitted pieces ---------------------------------------------------
    def init(self, params, num_workers: int):
        wp = replicate(params, num_workers)
        opt_state = jax.vmap(self.optimizer.init)(wp)
        outer_state = None
        if self.outer is not None:
            avg = consensus(wp)
            outer_state = (avg, self.outer.init(avg))
        return wp, opt_state, outer_state

    @partial(jax.jit, static_argnums=0)
    def local_step(self, worker_params, opt_state, batch, step, rngs):
        """One independent SGD step in every worker (paper Eq. 3).
        batch: leaves with leading worker axis. rngs: (M, 2) PRNG keys."""
        def one(params, ostate, b, rng):
            (loss, metrics), grads = jax.value_and_grad(
                self.loss_fn, has_aux=True)(params, b, rng)
            params, ostate = self.optimizer.apply(params, grads, ostate, step)
            return params, ostate, loss, metrics
        wp, os, loss, metrics = jax.vmap(one)(worker_params, opt_state,
                                              batch, rngs)
        return wp, os, {"loss": jnp.mean(loss), "metrics": metrics}

    @partial(jax.jit, static_argnums=(0, 3))
    def average(self, worker_params, outer_state, scope: str = "all"):
        """scope: "all" | "inner". Returns (worker_params, outer_state,
        dispersion-before-average)."""
        disp = worker_dispersion(worker_params)
        if scope == "inner" and self.schedule.inner_groups > 1:
            wp = average_inner(worker_params, self.schedule.inner_groups)
            return wp, outer_state, disp
        avg = consensus(worker_params)
        if self.outer is not None and outer_state is not None:
            prev_avg, vel = outer_state
            avg, vel = self.outer.apply(prev_avg, avg, vel)
            outer_state = (avg, vel)
        m = jax.tree.leaves(worker_params)[0].shape[0]
        wp = replicate(avg, m)
        return wp, outer_state, disp

    # ---- host-side driver -------------------------------------------------
    def run(self, params, batches, *, num_workers: int, seed: int = 0,
            record_every: int = 0, eval_fn=None):
        """batches: iterable of per-step worker batches (leading axis M).
        Returns (final averaged params, history dict)."""
        wp, opt_state, outer_state = self.init(params, num_workers)
        rng = np.random.default_rng(seed)
        key = jax.random.PRNGKey(seed)
        hist = {"loss": [], "dispersion": [], "averages": 0, "eval": []}
        step = 0
        for batch in batches:
            step += 1
            key, sub = jax.random.split(key)
            rngs = jax.random.split(sub, num_workers)
            wp, opt_state, info = self.local_step(wp, opt_state, batch,
                                                  jnp.asarray(step), rngs)
            scope = self.schedule.wants_average(step, rng)
            if scope != "none":
                wp, outer_state, disp = self.average(wp, outer_state, scope)
                hist["dispersion"].append((step, float(disp)))
                hist["averages"] += 1
            if record_every and step % record_every == 0:
                hist["loss"].append((step, float(info["loss"])))
                if eval_fn is not None:
                    hist["eval"].append((step, eval_fn(consensus(wp))))
        return consensus(wp), hist

"""Local-SGD runtime: M workers × independent steps × periodic averaging.

This is the paper's algorithm (Eq. 3 + phase-end averaging) as a
production training strategy:

    worker_params = replicate(params, M)        # leading worker axis
    for phase in phases:                        # ONE compiled dispatch
        worker_params, traces = run_phase(...)  #   K steps × M workers,
                                                #   averaging fused in

Execution is delegated to :class:`repro.core.engine.PhaseEngine`: the
whole phase — ``lax.scan`` over K vmapped local steps, the on-device
averaging decision (``AveragingSchedule.decision_code``), the model
average itself, and the loss/dispersion traces — is one jitted,
buffer-donated program. On a mesh the worker axis is sharded over
("data",) or ("pod","data"), so a local step contains zero cross-worker
collectives and each averaging event is exactly one parameter all-reduce
— the statistical/hardware-efficiency trade-off of the paper becomes
explicit, inspectable communication.

:class:`LocalSGD` is kept as the stable public API: ``run`` is a thin
wrapper over ``PhaseEngine.run``, and ``local_step`` / ``average`` expose
the engine's building blocks for callers that drive steps themselves.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property, partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.averaging import (AveragingSchedule, OuterOptimizer,
                                  average_inner, worker_dispersion)
from repro.core.engine import (PhaseEngine, consensus,  # noqa: F401
                               replicate, unreplicate)


@dataclass(frozen=True, eq=False)  # eq=False: hash by identity for jit
class LocalSGD:
    """loss_fn(params, batch, rng) -> (loss, metrics); optimizer from
    repro.optim (init/apply pair)."""
    loss_fn: Callable
    optimizer: Any
    schedule: AveragingSchedule
    outer: OuterOptimizer | None = None
    faults: Any = None  # repro.faults.FaultPlan | None

    @cached_property
    def engine(self) -> PhaseEngine:
        return PhaseEngine(self.loss_fn, self.optimizer, self.schedule,
                           outer=self.outer, faults=self.faults)

    # ---- jitted pieces ---------------------------------------------------
    def init(self, params, num_workers: int):
        state = self.engine.init(params, num_workers)
        outer_state = state.outer_state if self.outer is not None else None
        return state.worker_params, state.opt_state, outer_state

    @partial(jax.jit, static_argnums=0)
    def local_step(self, worker_params, opt_state, batch, step, rngs):
        """One independent SGD step in every worker (paper Eq. 3).
        batch: leaves with leading worker axis. rngs: (M, 2) PRNG keys."""
        wp, opt_state, losses, metrics = self.engine.worker_step(
            worker_params, opt_state, batch, step, rngs)
        return wp, opt_state, {"loss": jnp.mean(losses), "metrics": metrics}

    @partial(jax.jit, static_argnums=(0, 3))
    def average(self, worker_params, outer_state, scope: str = "all"):
        """scope: "all" | "inner". Returns (worker_params, outer_state,
        dispersion-before-average)."""
        disp = worker_dispersion(worker_params)
        if scope == "inner" and self.schedule.inner_groups > 1:
            wp = average_inner(worker_params, self.schedule.inner_groups)
            return wp, outer_state, disp
        m = jax.tree.leaves(worker_params)[0].shape[0]
        if self.outer is not None and outer_state is not None:
            wp, outer_state = self.engine._apply_all_average(
                worker_params, outer_state, m)
            return wp, outer_state, disp
        # no outer optimizer (or no state yet): the paper's plain mean
        wp = replicate(consensus(worker_params), m)
        return wp, outer_state, disp

    # ---- driver (compat wrapper over the phase engine) -------------------
    def run(self, params, batches, *, num_workers: int, seed: int = 0,
            record_every: int = 0, eval_fn=None):
        """batches: iterable of per-step worker batches (leading axis M).
        Returns (final averaged params, history dict). One compiled
        dispatch per phase; stochastic-schedule draws come from the
        engine's on-device PRNG stream (pure function of ``seed``)."""
        return self.engine.run(params, batches, num_workers=num_workers,
                               seed=seed, record_every=record_every,
                               eval_fn=eval_fn)

"""Compressed communication planes: the wire precision of averaging events.

The paper trades statistical efficiency against communication by picking
WHEN to average; PR 4/5 added adaptive timing and sparse topologies.
This module adds the third axis — what PRECISION the averaged/mixed rows
travel at. Every averaging event conceptually ships each worker's (P,)
row to its neighbors; production gossip quantizes that row. Four wire
formats:

  - ``f32``     — identity. The engine lowers this to the existing
                  uncompressed paths, bit-exactly.
  - ``bf16``    — round-to-nearest-even cast through bfloat16 (half the
                  bytes; deterministic, no shared randomness needed).
  - ``int8``    — per-row scale ``s = max|v| / 127`` plus stochastic
                  rounding of ``v / s`` to the int8 grid (4x fewer
                  bytes + one f32 scale per row).
  - ``one_bit`` — per-row scale ``s = mean|v|`` times the sign of each
                  entry (signSGD/EF-style; 32x fewer bytes + one f32
                  scale per row).

The quantizer is *biased* per event for ``int8``/``one_bit`` — what
makes low-precision mixing still converge like Parallel Restarted SGD
(Yu, Yang & Zhu, arXiv 1807.06629) predicts for infrequent exact
averaging is **error feedback**: the residual ``e`` of what quantization
dropped is added back before the next encode,

    v = plane + e;   q = Q(v);   e' = v - q;   event acts on q,

so the quantization error is re-sent (at full resolution, eventually)
instead of lost. The residual rides the phase scan as one more (M, P)
float32 plane, carried in ``EngineState.resid`` and checkpointed
(engine-state layout v3).

Reproducibility: ``int8``'s stochastic rounding draws one uniform per
entry from a salted per-row fold_in chain on ``(dec_key, step,
global_row_index)`` (:func:`row_uniforms`) — the same pure-function
recipe as the stochastic schedule and the gossip matchings — so every
engine path, phase blocking, shard (each shard generates exactly its own
rows) and checkpoint/resume replays identical quantizations.

``repro.kernels.ref`` holds the jnp event twins
(``compressed_avg_ref`` / ``compressed_mix_ref``), ``repro.kernels``
the fused Pallas passes; :class:`repro.core.engine.PhaseEngine`
accepts ``compression=Compression(...)``.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

#: wire formats, cheapest-precision last
WIRE_FORMATS = ("f32", "bf16", "int8", "one_bit")

#: payload bits per plane entry on the wire
WIRE_BITS = {"f32": 32, "bf16": 16, "int8": 8, "one_bit": 1}

#: formats whose per-event quantization is biased and therefore
#: requires the error-feedback residual to converge
_NEEDS_ERROR_FEEDBACK = ("int8", "one_bit")

#: formats that ship one f32 scale per row next to the payload
_SCALED = ("int8", "one_bit")

_ENC_SALT = 0x656E63  # "enc": decorrelates the stochastic-rounding
#                     # stream from the schedule's Bernoulli draws and
#                     # the gossip matchings, which fold the same
#                     # (dec_key, step)


def wire_row_bytes(p: int, wire: str) -> int:
    """Bytes ONE worker row (P entries) occupies on the wire: the packed
    payload (rounded up to whole bytes) plus the f32 per-row scale for
    the scaled formats."""
    if wire not in WIRE_FORMATS:
        raise ValueError(f"unknown wire format {wire!r}; "
                         f"pick one of {WIRE_FORMATS}")
    payload = -(-p * WIRE_BITS[wire] // 8)
    return payload + (4 if wire in _SCALED else 0)


@dataclass(frozen=True)
class Compression:
    """The communication-precision axis of every averaging/mixing event.

    ``wire`` picks the format; ``error_feedback`` keeps the (M, P)
    residual plane of what quantization dropped and re-adds it before
    the next encode. The biased formats (``int8``, ``one_bit``) refuse
    to run without it — without the residual their per-event bias
    accumulates and the run drifts from the consensus trajectory.
    ``f32`` is the identity: the engine lowers it to the uncompressed
    paths bit-exactly and carries no residual."""
    wire: str = "f32"
    error_feedback: bool = True

    def __post_init__(self):
        if self.wire not in WIRE_FORMATS:
            raise ValueError(f"unknown wire format {self.wire!r}; "
                             f"pick one of {WIRE_FORMATS}")
        if self.wire in _NEEDS_ERROR_FEEDBACK and not self.error_feedback:
            raise ValueError(
                f"wire format {self.wire!r} quantizes with per-event "
                "bias and needs the error-feedback residual to "
                "converge — keep error_feedback=True (or use bf16/f32)")

    @property
    def is_identity(self) -> bool:
        return self.wire == "f32"

    @property
    def stochastic(self) -> bool:
        """True when encoding consumes the per-row uniform stream
        (:func:`row_uniforms`)."""
        return self.wire == "int8"

    def row_bytes(self, p: int) -> int:
        return wire_row_bytes(p, self.wire)


def row_uniforms(dec_key, step, row_ids, p: int):
    """The stochastic-rounding uniforms for the given GLOBAL worker rows
    at this step: ``u[i] = uniform(fold_in(fold_in(fold_in(dec_key,
    salt), step), row_ids[i]), (p,))``.

    Keyed per row so a sharded engine generates exactly its own rows —
    bit-identical to the rows a single-device run generates — and pure
    in ``(dec_key, step)`` so every path, phase blocking and resume
    replays the same draws. ``step`` and ``row_ids`` may be traced."""
    base = jax.random.fold_in(jax.random.fold_in(dec_key, _ENC_SALT), step)
    return jax.vmap(
        lambda rid: jax.random.uniform(jax.random.fold_in(base, rid),
                                       (p,), jnp.float32))(row_ids)


def quantize(v, wire: str, *, u=None):
    """Encode+decode one (M, P) float32 plane through ``wire``: returns
    the decoded float32 image ``q`` — what the receiving workers
    reconstruct from the bytes actually shipped. ``u`` is the
    :func:`row_uniforms` plane (required for ``int8``, ignored
    otherwise). All-zero rows quantize to zero in every format."""
    if wire == "f32":
        return v
    if wire == "bf16":
        return v.astype(jnp.bfloat16).astype(jnp.float32)
    if wire == "int8":
        assert u is not None, "int8 stochastic rounding needs row_uniforms"
        amax = jnp.max(jnp.abs(v), axis=1, keepdims=True)
        s = jnp.where(amax > 0.0, amax / 127.0, 1.0)
        qi = jnp.clip(jnp.floor(v / s + u), -127.0, 127.0)
        return qi * s
    if wire == "one_bit":
        s = jnp.mean(jnp.abs(v), axis=1, keepdims=True)
        return jnp.where(v >= 0.0, s, -s)
    raise ValueError(f"unknown wire format {wire!r}; "
                     f"pick one of {WIRE_FORMATS}")


def encode_decode(plane, resid, *, wire: str, u=None,
                  error_feedback: bool = True):
    """The error-feedback encode of one event: ``v = plane + resid``,
    ``q = quantize(v)``, ``resid' = v - q``. Returns ``(q, resid')`` —
    the event operator (mean / group mean / ``W @``) acts on ``q``.
    Without ``error_feedback`` the residual passes through unchanged
    and ``v = plane``."""
    v = plane + resid if error_feedback else plane
    q = quantize(v, wire, u=u)
    return q, (v - q if error_feedback else resid)

"""Averaging schedules and averaging operators — the paper's technique.

A *schedule* decides WHEN the M workers' models are averaged:
  - one-shot     : only at the very end (Zinkevich et al. 2010)
  - minibatch    : every step (statistically = 1 worker with batch M)
  - periodic(K)  : every K steps — the paper's main subject
  - stochastic(ζ): i.i.d. per-step probability ζ (paper §2.3 / Lemma 1)
  - hierarchical : inner groups every K_inner, all workers every K_outer
                   (beyond-paper: matches TPU ICI/DCI bandwidth hierarchy)

An averaging *operator* says HOW: plain mean, or an outer optimizer
(Nesterov momentum on the averaging direction — beyond-paper, DiLoCo-like).

Workers are represented as a leading axis of size M on every leaf of the
params pytree; on a device mesh this axis is sharded over the worker
(data / pod×data) mesh axes, so the means below lower to all-reduces over
exactly those axes.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class AveragingSchedule:
    kind: str = "periodic"      # oneshot | minibatch | periodic | stochastic | hierarchical
    phase_len: int = 128        # K for periodic
    zeta: float = 0.0           # for stochastic
    inner_phase_len: int = 16   # hierarchical: average inner groups every K_i
    outer_phase_len: int = 512  # hierarchical: average everyone every K_o
    inner_groups: int = 1       # hierarchical: number of inner groups

    _KINDS = ("oneshot", "minibatch", "periodic", "stochastic",
              "hierarchical")

    def __post_init__(self):
        # the engine lowers decisions to traced integer mod / bernoulli
        # ops, where invalid parameters mis-schedule silently instead of
        # raising like the old host loop did — validate eagerly instead
        if self.kind not in self._KINDS:
            raise ValueError(f"unknown schedule kind {self.kind!r}")
        if self.kind == "periodic" and self.phase_len < 1:
            raise ValueError(f"periodic needs phase_len >= 1, "
                             f"got {self.phase_len}")
        if self.kind == "stochastic" and not 0.0 < self.zeta <= 1.0:
            raise ValueError(f"stochastic needs 0 < zeta <= 1, "
                             f"got {self.zeta}")
        if self.kind == "hierarchical" and (
                self.inner_phase_len < 1 or self.outer_phase_len < 1
                or self.inner_groups < 1):
            raise ValueError(
                "hierarchical needs inner_phase_len/outer_phase_len/"
                f"inner_groups >= 1, got ({self.inner_phase_len}, "
                f"{self.outer_phase_len}, {self.inner_groups})")

    def expected_phase_len(self) -> float:
        if self.kind == "oneshot":
            return float("inf")
        if self.kind == "minibatch":
            return 1.0
        if self.kind == "periodic":
            return float(self.phase_len)
        if self.kind == "stochastic":
            return 1.0 / max(self.zeta, 1e-12)
        if self.kind == "hierarchical":
            return float(self.inner_phase_len)
        raise ValueError(self.kind)

    def decision_code(self, step, key=None):
        """On-device decision for step ``step`` (1-indexed steps done).
        Returns an int32 code — 0: none, 1: inner, 2: all — computable
        under a jit trace, so the whole schedule lowers to ``lax.switch``
        inside the phase engine's scan. ``step`` may be a traced scalar.

        Stochastic draws come from ``fold_in(key, step)``, which makes the
        schedule a pure function of (key, step): reproducible, resumable
        from a checkpointed key, and identical whether evaluated on-device
        (engine) or eagerly on host (legacy loop).
        """
        if self.kind == "oneshot":
            return jnp.zeros((), jnp.int32)
        if self.kind == "minibatch":
            return jnp.full((), 2, jnp.int32)
        if self.kind == "periodic":
            return jnp.where(step % self.phase_len == 0, 2, 0).astype(jnp.int32)
        if self.kind == "stochastic":
            assert key is not None, "stochastic schedule needs a PRNG key"
            hit = jax.random.bernoulli(jax.random.fold_in(key, step),
                                       self.zeta)
            return jnp.where(hit, 2, 0).astype(jnp.int32)
        if self.kind == "hierarchical":
            outer = step % self.outer_phase_len == 0
            inner = step % self.inner_phase_len == 0
            return jnp.where(outer, 2,
                             jnp.where(inner, 1, 0)).astype(jnp.int32)
        raise ValueError(self.kind)

    def wants_average(self, step: int, rng: np.random.Generator | None = None):
        """Legacy host-side decision for step ``step`` (1-indexed steps
        done). Returns "none" | "inner" | "all". Stochastic draws use the
        numpy generator; the engine path uses ``decision_code`` instead."""
        if self.kind == "oneshot":
            return "none"
        if self.kind == "minibatch":
            return "all"
        if self.kind == "periodic":
            return "all" if step % self.phase_len == 0 else "none"
        if self.kind == "stochastic":
            assert rng is not None
            return "all" if rng.random() < self.zeta else "none"
        if self.kind == "hierarchical":
            if step % self.outer_phase_len == 0:
                return "all"
            if step % self.inner_phase_len == 0:
                return "inner"
            return "none"
        raise ValueError(self.kind)


# --------------------------------------------------------------------------
# Operators (worker axis = leading dim 0 of every leaf)
# --------------------------------------------------------------------------

def average_all(worker_tree):
    """Mean over the worker axis, broadcast back — the paper's operator."""
    def avg(x):
        m = jnp.mean(x, axis=0, keepdims=True)
        return jnp.broadcast_to(m, x.shape).astype(x.dtype)
    return jax.tree.map(avg, worker_tree)


def average_inner(worker_tree, inner_groups: int):
    """Hierarchical inner average: W workers = inner_groups contiguous
    groups; mean within each group only (lowers to an all-reduce over the
    intra-pod mesh axis when groups align with pods)."""
    def avg(x):
        w = x.shape[0]
        g = inner_groups
        xg = x.reshape((g, w // g) + x.shape[1:])
        m = jnp.mean(xg, axis=1, keepdims=True)
        return jnp.broadcast_to(m, xg.shape).reshape(x.shape).astype(x.dtype)
    return jax.tree.map(avg, worker_tree)


def worker_dispersion(worker_tree):
    """Mean squared distance of workers from their average — the paper's
    E||w_i - w̄||² variance diagnostic (Eq. 4)."""
    def sq(x):
        m = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.sum(jnp.square(x.astype(jnp.float32) - m)) / x.shape[0]
    return sum(jax.tree.leaves(jax.tree.map(sq, worker_tree)))


# --------------------------------------------------------------------------
# Outer optimizer (beyond-paper): treat the consensus move as a gradient
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class OuterOptimizer:
    """DiLoCo-style outer Nesterov momentum applied at averaging steps.
    With lr=1, momentum=0 this reduces exactly to the paper's plain mean."""
    lr: float = 1.0
    momentum: float = 0.0
    nesterov: bool = True

    def init(self, avg_tree):
        return jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32),
                            avg_tree)

    def apply(self, prev_avg, new_avg, velocity):
        """prev_avg/new_avg: trees WITHOUT worker axis. Returns
        (updated average, velocity). Two plain tree.map passes — params
        may be arbitrarily nested pytrees (incl. tuples), so no is_leaf
        tricks on the mapped output."""
        def outer_grad(p, n):
            return p.astype(jnp.float32) - n.astype(jnp.float32)

        velocity = jax.tree.map(
            lambda p, n, v: self.momentum * v + outer_grad(p, n),
            prev_avg, new_avg, velocity)
        updated = jax.tree.map(
            lambda p, n, v: (p.astype(jnp.float32) - self.lr * (
                self.momentum * v + outer_grad(p, n) if self.nesterov else v
            )).astype(p.dtype),
            prev_avg, new_avg, velocity)
        return updated, velocity

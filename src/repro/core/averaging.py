"""Averaging schedules and averaging operators — the paper's technique.

A *schedule* decides WHEN the M workers' models are averaged:
  - one-shot     : only at the very end (Zinkevich et al. 2010)
  - minibatch    : every step (statistically = 1 worker with batch M)
  - periodic(K)  : every K steps — the paper's main subject
  - stochastic(ζ): i.i.d. per-step probability ζ (paper §2.3 / Lemma 1)
  - hierarchical : inner groups every K_inner, all workers every K_outer
                   (beyond-paper: matches TPU ICI/DCI bandwidth hierarchy)

An averaging *operator* says HOW: plain mean, or an outer optimizer
(Nesterov momentum on the averaging direction — beyond-paper, DiLoCo-like).

Workers are represented as a leading axis of size M on every leaf of the
params pytree; on a device mesh this axis is sharded over the worker
(data / pod×data) mesh axes, so the means below lower to all-reduces over
exactly those axes.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class AveragingSchedule:
    kind: str = "periodic"      # oneshot | minibatch | periodic | stochastic | hierarchical
    phase_len: int = 128        # K for periodic
    zeta: float = 0.0           # for stochastic
    inner_phase_len: int = 16   # hierarchical: average inner groups every K_i
    outer_phase_len: int = 512  # hierarchical: average everyone every K_o
    inner_groups: int = 1       # hierarchical: number of inner groups

    def expected_phase_len(self) -> float:
        if self.kind == "oneshot":
            return float("inf")
        if self.kind == "minibatch":
            return 1.0
        if self.kind == "periodic":
            return float(self.phase_len)
        if self.kind == "stochastic":
            return 1.0 / max(self.zeta, 1e-12)
        if self.kind == "hierarchical":
            return float(self.inner_phase_len)
        raise ValueError(self.kind)

    def wants_average(self, step: int, rng: np.random.Generator | None = None):
        """Host-side decision for step ``step`` (1-indexed steps done).
        Returns "none" | "inner" | "all"."""
        if self.kind == "oneshot":
            return "none"
        if self.kind == "minibatch":
            return "all"
        if self.kind == "periodic":
            return "all" if step % self.phase_len == 0 else "none"
        if self.kind == "stochastic":
            assert rng is not None
            return "all" if rng.random() < self.zeta else "none"
        if self.kind == "hierarchical":
            if step % self.outer_phase_len == 0:
                return "all"
            if step % self.inner_phase_len == 0:
                return "inner"
            return "none"
        raise ValueError(self.kind)


# --------------------------------------------------------------------------
# Operators (worker axis = leading dim 0 of every leaf)
# --------------------------------------------------------------------------

def average_all(worker_tree):
    """Mean over the worker axis, broadcast back — the paper's operator."""
    def avg(x):
        m = jnp.mean(x, axis=0, keepdims=True)
        return jnp.broadcast_to(m, x.shape).astype(x.dtype)
    return jax.tree.map(avg, worker_tree)


def average_inner(worker_tree, inner_groups: int):
    """Hierarchical inner average: W workers = inner_groups contiguous
    groups; mean within each group only (lowers to an all-reduce over the
    intra-pod mesh axis when groups align with pods)."""
    def avg(x):
        w = x.shape[0]
        g = inner_groups
        xg = x.reshape((g, w // g) + x.shape[1:])
        m = jnp.mean(xg, axis=1, keepdims=True)
        return jnp.broadcast_to(m, xg.shape).reshape(x.shape).astype(x.dtype)
    return jax.tree.map(avg, worker_tree)


def worker_dispersion(worker_tree):
    """Mean squared distance of workers from their average — the paper's
    E||w_i - w̄||² variance diagnostic (Eq. 4)."""
    def sq(x):
        m = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.sum(jnp.square(x.astype(jnp.float32) - m)) / x.shape[0]
    return sum(jax.tree.leaves(jax.tree.map(sq, worker_tree)))


# --------------------------------------------------------------------------
# Outer optimizer (beyond-paper): treat the consensus move as a gradient
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class OuterOptimizer:
    """DiLoCo-style outer Nesterov momentum applied at averaging steps.
    With lr=1, momentum=0 this reduces exactly to the paper's plain mean."""
    lr: float = 1.0
    momentum: float = 0.0
    nesterov: bool = True

    def init(self, avg_tree):
        return jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32),
                            avg_tree)

    def apply(self, prev_avg, new_avg, velocity):
        """prev_avg/new_avg: trees WITHOUT worker axis. Returns
        (updated average, velocity)."""
        def upd(p, n, v):
            delta = p.astype(jnp.float32) - n.astype(jnp.float32)  # outer grad
            v2 = self.momentum * v + delta
            step = self.momentum * v2 + delta if self.nesterov else v2
            return (p.astype(jnp.float32) - self.lr * step).astype(p.dtype), v2
        flat = jax.tree.map(upd, prev_avg, new_avg, velocity)
        outer = jax.tree.map(lambda t: t[0], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        vel = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda t: isinstance(t, tuple))
        return outer, vel

"""Averaging schedules and averaging operators — the paper's technique.

A *schedule* decides WHEN the M workers' models are averaged:
  - one-shot     : only at the very end (Zinkevich et al. 2010)
  - minibatch    : every step (statistically = 1 worker with batch M)
  - periodic(K)  : every K steps — the paper's main subject
  - stochastic(ζ): i.i.d. per-step probability ζ (paper §2.3 / Lemma 1)
  - hierarchical : inner groups every K_inner, all workers every K_outer
                   (beyond-paper: matches TPU ICI/DCI bandwidth hierarchy)
  - adaptive_threshold : average when the running EMA of the Eq. 4
                   dispersion crosses ``disp_threshold`` — communication
                   follows the measured gradient-variance envelope the
                   paper says governs whether averaging helps
  - adaptive_budget : APA-style (Jiang & Agrawal, arXiv:2007.06134):
                   spend at most ``comm_budget`` averaging events over
                   ``budget_horizon`` steps, paced proportionally to the
                   measured dispersion envelope — high-dispersion
                   stretches get communication ahead of uniform pacing,
                   quiet stretches save it
  - adaptive_bytes : the same dispersion-paced accrual, but the budget
                   and the credit are BYTES on the wire, not events:
                   each event costs ``comm_bytes(topology, 1, P, wire)``
                   (the engine passes it as ``event_cost``), so the one
                   ``byte_budget`` knob prices timing x topology x
                   precision in a common currency — a ring event with an
                   int8 wire is ~100x cheaper than a full-mean f32 event
                   and the schedule fires proportionally more often

The two adaptive kinds are *stateful*: their decisions are pure
functions of an explicit :class:`SchedState` (dispersion EMA, cumulative
dispersion, pacing credit, events spent, steps since the last event)
threaded through the phase scan and checkpointed in ``EngineState`` —
see :meth:`AveragingSchedule.decision_state`. The static kinds flow
through the same transition (their state is pure bookkeeping), so every
engine path carries one uniform carry.

An averaging *operator* says HOW: plain mean, or an outer optimizer
(Nesterov momentum on the averaging direction — beyond-paper, DiLoCo-like).

Workers are represented as a leading axis of size M on every leaf of the
params pytree; on a device mesh this axis is sharded over the worker
(data / pod×data) mesh axes, so the means below lower to all-reduces over
exactly those axes.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class SchedState(NamedTuple):
    """The stateful-schedule carry: everything an adaptive decision may
    depend on, as jnp scalars so it rides the phase scan and checkpoints
    inside ``EngineState`` bit-exactly.

    ``disp_ema`` is the running EMA of the per-step Eq. 4 dispersion,
    reset to 0 at every averaging event (so it measures dispersion built
    up *since* the last average). ``cum_disp`` is the un-reset running
    sum (the envelope's integral), ``credit`` the adaptive_budget pacing
    credit (in events) or the adaptive_bytes credit (in bytes — same
    slot, so the checkpointed leaf structure never changes),
    ``comm_spent`` the number of averaging events so far, and
    ``since_avg`` the steps since the last event. The static schedule
    kinds update the same fields (pure bookkeeping), so every engine
    path carries one uniform state."""
    disp_ema: jnp.ndarray    # f32 scalar
    cum_disp: jnp.ndarray    # f32 scalar
    credit: jnp.ndarray      # f32 scalar
    comm_spent: jnp.ndarray  # int32 scalar
    since_avg: jnp.ndarray   # int32 scalar


@dataclass(frozen=True)
class AveragingSchedule:
    kind: str = "periodic"      # oneshot | minibatch | periodic | stochastic
    #                           # | hierarchical | adaptive_threshold
    #                           # | adaptive_budget
    phase_len: int = 128        # K for periodic
    zeta: float = 0.0           # for stochastic
    inner_phase_len: int = 16   # hierarchical: average inner groups every K_i
    outer_phase_len: int = 512  # hierarchical: average everyone every K_o
    inner_groups: int = 1       # hierarchical: number of inner groups
    disp_threshold: float = 0.0  # adaptive_threshold: EMA trip level
    disp_ema_beta: float = 0.9  # adaptive: dispersion EMA decay
    comm_budget: int = 0        # adaptive_budget: max averaging events
    budget_horizon: int = 0     # adaptive_*: steps the budget spans
    byte_budget: int = 0        # adaptive_bytes: max bytes per worker
    straggle_aware: bool = False  # adaptive: discount straggler-widened
    #                           # dispersion (engine passes the alive/
    #                           # updated fraction as disp_scale)

    _KINDS = ("oneshot", "minibatch", "periodic", "stochastic",
              "hierarchical", "adaptive_threshold", "adaptive_budget",
              "adaptive_bytes")
    _ADAPTIVE = ("adaptive_threshold", "adaptive_budget",
                 "adaptive_bytes")

    def __post_init__(self):
        # the engine lowers decisions to traced integer mod / bernoulli
        # ops, where invalid parameters mis-schedule silently instead of
        # raising like the old host loop did — validate eagerly instead
        if self.kind not in self._KINDS:
            raise ValueError(f"unknown schedule kind {self.kind!r}")
        if self.kind == "periodic" and self.phase_len < 1:
            raise ValueError(f"periodic needs phase_len >= 1, "
                             f"got {self.phase_len}")
        if self.kind == "stochastic" and not 0.0 < self.zeta <= 1.0:
            raise ValueError(f"stochastic needs 0 < zeta <= 1, "
                             f"got {self.zeta}")
        if self.kind == "hierarchical" and (
                self.inner_phase_len < 1 or self.outer_phase_len < 1
                or self.inner_groups < 1):
            raise ValueError(
                "hierarchical needs inner_phase_len/outer_phase_len/"
                f"inner_groups >= 1, got ({self.inner_phase_len}, "
                f"{self.outer_phase_len}, {self.inner_groups})")
        if self.is_adaptive and not 0.0 <= self.disp_ema_beta < 1.0:
            raise ValueError(f"adaptive schedules need 0 <= disp_ema_beta "
                             f"< 1, got {self.disp_ema_beta}")
        if self.kind == "adaptive_threshold" and self.disp_threshold <= 0.0:
            raise ValueError(f"adaptive_threshold needs disp_threshold > 0, "
                             f"got {self.disp_threshold}")
        if self.kind == "adaptive_budget":
            if self.comm_budget < 1 or self.budget_horizon < 1:
                raise ValueError(
                    "adaptive_budget needs comm_budget >= 1 and "
                    f"budget_horizon >= 1, got ({self.comm_budget}, "
                    f"{self.budget_horizon})")
            if self.comm_budget > self.budget_horizon:
                raise ValueError(
                    f"adaptive_budget cannot spend {self.comm_budget} "
                    f"events in {self.budget_horizon} steps (at most one "
                    "averaging event per step)")
        if self.kind == "adaptive_bytes":
            if self.byte_budget < 1 or self.budget_horizon < 1:
                raise ValueError(
                    "adaptive_bytes needs byte_budget >= 1 and "
                    f"budget_horizon >= 1, got ({self.byte_budget}, "
                    f"{self.budget_horizon})")
        if self.straggle_aware and not self.is_adaptive:
            raise ValueError(
                f"straggle_aware discounts the dispersion fed to the "
                f"adaptive schedules; {self.kind!r} never consumes "
                "dispersion — drop straggle_aware or use one of "
                f"{self._ADAPTIVE}")

    @property
    def is_adaptive(self) -> bool:
        return self.kind in self._ADAPTIVE

    def expected_phase_len(self) -> float:
        """A-priori expected steps between communication events.

        For ``hierarchical`` this counts *any* event (inner or outer):
        events sit at multiples of K_i or K_o, so the rate is the
        harmonic combination 1/K_i + 1/K_o - 1/lcm(K_i, K_o) (the lcm
        term removes the double-counted coinciding steps). For
        ``adaptive_threshold`` the interval is data-dependent with no
        a-priori value — returns NaN. For ``adaptive_budget`` it is the
        budget's paced average interval."""
        if self.kind == "oneshot":
            return float("inf")
        if self.kind == "minibatch":
            return 1.0
        if self.kind == "periodic":
            return float(self.phase_len)
        if self.kind == "stochastic":
            return 1.0 / max(self.zeta, 1e-12)
        if self.kind == "hierarchical":
            ki, ko = self.inner_phase_len, self.outer_phase_len
            rate = 1.0 / ki + 1.0 / ko - 1.0 / math.lcm(ki, ko)
            return 1.0 / rate
        if self.kind == "adaptive_threshold":
            return float("nan")
        if self.kind == "adaptive_budget":
            return self.budget_horizon / self.comm_budget
        if self.kind == "adaptive_bytes":
            # bytes-per-event depends on (topology, wire, P), which only
            # the engine knows — no a-priori interval
            return float("nan")
        raise ValueError(self.kind)

    def init_sched_state(self) -> SchedState:
        # distinct arrays per field: EngineState is buffer-donated, and
        # aliased leaves would be donated twice
        f32 = lambda: jnp.zeros((), jnp.float32)
        i32 = lambda: jnp.zeros((), jnp.int32)
        return SchedState(f32(), f32(), f32(), i32(), i32())

    def decision_state(self, step, sched_state: SchedState, disp, key=None,
                       event_cost=None, disp_scale=None):
        """The stateful on-device decision: one pure transition
        ``(step, state, dispersion) -> (code, new state)`` shared by
        every engine path (flat-native scan, tree scan, sharded
        shard_map body, host loop), so decisions replay bit-identically
        across paths, phase blockings, and checkpoint/resume.

        ``disp`` is the Eq. 4 dispersion measured at THIS step, after
        the local update and before any averaging (the fused
        opt_step/avg_disp passes emit it every step). ``step`` may be a
        Python int (host loop) or a traced int32 scalar (scan body);
        the returned code is int32 (0: none, 1: inner, 2: all).

        Transition: the dispersion EMA advances by ``disp_ema_beta``
        (then resets to 0 when an averaging event fires, so it measures
        dispersion built since the last average); ``adaptive_threshold``
        fires when the EMA crosses ``disp_threshold``;
        ``adaptive_budget`` accrues pacing credit at the uniform rate
        ``comm_budget / budget_horizon`` scaled by the current EMA
        relative to the long-run mean dispersion (APA-style: spend the
        budget where the envelope is high), fires when a whole credit is
        accumulated, and never exceeds ``comm_budget`` events.
        ``adaptive_bytes`` is the same accrual with the credit
        denominated in BYTES: it accrues ``byte_budget/budget_horizon``
        bytes-per-step (EMA-scaled), fires when the credit covers one
        event's ``event_cost`` (the engine passes
        ``comm_bytes(topology, 1, P, wire)``), and never lets
        ``(events+1) * event_cost`` exceed ``byte_budget``. Static kinds
        defer to :meth:`decision_code` and only update the bookkeeping
        fields.

        Determinism caveat: the transition is bitwise reproducible for
        a FIXED ``disp`` stream, but ``disp`` itself is a float32
        reduction whose summation order differs across engine paths
        (flat plane vs per-leaf tree sums vs psum of shard partials).
        A run whose EMA lands within a last-ulp tie of the trip level
        at a decision step could therefore fire one step apart between
        paths on multi-leaf models; the single-buffer paths (flat vs
        host on one leaf, gather-collective vs single-device) reduce
        identically and replay identical decision streams — what the
        equivalence tests pin.

        ``disp_scale``: with ``straggle_aware=True`` the engine passes
        the fraction of the mixing cohort that applied its update this
        step (``FaultPlan.disp_scale``); the measured dispersion is
        multiplied by it before entering the EMA/budget accrual, so a
        straggler's frozen iterate — which lags the mean and widens the
        dispersion without carrying gradient-variance signal — is
        discounted instead of triggering spurious averaging events. The
        recorded dispersion trace is NOT scaled; only the decision
        input is."""
        s = sched_state
        disp = jnp.asarray(disp, jnp.float32)
        if self.straggle_aware and disp_scale is not None:
            disp = disp * jnp.asarray(disp_scale, jnp.float32)
        beta = jnp.asarray(self.disp_ema_beta, jnp.float32)
        ema = beta * s.disp_ema + (1.0 - beta) * disp
        cum = s.cum_disp + disp
        credit = s.credit
        if self.kind == "adaptive_threshold":
            code = jnp.where(ema > self.disp_threshold, 2, 0)
            code = code.astype(jnp.int32)
        elif self.kind == "adaptive_budget":
            rate = jnp.asarray(self.comm_budget / self.budget_horizon,
                               jnp.float32)
            mean = cum / jnp.maximum(jnp.asarray(step, jnp.float32), 1.0)
            w = jnp.where(mean > 0.0, ema / jnp.maximum(mean, 1e-30), 0.0)
            credit = credit + rate * w
            fire = (credit >= 1.0) & (s.comm_spent < self.comm_budget)
            code = jnp.where(fire, 2, 0).astype(jnp.int32)
            credit = jnp.where(fire, credit - 1.0, credit)
        elif self.kind == "adaptive_bytes":
            if event_cost is None:
                raise ValueError(
                    "adaptive_bytes needs event_cost (bytes one event "
                    "puts on the wire per worker) — the engine passes "
                    "comm_bytes(topology, 1, P, wire)")
            ec = jnp.asarray(event_cost, jnp.float32)
            rate = jnp.asarray(self.byte_budget / self.budget_horizon,
                               jnp.float32)
            mean = cum / jnp.maximum(jnp.asarray(step, jnp.float32), 1.0)
            w = jnp.where(mean > 0.0, ema / jnp.maximum(mean, 1e-30), 0.0)
            credit = credit + rate * w
            spent_after = (s.comm_spent + 1).astype(jnp.float32) * ec
            fire = (credit >= ec) & (spent_after <= self.byte_budget)
            code = jnp.where(fire, 2, 0).astype(jnp.int32)
            credit = jnp.where(fire, credit - ec, credit)
        else:
            code = self.decision_code(step, key)
        avg = code > 0
        new = SchedState(
            disp_ema=jnp.where(avg, 0.0, ema).astype(jnp.float32),
            cum_disp=cum,
            credit=jnp.asarray(credit, jnp.float32),
            comm_spent=s.comm_spent + avg.astype(jnp.int32),
            since_avg=jnp.where(avg, 0, s.since_avg + 1).astype(jnp.int32))
        return code, new

    def decision_code(self, step, key=None):
        """On-device decision for step ``step`` (1-indexed steps done).
        Returns an int32 code — 0: none, 1: inner, 2: all — computable
        under a jit trace, so the whole schedule lowers to ``lax.switch``
        inside the phase engine's scan. ``step`` may be a traced scalar.

        Stochastic draws come from ``fold_in(key, step)``, which makes the
        schedule a pure function of (key, step): reproducible, resumable
        from a checkpointed key, and identical whether evaluated on-device
        (engine) or eagerly on host (legacy loop).

        The adaptive kinds have no stateless decision — use
        :meth:`decision_state`.
        """
        if self.is_adaptive:
            raise ValueError(
                f"{self.kind} decisions depend on SchedState; use "
                "decision_state(step, sched_state, disp, key)")
        if self.kind == "oneshot":
            return jnp.zeros((), jnp.int32)
        if self.kind == "minibatch":
            return jnp.full((), 2, jnp.int32)
        if self.kind == "periodic":
            return jnp.where(step % self.phase_len == 0, 2, 0).astype(jnp.int32)
        if self.kind == "stochastic":
            assert key is not None, "stochastic schedule needs a PRNG key"
            hit = jax.random.bernoulli(jax.random.fold_in(key, step),
                                       self.zeta)
            return jnp.where(hit, 2, 0).astype(jnp.int32)
        if self.kind == "hierarchical":
            outer = step % self.outer_phase_len == 0
            inner = step % self.inner_phase_len == 0
            return jnp.where(outer, 2,
                             jnp.where(inner, 1, 0)).astype(jnp.int32)
        raise ValueError(self.kind)

    def wants_average(self, step: int, rng: np.random.Generator | None = None):
        """Legacy host-side decision for step ``step`` (1-indexed steps
        done). Returns "none" | "inner" | "all". Stochastic draws use the
        numpy generator; the engine path uses ``decision_code`` instead.
        The adaptive kinds need :meth:`decision_state`."""
        if self.is_adaptive:
            raise ValueError(
                f"{self.kind} decisions depend on SchedState; use "
                "decision_state(step, sched_state, disp, key)")
        if self.kind == "oneshot":
            return "none"
        if self.kind == "minibatch":
            return "all"
        if self.kind == "periodic":
            return "all" if step % self.phase_len == 0 else "none"
        if self.kind == "stochastic":
            assert rng is not None
            return "all" if rng.random() < self.zeta else "none"
        if self.kind == "hierarchical":
            if step % self.outer_phase_len == 0:
                return "all"
            if step % self.inner_phase_len == 0:
                return "inner"
            return "none"
        raise ValueError(self.kind)


# --------------------------------------------------------------------------
# Operators (worker axis = leading dim 0 of every leaf)
# --------------------------------------------------------------------------

def average_all(worker_tree):
    """Mean over the worker axis, broadcast back — the paper's operator."""
    def avg(x):
        m = jnp.mean(x, axis=0, keepdims=True)
        return jnp.broadcast_to(m, x.shape).astype(x.dtype)
    return jax.tree.map(avg, worker_tree)


def average_inner(worker_tree, inner_groups: int):
    """Hierarchical inner average: W workers = inner_groups contiguous
    groups; mean within each group only (lowers to an all-reduce over the
    intra-pod mesh axis when groups align with pods)."""
    def avg(x):
        w = x.shape[0]
        g = inner_groups
        xg = x.reshape((g, w // g) + x.shape[1:])
        m = jnp.mean(xg, axis=1, keepdims=True)
        return jnp.broadcast_to(m, xg.shape).reshape(x.shape).astype(x.dtype)
    return jax.tree.map(avg, worker_tree)


def worker_dispersion(worker_tree):
    """Mean squared distance of workers from their average — the paper's
    E||w_i - w̄||² variance diagnostic (Eq. 4)."""
    def sq(x):
        m = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.sum(jnp.square(x.astype(jnp.float32) - m)) / x.shape[0]
    return sum(jax.tree.leaves(jax.tree.map(sq, worker_tree)))


# --------------------------------------------------------------------------
# Outer optimizer (beyond-paper): treat the consensus move as a gradient
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class OuterOptimizer:
    """DiLoCo-style outer Nesterov momentum applied at averaging steps.
    With lr=1, momentum=0 this reduces exactly to the paper's plain mean."""
    lr: float = 1.0
    momentum: float = 0.0
    nesterov: bool = True

    def init(self, avg_tree):
        return jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32),
                            avg_tree)

    def apply(self, prev_avg, new_avg, velocity):
        """prev_avg/new_avg: trees WITHOUT worker axis. Returns
        (updated average, velocity). Two plain tree.map passes — params
        may be arbitrarily nested pytrees (incl. tuples), so no is_leaf
        tricks on the mapped output."""
        def outer_grad(p, n):
            return p.astype(jnp.float32) - n.astype(jnp.float32)

        velocity = jax.tree.map(
            lambda p, n, v: self.momentum * v + outer_grad(p, n),
            prev_avg, new_avg, velocity)
        updated = jax.tree.map(
            lambda p, n, v: (p.astype(jnp.float32) - self.lr * (
                self.momentum * v + outer_grad(p, n) if self.nesterov else v
            )).astype(p.dtype),
            prev_avg, new_avg, velocity)
        return updated, velocity

"""Telemetry plane: on-device metrics, structured run events, timing.

Three layers (docs/TELEMETRY.md):

- :mod:`repro.telemetry.metrics` — a fixed-layout f32 accumulator that
  rides the phase scan carry and is flushed to the host ONCE per phase
  with the existing trace fetch. Enabling it never changes trained
  state: telemetry on vs off is bit-identical.
- :mod:`repro.telemetry.events` — versioned JSONL records
  (``run_meta`` / ``phase_metrics`` / ``averaging_event`` /
  ``fault_event`` / ``resize_event`` / ``checkpoint_event``) behind the
  :class:`TelemetrySink` protocol, with :class:`RunLog` reading them
  back (including the legacy history-dict reconstruction).
- :mod:`repro.telemetry.timing` — warmup / best-of-reps wall-clock
  helpers with explicit ``block_until_ready`` semantics, and the
  ``jax.profiler.trace`` phase-capture hook.

``python -m repro.telemetry.report <run.jsonl>`` renders a run log as
a per-phase table (steps/sec, dispersion envelope vs the variance-model
prediction, bytes/event).
"""
from repro.telemetry.events import (JsonlSink, MemorySink, NullSink,  # noqa: F401
                                    RunLog, TELEMETRY_VERSION,
                                    TelemetrySink, init_history,
                                    make_record, parse_record,
                                    run_meta_record)
from repro.telemetry.metrics import (FLUSH_FUNCTIONS, NUM_SLOTS,  # noqa: F401
                                     SLOT_NAMES, accumulate,
                                     flush_metrics, init_metrics)
from repro.telemetry.timing import profile_trace, time_run, timed  # noqa: F401

"""On-device metrics plane: a fixed-layout f32 accumulator in the scan.

The phase engine's design rule is ONE host transfer per phase — the
per-step ``{loss, dispersion, avg_code}`` traces come back from the
compiled ``run_phase`` dispatch and are fetched once by the driver.
Telemetry must not erode that: per-phase aggregates (sums, maxes,
counts) are therefore accumulated ON DEVICE, as one small ``(NUM_SLOTS,)``
float32 vector riding the scan carry, and ride the very same trace
fetch to the host. The accumulator is created as zeros inside the phase
trace (:func:`init_metrics`), so it is NOT part of the checkpointed
:class:`~repro.core.engine.EngineState` — a resumed run reconstructs
its metrics instead of persisting them, and the checkpoint layout is
untouched (docs/TELEMETRY.md).

Host round-trips on these values (``float()``, ``.item()``,
``jax.device_get``, ``np.asarray``) are only legal inside the flush
functions named in :data:`FLUSH_FUNCTIONS` — the ``telemetry-host-sync``
analysis rule (docs/INVARIANTS.md §7) enforces this, keeping the
metrics plane from silently re-introducing per-step device syncs.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Fixed slot layout of the accumulator vector. Appending a slot is a
# backward-compatible change (flush keys by name); reordering is not.
SLOT_NAMES = (
    "steps",          # 0: local steps accumulated
    "loss_sum",       # 1: sum of per-step (alive-)mean losses
    "loss_max",       # 2: running max of the per-step loss
    "disp_sum",       # 3: sum of the per-step Eq. 4 dispersion
    "disp_max",       # 4: running max of the dispersion envelope
    "events_inner",   # 5: inner (group-mean) averaging events
    "events_all",     # 6: all-scope averaging / mixing events
    "comm_bytes",     # 7: nominal wire bytes ONE worker shipped
    #                      (topology.comm_bytes pricing per event)
    "alive_sum",      # 8: sum over steps of the alive-worker count
    "alive_min",      # 9: min alive-worker count seen in the phase
    "straggle_sum",   # 10: sum over steps of alive-and-straggling rows
)
NUM_SLOTS = len(SLOT_NAMES)
_I = {name: i for i, name in enumerate(SLOT_NAMES)}

# Host flush functions — the ONLY places a telemetry value may cross
# the device boundary (docs/INVARIANTS.md §7, rule telemetry-host-sync).
FLUSH_FUNCTIONS = ("flush_metrics",)


def init_metrics():
    """Zero accumulator (max slots at -inf, min slots at +inf) — built
    fresh inside every phase trace, never checkpointed."""
    init = np.zeros((NUM_SLOTS,), np.float32)
    init[_I["loss_max"]] = -np.inf
    init[_I["disp_max"]] = -np.inf
    init[_I["alive_min"]] = np.inf
    return jnp.asarray(init)


def accumulate(acc, *, loss, disp, code, event_bytes_all: float,
               event_bytes_inner: float, n_alive, n_straggle):
    """Fold one step into the accumulator — pure jnp, traced inside the
    scan body. ``code`` is the averaging decision (0 none / 1 inner /
    2 all); ``event_bytes_*`` are static per-event wire costs priced by
    ``topology.comm_bytes``; ``n_alive`` / ``n_straggle`` come from the
    fault plan's pure per-step streams (constants without one)."""
    loss = jnp.asarray(loss, jnp.float32)
    disp = jnp.asarray(disp, jnp.float32)
    n_alive = jnp.asarray(n_alive, jnp.float32)
    n_straggle = jnp.asarray(n_straggle, jnp.float32)
    inner = (code == 1).astype(jnp.float32)
    allv = (code == 2).astype(jnp.float32)
    add = jnp.stack([
        jnp.float32(1.0), loss, jnp.float32(0.0), disp, jnp.float32(0.0),
        inner, allv,
        inner * jnp.float32(event_bytes_inner)
        + allv * jnp.float32(event_bytes_all),
        n_alive, jnp.float32(0.0), n_straggle,
    ])
    acc = acc + add
    acc = acc.at[_I["loss_max"]].max(loss)
    acc = acc.at[_I["disp_max"]].max(disp)
    acc = acc.at[_I["alive_min"]].min(n_alive)
    return acc


def flush_metrics(vec) -> dict:
    """HOST-side flush: the per-phase accumulator vector (already
    fetched with the phase trace — this adds no device sync of its own
    when handed the device_get'd value) rendered as a plain-float dict,
    raw slots plus the derived means/rates the report table shows."""
    v = np.asarray(vec, dtype=np.float64).reshape(-1)
    if v.shape[0] != NUM_SLOTS:
        raise ValueError(
            f"metrics vector has {v.shape[0]} slots, expected "
            f"{NUM_SLOTS} ({', '.join(SLOT_NAMES)})")
    out = {name: float(v[i]) for i, name in enumerate(SLOT_NAMES)}
    steps = out["steps"]
    if steps < 1:
        raise ValueError("flush_metrics needs a phase of >= 1 steps")
    out["steps"] = int(steps)
    out["events_inner"] = int(out["events_inner"])
    out["events_all"] = int(out["events_all"])
    out["events"] = out["events_inner"] + out["events_all"]
    out["loss_mean"] = out.pop("loss_sum") / steps
    out["disp_mean"] = out.pop("disp_sum") / steps
    alive_sum = out.pop("alive_sum")
    out["alive_mean"] = alive_sum / steps
    straggle_sum = out.pop("straggle_sum")
    out["straggle_rate"] = (straggle_sum / alive_sum if alive_sum > 0
                            else 0.0)
    return out

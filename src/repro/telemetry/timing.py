"""Wall-clock measurement and profiling hooks, lifted from bench_engine.

The benchmark's timing policy — one untimed warmup call (compile +
cache fill), then best-of-``reps`` wall-clock — lives here so every
caller (benchmarks, the report CLI, ad-hoc measurements) shares one
definition of "ms/step". ``block_until_ready`` semantics are explicit:
jax dispatch is asynchronous, so a timed callable that returns device
values without blocking measures dispatch latency, not compute —
:func:`timed` and :func:`time_run` block on the returned pytree by
default (``block=False`` opts out for callables that already
synchronize, e.g. anything ending in a host ``device_get``).

:func:`profile_trace` wraps a block in ``jax.profiler.trace`` when
given a directory (``train.py --profile-dir``), and is a no-op
otherwise — callers keep one unconditional ``with`` statement.
"""
from __future__ import annotations

import contextlib
import time


def _block(out):
    import jax
    if out is not None:
        jax.block_until_ready(out)
    return out


def timed(fn, *, block: bool = False) -> float:
    """Seconds for ONE ``fn()`` call. ``block=True`` blocks on the
    returned pytree before stopping the clock."""
    t0 = time.perf_counter()
    out = fn()
    if block:
        _block(out)
    return time.perf_counter() - t0


def time_run(fn, steps: int, *, reps: int = 3, warmup: int = 1,
             block: bool = False) -> float:
    """ms/step: best of ``reps`` timed ``fn()`` calls after ``warmup``
    untimed ones (compile; warmup policy is explicit so a caller can
    measure cold-start with ``warmup=0``)."""
    if steps < 1:
        raise ValueError(f"time_run needs steps >= 1, got {steps}")
    if reps < 1:
        raise ValueError(f"time_run needs reps >= 1, got {reps}")
    for _ in range(warmup):
        out = fn()
        if block:
            _block(out)
    best = min(timed(fn, block=block) for _ in range(reps))
    return best / steps * 1e3


@contextlib.contextmanager
def profile_trace(profile_dir: str | None):
    """``jax.profiler.trace(profile_dir)`` when a directory is given,
    else a no-op — phase-level capture behind one ``with``."""
    if not profile_dir:
        yield
        return
    import jax
    with jax.profiler.trace(profile_dir):
        yield

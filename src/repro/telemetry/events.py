"""Structured run events: versioned JSONL records, sinks, and RunLog.

One schema for everything a run emits — environment provenance
(``run_meta``), per-phase aggregates (``phase_metrics``), and the
point events (``averaging_event``, ``fault_event``, ``resize_event``,
``checkpoint_event``). Records are flat JSON dicts stamped with
``{"v": TELEMETRY_VERSION, "type": <record type>}``; a reader refuses
records from a NEWER writer (mirroring the checkpoint ladder's
future-version refusal) and unknown record types.

Sinks implement the tiny :class:`TelemetrySink` protocol
(``emit(record)`` / ``close()``): :class:`JsonlSink` appends one JSON
line per record, :class:`MemorySink` collects them in a list (tests),
:class:`NullSink` drops them. Drivers emit unconditionally through
whatever sink they were handed.

:class:`RunLog` reads a record stream back and — via :meth:`history` —
reconstructs the legacy history dict (``loss`` / ``dispersion`` /
``disp_trace`` / ``averages`` / ``eval`` / ``worker_eval`` [/
``resizes``]) that :meth:`repro.core.engine.PhaseEngine.run` returns,
key for key: the events layer supersedes the hand-rolled hist dicts
without breaking anything that consumes them. :func:`init_history` is
the one shared constructor behind those dicts (previously four
copy-pasted literals across the engine and elastic drivers).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

TELEMETRY_VERSION = 1

RECORD_TYPES = (
    "run_meta",
    "phase_metrics",
    "averaging_event",
    "fault_event",
    "resize_event",
    "checkpoint_event",
)


def init_history(*, resizes: bool = False) -> dict:
    """The engine drivers' history dict — ONE constructor for the keys
    every driver (``run``, ``run_host``, ``_run_host_faults``,
    ``run_elastic``) must agree on."""
    hist = {"loss": [], "dispersion": [], "disp_trace": [],
            "averages": 0, "eval": [], "worker_eval": []}
    if resizes:
        hist["resizes"] = []
    return hist


def make_record(rtype: str, **fields) -> dict:
    """A versioned record dict. ``rtype`` must be one of
    :data:`RECORD_TYPES`; field values must be JSON-serializable."""
    if rtype not in RECORD_TYPES:
        raise ValueError(
            f"unknown telemetry record type {rtype!r} (expected one of "
            f"{RECORD_TYPES})")
    rec = {"v": TELEMETRY_VERSION, "type": rtype}
    rec.update(fields)
    return rec


def parse_record(obj) -> dict:
    """Validate one record (a dict, or a JSON line to parse). Refuses
    records written by a newer telemetry version and unknown types —
    silently misreading a future schema is worse than failing."""
    if isinstance(obj, (str, bytes)):
        obj = json.loads(obj)
    if not isinstance(obj, dict):
        raise ValueError(f"telemetry record must be a dict, got "
                         f"{type(obj).__name__}")
    v = obj.get("v")
    if not isinstance(v, int):
        raise ValueError("telemetry record has no integer 'v' version "
                         f"field: {obj!r}")
    if v > TELEMETRY_VERSION:
        raise ValueError(
            f"telemetry record version {v} is newer than this reader "
            f"(TELEMETRY_VERSION={TELEMETRY_VERSION}) — read it with "
            "the build that wrote it")
    rtype = obj.get("type")
    if rtype not in RECORD_TYPES:
        raise ValueError(
            f"unknown telemetry record type {rtype!r} (expected one of "
            f"{RECORD_TYPES})")
    return obj


def _git_sha() -> str | None:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=5)
        if out.returncode == 0:
            return out.stdout.strip()
    except Exception:
        pass
    return None


def run_meta_record(config: dict | None = None, **extra) -> dict:
    """The provenance record every sink stream should start with: jax
    version, backend, device kind and host device count, python, git
    sha — plus the run's ``config`` dict verbatim."""
    import jax
    devices = jax.devices()
    return make_record(
        "run_meta",
        jax_version=jax.__version__,
        backend=jax.default_backend(),
        device_kind=devices[0].device_kind if devices else None,
        device_count=len(devices),
        python_version=sys.version.split()[0],
        platform=sys.platform,
        git_sha=_git_sha(),
        config=dict(config or {}),
        **extra)


# --------------------------------------------------------------------------
# Sinks
# --------------------------------------------------------------------------

class TelemetrySink:
    """Protocol: ``emit(record)`` accepts one :func:`make_record` dict;
    ``close()`` releases resources. Usable as a context manager."""

    def emit(self, record: dict) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class NullSink(TelemetrySink):
    """Drops every record — the no-telemetry sink."""

    def emit(self, record: dict) -> None:
        pass


class MemorySink(TelemetrySink):
    """Collects records in :attr:`records` (tests / in-process use)."""

    def __init__(self):
        self.records: list = []

    def emit(self, record: dict) -> None:
        self.records.append(parse_record(record))


class JsonlSink(TelemetrySink):
    """Appends one JSON line per record to ``path`` (parent directories
    created), flushing per emit so a crashed run keeps its telemetry."""

    def __init__(self, path: str):
        self.path = path
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        self._f = open(path, "w")

    def emit(self, record: dict) -> None:
        self._f.write(json.dumps(parse_record(record), default=float))
        self._f.write("\n")
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


# --------------------------------------------------------------------------
# Reader
# --------------------------------------------------------------------------

class RunLog:
    """A validated, in-order view over one run's records."""

    def __init__(self, records):
        self.records = [parse_record(r) for r in records]

    @classmethod
    def load(cls, path: str) -> "RunLog":
        with open(path) as f:
            return cls(line for line in f if line.strip())

    def of_type(self, rtype: str) -> list:
        if rtype not in RECORD_TYPES:
            raise ValueError(f"unknown record type {rtype!r}")
        return [r for r in self.records if r["type"] == rtype]

    @property
    def meta(self) -> dict | None:
        metas = self.of_type("run_meta")
        return metas[0] if metas else None

    @property
    def phases(self) -> list:
        return self.of_type("phase_metrics")

    def history(self) -> dict:
        """The legacy history dict, reconstructed exactly: per-phase
        ``loss_trace`` / ``disp_trace`` entries concatenate into the
        recorded traces, averaging events carry the event-step
        dispersion and count, resize events the membership changes.
        ``eval`` / ``worker_eval`` hold host-callback results that
        never serialize; they reconstruct empty."""
        resizes = self.of_type("resize_event")
        hist = init_history(resizes=bool(resizes))
        for ph in self.phases:
            hist["loss"].extend(tuple(e) for e in ph.get("loss_trace", []))
            hist["disp_trace"].extend(
                tuple(e) for e in ph.get("disp_trace", []))
        for ev in self.of_type("averaging_event"):
            hist["dispersion"].append((ev["step"], ev["dispersion"]))
            hist["averages"] += 1
        for ev in resizes:
            hist["resizes"].append((ev["step"], ev["old_m"], ev["new_m"]))
        return hist

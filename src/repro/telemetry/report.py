"""Render a run's telemetry JSONL as a per-phase table.

    PYTHONPATH=src python -m repro.telemetry.report run.jsonl

Each row is one ``phase_metrics`` record (one compiled ``run_phase``
dispatch): steps and wall-clock throughput, the mean/max loss, the
measured Eq. 4 dispersion envelope, averaging events and the nominal
wire bytes they shipped (``topology.comm_bytes`` pricing), and fault
occupancy (alive / straggle).

When the stream's ``run_meta`` carries the run recipe (``lr``,
``momentum``, ``workers`` — the train CLI emits them), the table adds
the ``variance_model`` envelope prediction: the per-worker gradient
variance is calibrated once from the FIRST phase's measured mean
dispersion (the prediction is linear in sigma^2, so one phase pins it),
then every phase's pre-event envelope is predicted at that phase's
mean inter-event gap via
:func:`repro.core.variance_model.predict_post_resize_dispersion` —
the ``x pred`` column is measured max / predicted, the single-number
check that the run tracks the paper's variance envelope.
"""
from __future__ import annotations

import argparse

from repro.telemetry.events import RunLog


def _phase_gap(ph: dict) -> int:
    """Mean inter-event gap of the phase (its whole length when no
    event fired) — the K the envelope prediction is evaluated at."""
    steps = max(int(ph["steps"]), 1)
    events = int(ph.get("events", 0))
    return max(1, round(steps / events)) if events else steps


def _calibrate(phases: list, meta: dict | None):
    """(sigma2_hat, lr, momentum, workers) from the first phase, or
    None when the stream lacks the recipe or a usable signal."""
    if meta is None or not phases:
        return None
    cfg = meta.get("config") or {}
    lr = cfg.get("lr")
    workers = cfg.get("workers")
    if not lr or not workers or int(workers) < 2:
        return None
    momentum = float(cfg.get("momentum") or 0.0)
    first = phases[0]
    d0 = float(first.get("disp_mean") or 0.0)
    if d0 <= 0.0:
        return None
    from repro.core.variance_model import predict_post_resize_dispersion
    # mid-window mean: dispersion resets at each event, so the phase
    # MEAN sits near the envelope at half the inter-event gap
    k_cal = max(1, round((_phase_gap(first) + 1) / 2))
    unit = predict_post_resize_dispersion(
        [1.0] * int(workers), lr=float(lr), steps=k_cal,
        momentum=momentum)["predicted_dispersion"]
    if unit <= 0.0:
        return None
    return d0 / unit, float(lr), momentum, int(workers)


def _predict(cal, ph: dict) -> float | None:
    if cal is None:
        return None
    sigma2, lr, momentum, workers = cal
    from repro.core.variance_model import predict_post_resize_dispersion
    return predict_post_resize_dispersion(
        [sigma2] * workers, lr=lr, steps=_phase_gap(ph),
        momentum=momentum)["predicted_dispersion"]


def _fmt(x, width: int, prec: int = 3) -> str:
    if x is None:
        return "-".rjust(width)
    if isinstance(x, int):
        return f"{x:{width}d}"
    return f"{x:{width}.{prec}g}"


def render(log: RunLog) -> str:
    """The report as one printable string."""
    lines = []
    meta = log.meta
    if meta is not None:
        cfg = meta.get("config") or {}
        recipe = " ".join(f"{k}={cfg[k]}" for k in sorted(cfg)
                          if cfg[k] is not None)
        lines.append(
            f"run: jax {meta.get('jax_version')} "
            f"({meta.get('backend')}, {meta.get('device_count')}x "
            f"{meta.get('device_kind')}), git {meta.get('git_sha')}")
        if recipe:
            lines.append(f"config: {recipe}")
    phases = log.phases
    if not phases:
        lines.append("no phase_metrics records")
        return "\n".join(lines)
    cal = _calibrate(phases, meta)
    hdr = (f"{'phase':>5} {'steps':>7} {'steps/s':>8} {'loss':>9} "
           f"{'disp_mean':>9} {'disp_max':>9} {'disp_pred':>9} "
           f"{'x pred':>7} {'events':>6} {'bytes':>10} {'B/event':>9} "
           f"{'alive':>6} {'strag%':>6}")
    lines.append(hdr)
    lines.append("-" * len(hdr))
    tot_steps = tot_events = 0
    tot_bytes = tot_wall = 0.0
    for i, ph in enumerate(phases):
        steps = int(ph["steps"])
        events = int(ph.get("events", 0))
        byts = float(ph.get("comm_bytes", 0.0))
        wall = float(ph.get("wall_s") or 0.0)
        sps = steps / wall if wall > 0 else None
        pred = _predict(cal, ph)
        dmax = ph.get("disp_max")
        ratio = (dmax / pred if pred and dmax is not None else None)
        lines.append(" ".join([
            f"{i:>5d}",
            f"{ph.get('t0', '?')}-{ph.get('t1', '?')}".rjust(7),
            _fmt(sps, 8),
            _fmt(ph.get("loss_mean"), 9, 4),
            _fmt(ph.get("disp_mean"), 9),
            _fmt(dmax, 9),
            _fmt(pred, 9),
            _fmt(ratio, 7, 2),
            f"{events:>6d}",
            _fmt(byts, 10, 4),
            _fmt(byts / events if events else None, 9, 4),
            _fmt(ph.get("alive_mean"), 6, 3),
            _fmt(100.0 * float(ph.get("straggle_rate") or 0.0), 6, 2),
        ]))
        tot_steps += steps
        tot_events += events
        tot_bytes += byts
        tot_wall += wall
    lines.append("-" * len(hdr))
    sps = tot_steps / tot_wall if tot_wall > 0 else None
    lines.append(
        f"total: {tot_steps} steps, {tot_events} events, "
        f"{tot_bytes:.4g} B/worker on the wire"
        + (f", {sps:.1f} steps/s" if sps else ""))
    extra = []
    for rtype in ("fault_event", "resize_event", "checkpoint_event"):
        n = len(log.of_type(rtype))
        if n:
            extra.append(f"{n} {rtype}")
    if extra:
        lines.append("events: " + ", ".join(extra))
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.report",
        description="Render a telemetry JSONL run log as a per-phase "
                    "table.")
    ap.add_argument("path", help="telemetry JSONL file "
                                 "(train.py --telemetry <path>)")
    args = ap.parse_args(argv)
    print(render(RunLog.load(args.path)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

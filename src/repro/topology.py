"""Gossip topologies: mixing-matrix averaging as a scenario axis.

The paper asks *when* averaging helps; its operator is always the full
worker mean w_i <- (1/M) Σ_j w_j. This module generalizes every
averaging event to ONE application of a doubly-stochastic mixing matrix

    w_i  <-  Σ_j W_ij w_j            (each worker keeps its own mixed row)

over a communication graph — ring, 2-D torus, hypercube/exponential
graph, random gossip pairs — which interpolates continuously between
"no averaging" (W = I) and "full averaging" (W = 11ᵀ/M) at a fraction
of the communication cost. Local/K-step averaging analyses (Zhou & Cong
1708.01012; Yu et al. 1807.06629) are the degenerate full-graph case.

How fast partial mixing kills the paper's Eq. 4 worker dispersion is
governed by the matrix spectrum: writing a worker state as consensus +
deviation, one mix contracts the deviation by at most λ₂(W) — the
second-largest eigenvalue *modulus* (SLEM) — so each event multiplies
the dispersion by ≤ λ₂². :attr:`Topology.spectral_gap` exposes
``1 - λ₂`` for the theory hooks in ``repro.core.theory``
(:func:`~repro.core.theory.mixing_contraction`,
:func:`~repro.core.theory.mixed_dispersion_fixed_point`).

Builders (all symmetric and doubly stochastic; deterministic graphs use
Metropolis–Hastings edge weights, uniform ``1/(deg+1)`` on regular
graphs):

  - :meth:`Topology.full`         W = 11ᵀ/M (gap 1). The engine lowers
    this to the existing fused-mean path, so it is *bit-identical* to
    running without a topology.
  - :meth:`Topology.ring`         degree-2 cycle, M >= 3.
  - :meth:`Topology.torus`        2-D periodic grid a×b (a the largest
    divisor ≤ √M), composite M.
  - :meth:`Topology.hypercube`    exponential graph: neighbors at
    i XOR 2^k, M a power of two; degree log₂M, gap independent of M.
  - :meth:`Topology.groups`       block-diagonal W: full mean within g
    contiguous groups — exactly the engine's existing ``inner_groups``
    block mean, now expressed as a mixing matrix (gap 0: the graph is
    disconnected). Lowers to the fused group-mean path bit-identically.
  - :meth:`Topology.gossip_pairs` per-EVENT random perfect matching:
    each worker averages with one partner (W = ½(I + P), P an
    involution permutation). The matrix is sampled per event as a pure
    function of (decision key, step) — see :func:`gossip_matrix` — so
    runs replay bit-identically across engine paths, phase blockings
    and checkpoint/resume. The declared gap is that of the *expected*
    matrix E[W] = ½I + ½(J−I)/(M−1).
  - :meth:`Topology.disconnected` W = I: events fire (schedule state
    and event counts advance) but mix nothing — the no-communication
    endpoint of the axis.

``repro.core.engine.PhaseEngine(topology=...)`` wires a topology
through every runtime path; ``repro.kernels.opt_step`` /
``repro.kernels.avg_disp`` fuse the (M,M)@(M,P) mix with the local
update and the Eq. 4 dispersion in one pass.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import cached_property

import numpy as np

KINDS = ("full", "ring", "torus", "hypercube", "groups", "gossip_pairs",
         "disconnected")

#: kinds whose events need the generic W @ plane mix; ``full`` and
#: ``groups`` lower to the engine's existing (bit-identical) fused
#: mean / group-mean paths instead
MIX_KINDS = ("ring", "torus", "hypercube", "gossip_pairs", "disconnected")

_GOSSIP_SALT = 0x676F73  # "gos": decorrelates the per-event matching
#                        # stream from the stochastic schedule's
#                        # fold_in(key, step) Bernoulli stream


def gossip_matrix(key, step, num_workers: int):
    """The per-event gossip mixing matrix: a uniformly random perfect
    matching of the M workers, each pair averaging — W = ½(I + P) with
    P the matching's (involution) permutation matrix.

    A pure function of ``(key, step)`` via a salted double ``fold_in``,
    exactly like the stochastic schedule's Bernoulli draws: the same
    checkpointed decision key replays the same matchings on resume, on
    every engine path, and on every shard of a sharded run. Traceable
    (``step`` may be a traced int32 scalar).
    """
    import jax
    import jax.numpy as jnp
    assert num_workers % 2 == 0, num_workers
    k = jax.random.fold_in(jax.random.fold_in(key, _GOSSIP_SALT), step)
    perm = jax.random.permutation(k, num_workers)
    a, b = perm[0::2], perm[1::2]
    partner = (jnp.zeros(num_workers, jnp.int32).at[a].set(b)
               .at[b].set(a))
    eye = jnp.eye(num_workers, dtype=jnp.float32)
    return 0.5 * (eye + eye[partner])


def mix_tree(worker_tree, W):
    """Apply the mixing matrix along the worker axis of every leaf —
    the tree-path twin of ``W @ plane``. Computed in float32 and cast
    back to the leaf dtype, like ``repro.core.averaging.average_all``.
    """
    import jax
    import jax.numpy as jnp

    def mx(x):
        xf = x.astype(jnp.float32).reshape(x.shape[0], -1)
        out = jnp.dot(W, xf, preferred_element_type=jnp.float32)
        return out.reshape(x.shape).astype(x.dtype)

    return jax.tree.map(mx, worker_tree)


def _metropolis(adj: np.ndarray) -> np.ndarray:
    """Metropolis–Hastings weights for a symmetric adjacency (no self
    loops): W_ij = 1/(1 + max(deg_i, deg_j)) on edges, diagonal fills
    each row to 1. Symmetric and doubly stochastic for ANY graph; on a
    d-regular graph it is the uniform 1/(d+1) weighting."""
    deg = adj.sum(1)
    W = np.where(adj, 1.0 / (1.0 + np.maximum(deg[:, None], deg[None, :])),
                 0.0)
    np.fill_diagonal(W, 1.0 - W.sum(1))
    return W


@dataclass(frozen=True, eq=False)  # eq=False: hash by identity for jit
class Topology:
    """A communication graph and its doubly-stochastic mixing matrix.

    ``matrix`` is the static (M, M) float64 W for deterministic kinds
    and None for ``gossip_pairs`` (whose W is sampled per event —
    :meth:`mixing_matrix`). Build through the classmethods, which
    validate the worker count eagerly with actionable messages (the
    same errors ``train.py --topology`` surfaces at parse time)."""
    kind: str
    num_workers: int
    matrix: np.ndarray | None = field(repr=False)
    groups: int = 1

    # ---- builders --------------------------------------------------------
    @classmethod
    def full(cls, num_workers: int) -> "Topology":
        if num_workers < 1:
            raise ValueError(f"full topology needs >= 1 worker, "
                             f"got {num_workers}")
        W = np.full((num_workers, num_workers), 1.0 / num_workers)
        return cls("full", num_workers, W)

    @classmethod
    def ring(cls, num_workers: int) -> "Topology":
        if num_workers < 3:
            raise ValueError(
                f"ring topology needs >= 3 workers (got {num_workers}): "
                "with 2 the two neighbors coincide — use 'full' (the "
                "pair mean) instead")
        m = num_workers
        i = np.arange(m)
        adj = np.zeros((m, m), bool)
        adj[i, (i + 1) % m] = adj[i, (i - 1) % m] = True
        return cls("ring", m, _metropolis(adj))

    @staticmethod
    def torus_sides(num_workers: int) -> tuple[int, int]:
        """The a×b factorization a torus uses: a is the largest divisor
        of M with 2 <= a <= √M. Raises for prime / too-small M."""
        m = num_workers
        for a in range(math.isqrt(m), 1, -1):
            if m % a == 0:
                return a, m // a
        raise ValueError(
            f"torus topology needs a composite worker count that "
            f"factors into a 2-D grid (got {m}): use 'ring' for a "
            "1-D cycle instead")

    @classmethod
    def torus(cls, num_workers: int) -> "Topology":
        a, b = cls.torus_sides(num_workers)
        m = num_workers
        adj = np.zeros((m, m), bool)
        for n in range(m):
            i, j = divmod(n, b)
            for ni, nj in (((i + 1) % a, j), ((i - 1) % a, j),
                           (i, (j + 1) % b), (i, (j - 1) % b)):
                nb = ni * b + nj
                if nb != n:
                    adj[n, nb] = True
        return cls("torus", m, _metropolis(adj))

    @classmethod
    def hypercube(cls, num_workers: int) -> "Topology":
        m = num_workers
        if m < 2 or m & (m - 1):
            raise ValueError(
                f"hypercube (exponential-graph) topology needs a "
                f"power-of-two worker count >= 2, got {m}")
        adj = np.zeros((m, m), bool)
        for n in range(m):
            for k in range(m.bit_length() - 1):
                adj[n, n ^ (1 << k)] = True
        return cls("hypercube", m, _metropolis(adj))

    @classmethod
    def blocks(cls, num_workers: int, groups: int) -> "Topology":
        """Block-diagonal W: full mean within ``groups`` contiguous
        worker groups — the existing ``inner_groups`` block mean as a
        mixing matrix. Disconnected for groups > 1, so the spectral
        gap is 0 (no global consensus)."""
        m = num_workers
        if groups < 1 or m % groups:
            raise ValueError(
                f"groups topology needs a group count >= 1 dividing the "
                f"worker count, got groups={groups} for M={m}")
        per = m // groups
        W = np.zeros((m, m))
        for g in range(groups):
            W[g * per:(g + 1) * per, g * per:(g + 1) * per] = 1.0 / per
        return cls("groups", m, W, groups=groups)

    @classmethod
    def gossip_pairs(cls, num_workers: int) -> "Topology":
        m = num_workers
        if m < 2 or m % 2:
            raise ValueError(
                f"gossip_pairs topology pairs the workers into a "
                f"perfect matching and needs an even count >= 2, "
                f"got {m}")
        return cls("gossip_pairs", m, None)

    @classmethod
    def disconnected(cls, num_workers: int) -> "Topology":
        if num_workers < 1:
            raise ValueError(f"disconnected topology needs >= 1 worker, "
                             f"got {num_workers}")
        return cls("disconnected", num_workers, np.eye(num_workers))

    @classmethod
    def build(cls, kind: str, num_workers: int, *,
              groups: int | None = None) -> "Topology":
        """CLI dispatcher: one builder per kind, same eager validation.
        ``groups`` defaults to 2 only when omitted — an explicit invalid
        count (e.g. 0) still hits the builder's validation."""
        if kind not in KINDS:
            raise ValueError(f"unknown topology kind {kind!r}; "
                             f"pick one of {KINDS}")
        if kind == "groups":
            return cls.blocks(num_workers, 2 if groups is None else groups)
        return getattr(cls, kind)(num_workers)

    # ---- spectrum / communication ----------------------------------------
    def expected_matrix(self) -> np.ndarray:
        """E[W] in float64: the matrix itself for deterministic kinds;
        for gossip pairs, each worker's partner is uniform over the
        others — E[W] = ½I + ½(J−I)/(M−1)."""
        if self.matrix is not None:
            return np.asarray(self.matrix, np.float64)
        m = self.num_workers
        return (0.5 * np.eye(m)
                + 0.5 * (np.ones((m, m)) - np.eye(m)) / (m - 1))

    @cached_property
    def slem(self) -> float:
        """Second-largest eigenvalue modulus of E[W] — the per-event
        contraction factor of the consensus deviation (dispersion
        contracts by ≤ slem² per mix)."""
        ev = np.linalg.eigvalsh(self.expected_matrix())  # ascending
        if len(ev) < 2:
            return 0.0
        # clamp eigensolver roundoff: a doubly-stochastic W has its
        # whole spectrum in [-1, 1]
        return float(min(1.0, max(abs(ev[0]), ev[-2], 0.0)))

    @cached_property
    def spectral_gap(self) -> float:
        """1 - λ₂(W), λ₂ the SLEM of the expected mixing matrix: 1 for
        ``full`` (one mix reaches consensus), 0 for ``disconnected``
        and ``groups`` (the graph has no global consensus direction)."""
        return 1.0 - self.slem

    def effective_spectral_gap(self, alive) -> float:
        """Spectral gap of the fault-degraded expected mixing matrix,
        restricted to the alive workers.

        Dead rows are masked out the way ``repro.faults.degraded_matrix``
        does at runtime — off-diagonal mass to/from dead workers is
        dropped and the lost weight refilled on the diagonal — and the
        gap is the SLEM gap of the alive-alive submatrix (dead workers
        are identity rows: they neither mix nor count toward consensus).
        All alive recovers :attr:`spectral_gap` (up to eigensolver
        roundoff); a cut that disconnects the alive subgraph returns
        0.0."""
        a = (np.asarray(alive, np.float64).reshape(-1) > 0)
        if a.shape[0] != self.num_workers:
            raise ValueError(f"alive has {a.shape[0]} rows, topology "
                             f"has {self.num_workers}")
        idx = np.flatnonzero(a)
        if len(idx) == 0:
            raise ValueError("effective_spectral_gap needs >= 1 alive "
                             "worker")
        if len(idx) == 1:
            return 1.0  # a single alive worker is trivially at consensus
        W = self.expected_matrix()
        af = a.astype(np.float64)
        off = W * (1.0 - np.eye(self.num_workers)) * af[:, None] * af[None, :]
        Wm = off + np.diag(1.0 - off.sum(1))
        sub = Wm[np.ix_(idx, idx)]
        ev = np.linalg.eigvalsh(sub)
        slem = float(min(1.0, max(abs(ev[0]), ev[-2], 0.0)))
        return 1.0 - slem

    @cached_property
    def comm_degree(self) -> float:
        """Mean per-event messages per worker: the off-diagonal nonzero
        count of a row of one event's W (for gossip pairs: exactly the
        1 partner). The unit of the benchmark's matched-communication
        sweeps — one full-mean event costs M−1 where a ring event
        costs 2."""
        if self.kind == "gossip_pairs":
            return 1.0
        W = self.expected_matrix()
        off = (np.abs(W) > 1e-12) & ~np.eye(self.num_workers, dtype=bool)
        return float(off.sum(1).mean())

    # ---- per-event matrix ------------------------------------------------
    def mixing_matrix(self, step=0, key=None):
        """This event's W as an (M, M) float32 jnp array. Deterministic
        kinds ignore ``(step, key)``; ``gossip_pairs`` samples the
        matching from them (:func:`gossip_matrix`)."""
        import jax.numpy as jnp
        if self.kind == "gossip_pairs":
            assert key is not None, \
                "gossip_pairs needs the decision key to sample a matching"
            return gossip_matrix(key, step, self.num_workers)
        return jnp.asarray(self.matrix, jnp.float32)


def comm_bytes(topology: "Topology", events: int, p: int,
               wire: str = "f32") -> int:
    """Bytes ONE worker puts on the wire for ``events`` averaging
    events over ``topology``, shipping (1, P) rows in the ``wire``
    format: events x comm_degree messages, each one encoded row of
    :func:`repro.core.compress.wire_row_bytes`. The common currency of
    the timing x topology x precision budget ladder — the
    ``adaptive_bytes`` schedule spends exactly this per event, the
    benchmark's matched-budget sweeps equalize it across arms, and the
    telemetry plane's per-phase ``comm_bytes`` slot prices each
    on-device averaging event at exactly this cost
    (:meth:`repro.core.engine.PhaseEngine._event_bytes`)."""
    from repro.core.compress import wire_row_bytes
    return int(round(events * topology.comm_degree)) * wire_row_bytes(
        p, wire)

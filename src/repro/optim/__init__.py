"""Optimizers: init/apply pairs over pytrees (kept dependency-free).

Each optimizer exposes:
    init(params)                      -> opt_state
    apply(params, grads, state, step) -> (params, state)
"""
from repro.optim.sgd import SGD, Momentum, schedules  # noqa: F401
from repro.optim.adamw import AdamW  # noqa: F401

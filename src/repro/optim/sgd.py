"""SGD / momentum SGD with the paper's learning-rate schedules."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


class schedules:
    """Learning-rate schedules. Every schedule casts ``step`` to float32
    first, so host-path calls with a Python int produce the same strong
    float32 value (not a weak / float64-promoted one) as the engine's
    traced int32 step — traces stay bit-identical across both paths."""

    @staticmethod
    def constant(lr: float) -> Callable:
        return lambda step: jnp.asarray(lr, jnp.float32)

    @staticmethod
    def inverse(alpha: float, d: float) -> Callable:
        """The paper's §3.1 schedule: alpha / (t + d)."""
        return lambda step: (jnp.asarray(alpha, jnp.float32)
                             / (jnp.asarray(step, jnp.float32) + d))

    @staticmethod
    def exponential_epoch(lr0: float, decay: float, steps_per_epoch: int):
        """The paper's §3.2 CNN schedule: x``decay`` each epoch."""
        def fn(step):
            step = jnp.asarray(step, jnp.float32)
            epoch = jnp.floor(step / steps_per_epoch)
            return jnp.asarray(lr0, jnp.float32) * decay ** epoch
        return fn


def _scalars(lr, c1=1.0, c2=1.0):
    """(4,) float32 dynamic-scalar vector for repro.kernels.opt_step:
    [lr, bias-correction c1, bias-correction c2, unused]."""
    z = jnp.zeros((), jnp.float32)
    return jnp.stack([jnp.asarray(lr, jnp.float32).reshape(()),
                      jnp.asarray(c1, jnp.float32).reshape(()),
                      jnp.asarray(c2, jnp.float32).reshape(()), z])


@dataclass(frozen=True)
class SGD:
    lr: Callable | float = 0.01

    # plane protocol (repro.core.flat.FlatOptSpec / repro.kernels.opt_step)
    plane_kind = "sgd"
    state_planes = 0

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def init(self, params):
        return ()

    def apply(self, params, grads, state, step):
        lr = self._lr(step)
        new = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new, state

    def plane_hypers(self) -> dict:
        """Static hyperparameters for the fused plane update."""
        return {}

    def plane_scalars(self, step):
        """Per-step dynamic scalars (see ``_scalars``)."""
        return _scalars(self._lr(step))


@dataclass(frozen=True)
class Momentum:
    """Heavy-ball momentum (the paper's CNN recipe: lr .01, mu .9)."""
    lr: Callable | float = 0.01
    mu: float = 0.9
    nesterov: bool = False

    plane_kind = "momentum"
    state_planes = 1  # velocity

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def init(self, params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def apply(self, params, grads, state, step):
        lr = self._lr(step)
        vel = jax.tree.map(
            lambda g, v: self.mu * v + g.astype(jnp.float32), grads, state)
        new = jax.tree.map(
            lambda p, g, v: (p.astype(jnp.float32) - lr * (
                g.astype(jnp.float32) + self.mu * v if self.nesterov else v
            )).astype(p.dtype),
            params, grads, vel)
        return new, vel

    def plane_hypers(self) -> dict:
        return {"mu": self.mu, "nesterov": self.nesterov}

    def plane_scalars(self, step):
        return _scalars(self._lr(step))

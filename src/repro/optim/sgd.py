"""SGD / momentum SGD with the paper's learning-rate schedules."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


class schedules:
    @staticmethod
    def constant(lr: float) -> Callable:
        return lambda step: jnp.asarray(lr, jnp.float32)

    @staticmethod
    def inverse(alpha: float, d: float) -> Callable:
        """The paper's §3.1 schedule: alpha / (t + d)."""
        return lambda step: jnp.asarray(alpha, jnp.float32) / (step + d)

    @staticmethod
    def exponential_epoch(lr0: float, decay: float, steps_per_epoch: int):
        """The paper's §3.2 CNN schedule: x``decay`` each epoch."""
        def fn(step):
            epoch = jnp.floor(step / steps_per_epoch)
            return jnp.asarray(lr0, jnp.float32) * decay ** epoch
        return fn


@dataclass(frozen=True)
class SGD:
    lr: Callable | float = 0.01

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def init(self, params):
        return ()

    def apply(self, params, grads, state, step):
        lr = self._lr(step)
        new = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new, state


@dataclass(frozen=True)
class Momentum:
    """Heavy-ball momentum (the paper's CNN recipe: lr .01, mu .9)."""
    lr: Callable | float = 0.01
    mu: float = 0.9
    nesterov: bool = False

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def init(self, params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def apply(self, params, grads, state, step):
        lr = self._lr(step)
        vel = jax.tree.map(
            lambda g, v: self.mu * v + g.astype(jnp.float32), grads, state)
        new = jax.tree.map(
            lambda p, g, v: (p.astype(jnp.float32) - lr * (
                g.astype(jnp.float32) + self.mu * v if self.nesterov else v
            )).astype(p.dtype),
            params, grads, vel)
        return new, vel

"""AdamW (used by the LM examples; fp32 moments over bf16 params)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def init(self, params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def apply(self, params, grads, state, step):
        lr = self._lr(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - self.b1 ** t
        c2 = 1.0 - self.b2 ** t

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m2 = self.b1 * m + (1 - self.b1) * g
            v2 = self.b2 * v + (1 - self.b2) * g * g
            d = (m2 / c1) / (jnp.sqrt(v2 / c2) + self.eps)
            p32 = p.astype(jnp.float32)
            p32 = p32 - lr * (d + self.weight_decay * p32)
            return p32.astype(p.dtype), m2, v2

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                      is_leaf=lambda t: isinstance(t, tuple))
        return pick(0), {"m": pick(1), "v": pick(2)}

"""AdamW (used by the LM examples; fp32 moments over bf16 params)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0

    plane_kind = "adamw"
    state_planes = 2  # first/second moments, in {"m","v"} flatten order

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def init(self, params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def apply(self, params, grads, state, step):
        lr = self._lr(step)
        t = jnp.asarray(step).astype(jnp.float32) + 1.0
        c1 = 1.0 - self.b1 ** t
        c2 = 1.0 - self.b2 ** t

        # three plain tree.map passes — params may be arbitrarily nested
        # pytrees (incl. tuples), so no is_leaf tricks on mapped outputs
        m = jax.tree.map(
            lambda mm, g: self.b1 * mm + (1 - self.b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree.map(
            lambda vv, g: (self.b2 * vv
                           + (1 - self.b2) * g.astype(jnp.float32)
                           * g.astype(jnp.float32)),
            state["v"], grads)

        def upd(p, m2, v2):
            d = (m2 / c1) / (jnp.sqrt(v2 / c2) + self.eps)
            p32 = p.astype(jnp.float32)
            return (p32 - lr * (d + self.weight_decay * p32)).astype(p.dtype)

        return jax.tree.map(upd, params, m, v), {"m": m, "v": v}

    def plane_hypers(self) -> dict:
        return {"b1": self.b1, "b2": self.b2, "eps": self.eps,
                "weight_decay": self.weight_decay}

    def plane_scalars(self, step):
        from repro.optim.sgd import _scalars
        t = jnp.asarray(step).astype(jnp.float32) + 1.0
        return _scalars(self._lr(step), 1.0 - self.b1 ** t,
                        1.0 - self.b2 ** t)

"""recurrentgemma-2b — Griffin-style hybrid: RG-LRU recurrent blocks mixed
with local (sliding-window) attention in a 2:1 ratio ("1:2" attn:recurrent).

[arXiv:2402.19427] Griffin: Mixing Gated Linear Recurrences with Local
Attention for Efficient Language Models; RecurrentGemma model card.
26 layers, d_model=2560, 10 heads (MQA kv=1, head_dim 256), d_ff=7680
(GeGLU), vocab 256000, window 2048, rnn width 2560.
"""
from repro.configs import LayerSpec, ModelConfig, _pattern, reduce_config

_PATTERN = [
    LayerSpec(mixer="rglru"),
    LayerSpec(mixer="rglru"),
    LayerSpec(mixer="attn_local"),
]


def make_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256_000,
        layers=_pattern(_PATTERN, 26),
        sliding_window=2048,
        rnn_width=2560,
        conv_width=4,
        norm="rmsnorm",
        act="gelu",
        gated_mlp=True,
        citation="arXiv:2402.19427",
    )


def make_reduced() -> ModelConfig:
    return reduce_config(make_config())

"""gemma3-27b — dense decoder with 5:1 local:global attention mix, 128k
context. [hf:google/gemma-3-1b-pt model card / Gemma 3 technical report]

62 layers, d_model=5376, 32 heads (GQA kv=16, head_dim 128), d_ff=21504
(GeGLU), vocab 262144, local window 1024, logit softcapping.
"""
from repro.configs import LayerSpec, ModelConfig, _pattern, reduce_config

_PATTERN = [
    LayerSpec(mixer="attn_local"),
    LayerSpec(mixer="attn_local"),
    LayerSpec(mixer="attn_local"),
    LayerSpec(mixer="attn_local"),
    LayerSpec(mixer="attn_local"),
    LayerSpec(mixer="attn"),
]


def make_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b",
        family="dense",
        num_layers=62,
        d_model=5376,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        d_ff=21_504,
        vocab_size=262_144,
        layers=_pattern(_PATTERN, 62),
        sliding_window=1024,
        rope_theta=1_000_000.0,
        norm="rmsnorm",
        act="gelu",
        gated_mlp=True,
        citation="hf:google/gemma-3-1b-pt",
    )


def make_reduced() -> ModelConfig:
    return reduce_config(make_config())

"""starcoder2-3b — dense GQA code model with 4k sliding-window attention
and RoPE. [arXiv:2402.19173] StarCoder 2 and The Stack v2.

30 layers, d_model=3072, 24 heads (GQA kv=2, head_dim 128), d_ff=12288
(non-gated GELU MLP), vocab 49152, window 4096, layernorm.
"""
from repro.configs import LayerSpec, ModelConfig, _pattern, reduce_config


def make_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        family="dense",
        num_layers=30,
        d_model=3072,
        num_heads=24,
        num_kv_heads=2,
        head_dim=128,
        d_ff=12_288,
        vocab_size=49_152,
        layers=_pattern([LayerSpec(mixer="attn_local")], 30),
        sliding_window=4096,
        rope_theta=100_000.0,
        norm="layernorm",
        act="gelu",
        gated_mlp=False,
        citation="arXiv:2402.19173",
    )


def make_reduced() -> ModelConfig:
    return reduce_config(make_config())

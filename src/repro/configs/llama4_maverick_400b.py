"""llama4-maverick-400b-a17b — 128-expert top-1 MoE with a shared expert
and chunked/local attention on 3 of 4 layers (long-context native).
Early-fusion multimodality is out of scope for the text backbone (noted
in DESIGN.md). [hf:meta-llama/Llama-4-Scout-17B-16E model card family]

48 layers, d_model=5120, 40 heads (GQA kv=8, head_dim 128), 128 experts
top-1 + shared expert, expert d_ff=8192 (SwiGLU), vocab 202048.
"""
from repro.configs import LayerSpec, ModelConfig, _pattern, reduce_config

# MoE interleaved 1:1 with dense-FFN layers (as in Maverick); chunked
# (local) attention on 3 of 4 layers, global RoPE-less on the 4th.
_PATTERN = [
    LayerSpec(mixer="attn_local", ffn="dense"),
    LayerSpec(mixer="attn_local", ffn="moe"),
    LayerSpec(mixer="attn_local", ffn="dense"),
    LayerSpec(mixer="attn", ffn="moe"),
]


def make_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16_384,              # dense interleaved layers
        vocab_size=202_048,
        layers=_pattern(_PATTERN, 48),
        sliding_window=8192,          # chunked attention
        rope_theta=500_000.0,
        num_experts=128,
        top_k=1,
        moe_d_ff=8192,
        shared_expert=True,
        capacity_factor=1.25,
        norm="rmsnorm",
        act="silu",
        gated_mlp=True,
        tie_embeddings=False,
        citation="hf:meta-llama/Llama-4-Scout-17B-16E",
    )


def make_reduced() -> ModelConfig:
    return reduce_config(make_config())

"""smollm-360m — llama-architecture small dense model.
[hf:HuggingFaceTB/SmolLM-135M model card family]

32 layers, d_model=960, 15 heads (GQA kv=5, head_dim 64), d_ff=2560
(SwiGLU), vocab 49152, RMSNorm, RoPE.
"""
from repro.configs import LayerSpec, ModelConfig, _pattern, reduce_config


def make_config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m",
        family="dense",
        num_layers=32,
        d_model=960,
        num_heads=15,
        num_kv_heads=5,
        head_dim=64,
        d_ff=2560,
        vocab_size=49_152,
        layers=_pattern([LayerSpec(mixer="attn")], 32),
        norm="rmsnorm",
        act="silu",
        gated_mlp=True,
        citation="hf:HuggingFaceTB/SmolLM-135M",
    )


def make_reduced() -> ModelConfig:
    return reduce_config(make_config())

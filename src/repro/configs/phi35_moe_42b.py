"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE.
[hf:microsoft/Phi-3.5-MoE-instruct model card]

32 layers, d_model=4096, 32 heads (GQA kv=8, head_dim 128), 16 experts
top-2 with expert d_ff=6400 (SwiGLU), vocab 32064.
"""
from repro.configs import LayerSpec, ModelConfig, _pattern, reduce_config


def make_config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=6400,
        vocab_size=32_064,
        layers=_pattern([LayerSpec(mixer="attn", ffn="moe")], 32),
        num_experts=16,
        top_k=2,
        moe_d_ff=6400,
        capacity_factor=1.25,
        norm="layernorm",
        act="silu",
        gated_mlp=True,
        tie_embeddings=False,
        citation="hf:microsoft/Phi-3.5-MoE-instruct",
    )


def make_reduced() -> ModelConfig:
    return reduce_config(make_config())

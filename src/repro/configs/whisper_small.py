"""whisper-small — encoder-decoder speech model; the conv+mel frontend is
STUBBED per the audio carve-out (``input_specs`` provides precomputed
frame embeddings of shape (B, 1500, d_model)). [arXiv:2212.04356]
Robust Speech Recognition via Large-Scale Weak Supervision.

12 enc + 12 dec layers, d_model=768, 12 heads (kv=12, head_dim 64),
d_ff=3072 (plain GELU MLP), vocab 51865, layernorm, learned positions.
"""
from repro.configs import LayerSpec, ModelConfig, _pattern, reduce_config


def make_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="audio",
        num_layers=12,                      # decoder layers
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=51_865,
        layers=_pattern([LayerSpec(mixer="attn", cross_attn=True)], 12),
        encoder_layers=12,
        encoder_seq=1500,                   # mel frames after conv stride 2
        pos_emb="learned",
        max_seq_len=65_536,                 # decoder positions (dry-run shapes)
        norm="layernorm",
        act="gelu",
        gated_mlp=False,
        tie_embeddings=True,
        citation="arXiv:2212.04356",
    )


def make_reduced() -> ModelConfig:
    return reduce_config(make_config())

"""rwkv6-7b ("Finch") — attention-free RNN with data-dependent decay
(dynamic token-shift + WKV6 recurrence). [arXiv:2404.05892] Eagle and
Finch: RWKV with Matrix-Valued States and Dynamic Recurrence.

32 layers, d_model=4096, attn-free (64 wkv heads of dim 64),
channel-mix d_ff=14336, vocab 65536, layernorm.
"""
from repro.configs import LayerSpec, ModelConfig, _pattern, reduce_config


def make_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        family="ssm",
        num_layers=32,
        d_model=4096,
        num_heads=64,          # wkv heads = d_model / rwkv_head_dim
        num_kv_heads=64,
        head_dim=64,
        d_ff=14_336,
        vocab_size=65_536,
        layers=_pattern([LayerSpec(mixer="rwkv", ffn="rwkv_cmix")], 32),
        rwkv_head_dim=64,
        pos_emb="none",
        norm="layernorm",
        act="relu2",
        gated_mlp=False,
        tie_embeddings=False,
        citation="arXiv:2404.05892",
    )


def make_reduced() -> ModelConfig:
    return reduce_config(make_config())

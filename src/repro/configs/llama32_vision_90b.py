"""llama-3.2-vision-90b — dense decoder with interleaved cross-attention
image layers (1 cross per 4 self). The ViT vision encoder + projector is
STUBBED per the vlm carve-out (``input_specs`` provides projected patch
embeddings of shape (B, num_media_tokens, d_model)).
[hf:meta-llama/Llama-3.2-11B-Vision model card, scaled to 90B]

100 layers (80 self + 20 cross), d_model=8192, 64 heads (GQA kv=8,
head_dim 128), d_ff=28672 (SwiGLU), vocab 128256.
"""
from repro.configs import LayerSpec, ModelConfig, _pattern, reduce_config

_PATTERN = [
    LayerSpec(mixer="attn"),
    LayerSpec(mixer="attn"),
    LayerSpec(mixer="attn"),
    LayerSpec(mixer="attn"),
    LayerSpec(mixer="none", cross_attn=True),  # pure cross-attn block
]


def make_config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        num_layers=100,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28_672,
        vocab_size=128_256,
        layers=_pattern(_PATTERN, 100),
        rope_theta=500_000.0,
        num_media_tokens=1601,   # 1 tile of 1600 patches + CLS, projected
        norm="rmsnorm",
        act="silu",
        gated_mlp=True,
        tie_embeddings=False,
        citation="hf:meta-llama/Llama-3.2-11B-Vision",
    )


def make_reduced() -> ModelConfig:
    return reduce_config(make_config())

"""Config system: model/shape dataclasses + arch registry.

Every assigned architecture has one file in this package exporting
``make_config() -> ModelConfig`` (full size, citation in the docstring)
and ``make_reduced() -> ModelConfig`` (2 layers, d_model<=512, <=4
experts) for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, replace


# --------------------------------------------------------------------------
# Layer / model configs
# --------------------------------------------------------------------------

MIXERS = ("attn", "attn_local", "rglru", "rwkv", "none")
FFNS = ("dense", "moe", "rwkv_cmix", "none")


@dataclass(frozen=True)
class LayerSpec:
    """One transformer block: a sequence mixer + an FFN.

    mixer:      attn | attn_local | rglru | rwkv | none
    ffn:        dense | moe | rwkv_cmix | none
    cross_attn: insert a cross-attention sublayer (VLM / whisper decoder)
    causal:     causal mask for attention mixers (False for encoders)
    """

    mixer: str = "attn"
    ffn: str = "dense"
    cross_attn: bool = False
    causal: bool = True

    def __post_init__(self):
        assert self.mixer in MIXERS, self.mixer
        assert self.ffn in FFNS, self.ffn


def _pattern(pattern: list[LayerSpec], n: int) -> tuple[LayerSpec, ...]:
    """Repeat ``pattern`` cyclically, truncated to exactly ``n`` layers."""
    out = []
    while len(out) < n:
        out.extend(pattern)
    return tuple(out[:n])


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    layers: tuple[LayerSpec, ...] = ()
    # attention
    sliding_window: int = 0          # window for attn_local mixers
    rope_theta: float = 10_000.0
    pos_emb: str = "rope"            # rope | learned | none
    max_seq_len: int = 1 << 20       # cap for learned positions
    logit_softcap: float = 0.0
    # moe
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_group_size: int = 0          # 0 = global capacity (naive GShard);
                                     # >0 = per-group dispatch (§Perf)
    # recurrent (RG-LRU)
    rnn_width: int = 0
    conv_width: int = 4
    # rwkv
    rwkv_head_dim: int = 64
    # enc-dec / modality frontends (stubbed per the audio/vlm carve-out)
    encoder_layers: int = 0
    encoder_seq: int = 0             # whisper: 1500 frames
    num_media_tokens: int = 0        # vlm: image-patch token count
    # perf variants (EXPERIMENTS.md §Perf; defaults = paper-faithful baseline)
    attn_banded: bool = False        # banded sliding-window attention
    score_dtype: str = "float32"     # attention score traffic dtype
    # misc
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu | gelu | relu2
    gated_mlp: bool = True
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    citation: str = ""

    # ---- derived -----------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to a multiple of 256 so it shards over 16-way
        model axes and aligns with the MXU lane width (128)."""
        return -(-self.vocab_size // 256) * 256

    def supports_long_decode(self) -> bool:
        """True if every mixer is sub-quadratic at decode time (recurrent
        state, sliding window, or a local:global mix where global layers
        are O(S) per decoded token)."""
        for spec in self.layers:
            if spec.mixer == "attn_local" and self.sliding_window <= 0:
                return False
        return self.encoder_layers == 0 or self.family != "audio"

    def num_params(self) -> int:
        """Analytic parameter count (used for 6ND model FLOPs)."""
        d, hd = self.d_model, self.head_dim
        n = self.padded_vocab * d  # embed
        if not self.tie_embeddings:
            n += self.padded_vocab * d
        for spec in self.layers:
            if spec.mixer in ("attn", "attn_local"):
                n += d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
            elif spec.mixer == "rglru":
                w = self.rnn_width or d
                n += 2 * d * w + w * d + self.conv_width * w + 3 * w
            elif spec.mixer == "rwkv":
                n += 4 * d * d + d * d // 2  # r,k,v,o + decay lora approx
            if spec.cross_attn:
                n += d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
            if spec.ffn == "dense":
                mult = 3 if self.gated_mlp else 2
                n += mult * d * self.d_ff
            elif spec.ffn == "moe":
                mult = 3 if self.gated_mlp else 2
                n += self.num_experts * mult * d * self.moe_d_ff
                n += d * self.num_experts  # router
                if self.shared_expert:
                    n += mult * d * self.moe_d_ff
            elif spec.ffn == "rwkv_cmix":
                n += 2 * d * self.d_ff
            n += 2 * d  # norms
        for _ in range(self.encoder_layers):
            n += d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
            mult = 3 if self.gated_mlp else 2
            n += mult * d * self.d_ff + 2 * d
        return n

    def num_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.num_experts == 0:
            return self.num_params()
        mult = 3 if self.gated_mlp else 2
        moe_layers = sum(1 for s in self.layers if s.ffn == "moe")
        dead = (self.num_experts - self.top_k) * mult * self.d_model * self.moe_d_ff
        return self.num_params() - moe_layers * dead


# --------------------------------------------------------------------------
# Input shapes (assigned)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

ARCHS = [
    "recurrentgemma-2b",
    "gemma3-27b",
    "starcoder2-3b",
    "smollm-360m",
    "rwkv6-7b",
    "whisper-small",
    "minitron-8b",
    "llama-3.2-vision-90b",
    "phi3.5-moe-42b-a6.6b",
    "llama4-maverick-400b-a17b",
]

_MODULES = {
    "recurrentgemma-2b": "recurrentgemma_2b",
    "gemma3-27b": "gemma3_27b",
    "starcoder2-3b": "starcoder2_3b",
    "smollm-360m": "smollm_360m",
    "rwkv6-7b": "rwkv6_7b",
    "whisper-small": "whisper_small",
    "minitron-8b": "minitron_8b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
}


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.make_reduced() if reduced else mod.make_config()


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def long_context_variant(cfg: ModelConfig) -> ModelConfig:
    """Sliding-window variant used for ``long_500k`` on dense archs
    (see DESIGN.md §4 shape skips). Archs with native sub-quadratic
    mixers are returned unchanged."""
    if all(s.mixer in ("rglru", "rwkv", "attn_local", "none") for s in cfg.layers):
        return cfg
    window = cfg.sliding_window or 8_192
    new_layers = tuple(
        replace(s, mixer="attn_local") if s.mixer == "attn" else s
        for s in cfg.layers
    )
    return replace(cfg, layers=new_layers, sliding_window=window,
                   name=cfg.name + "+swa")


def reduce_config(cfg: ModelConfig, num_layers: int = 2,
                  d_model: int = 256) -> ModelConfig:
    """Generic reduced variant for smoke tests: preserves the layer-type
    flavor of the family while shrinking every dimension."""
    head_dim = 32
    num_heads = max(2, min(4, cfg.num_heads))
    num_kv = 1 if cfg.num_kv_heads < cfg.num_heads else num_heads
    # keep the first layers of the pattern so every mixer kind appears
    kinds = list(dict.fromkeys(s.mixer for s in cfg.layers))
    layers = []
    for i in range(num_layers):
        base = cfg.layers[i % len(cfg.layers)]
        layers.append(base)
    # guarantee every distinct mixer kind shows up at least once
    for j, k in enumerate(kinds[:num_layers]):
        if all(l.mixer != k for l in layers):
            layers[j] = replace(layers[j], mixer=k)
    return replace(
        cfg,
        name=cfg.name + "-reduced",
        num_layers=num_layers,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=head_dim,
        d_ff=2 * d_model,
        vocab_size=512,
        layers=tuple(layers),
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        moe_d_ff=2 * d_model if cfg.moe_d_ff else 0,
        rnn_width=d_model if cfg.rnn_width else 0,
        rwkv_head_dim=32,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq=min(cfg.encoder_seq, 32) if cfg.encoder_seq else 0,
        num_media_tokens=min(cfg.num_media_tokens, 16) if cfg.num_media_tokens else 0,
        max_seq_len=4096,
    )

"""Configs for the paper's own experiments (Zhang et al. 2016).

- LeNet5-like CNN (§3.2): conv 32@5x5 -> relu -> maxpool/2 ->
  conv 64@5x5 -> relu -> maxpool/2 -> fc 512 -> fc 10, cross-entropy.
  Momentum SGD lr 0.01, momentum 0.9, x0.95 decay per epoch, 4 workers,
  minibatch 8, phase length 10.
- Convex problems (§3.1): least squares / logistic regression with the
  paper's datasets replaced by synthetic generators of matching
  sparsity/rho regimes (offline container; see DESIGN.md §6).
- Scalar quadratic (§2.3 / Lemma 1) and quartic (§2.4) settings.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class CNNConfig:
    name: str = "paper-lenet5"
    image_size: int = 28
    in_channels: int = 1
    conv_channels: tuple = (32, 64)
    kernel_size: int = 5
    fc_hidden: int = 512
    num_classes: int = 10
    # paper's training recipe
    lr: float = 0.01
    momentum: float = 0.9
    lr_decay_per_epoch: float = 0.95
    num_workers: int = 4
    batch_size: int = 8
    phase_len: int = 10


@dataclass(frozen=True)
class ConvexConfig:
    """Synthetic stand-ins for the paper's Table 1 datasets.

    ``beta2`` / ``sigma2`` control the gradient-variance envelope
    Delta(w) <= beta2 ||w - w*||^2 + sigma2, hence rho."""
    name: str
    model: str               # "ls" | "lr"
    num_samples: int
    num_dims: int
    sparsity: float = 1.0    # fraction of nonzero features
    noise: float = 0.1
    num_workers: int = 24
    phase_lens: tuple = (1, 128, 1024, 0)   # 0 => one-shot


# Regime analogues of paper Table 1 (same model kind + rho regime).
CONVEX_SUITE = (
    ConvexConfig("synth-ls-sparse-highrho", "ls", 4096, 1024, sparsity=0.01, noise=0.001),
    ConvexConfig("synth-ls-dense-lowrho", "ls", 8192, 64, sparsity=1.0, noise=3.0),
    ConvexConfig("synth-lr-sparse", "lr", 4096, 512, sparsity=0.02, noise=0.0),
    ConvexConfig("synth-lr-dense", "lr", 8192, 32, sparsity=1.0, noise=0.0),
)


@dataclass(frozen=True)
class QuadraticConfig:
    """Scalar model of §2.3: f(w) = c w^2 / 2, grad noise b~N(0,beta2),
    h~N(0,sigma2); averaging with per-step probability zeta."""
    c: float = 1.0
    beta2: float = 4.0
    sigma2: float = 1.0
    alpha: float = 0.05
    num_workers: int = 24


@dataclass(frozen=True)
class QuarticConfig:
    """Non-convex example of §2.4: f(w) = (w^2-1)^2 with
    grad samples 4(w^3 - w + u), u ~ N(0,1)."""
    alpha: float = 0.025
    num_steps: int = 10_000
    num_workers: int = 24


@dataclass(frozen=True)
class PCAConfig:
    """Oja's rule PCA of §2.4: 20-dim Gaussian, spectrum [1.0, 0.7...]."""
    dim: int = 20
    top_eig: float = 1.0
    tail_eig: float = 0.7
    num_workers: int = 48
    num_samples: int = 10_000
    alpha: float = 0.01

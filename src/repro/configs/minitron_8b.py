"""minitron-8b — width/depth-pruned Nemotron-4. [arXiv:2407.14679]
Compact Language Models via Pruning and Knowledge Distillation.

32 layers, d_model=4096, 32 heads (GQA kv=8, head_dim 128), d_ff=16384
(squared-ReLU non-gated MLP, Nemotron-style), vocab 256000.
"""
from repro.configs import LayerSpec, ModelConfig, _pattern, reduce_config


def make_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16_384,
        vocab_size=256_000,
        layers=_pattern([LayerSpec(mixer="attn")], 32),
        norm="layernorm",
        act="relu2",
        gated_mlp=False,
        tie_embeddings=False,
        citation="arXiv:2407.14679",
    )


def make_reduced() -> ModelConfig:
    return reduce_config(make_config())

"""Flash attention Pallas TPU kernel (causal / sliding-window / GQA).

Online-softmax blocked attention: grid (batch, q_head, q_blocks,
k_blocks) with the k dimension innermost; running (max, sum, acc) live in
VMEM scratch and persist across the innermost grid steps. Block shapes
are MXU-aligned (q/k blocks of 128 rows, full head_dim lanes).

HBM->VMEM traffic per (q_block, k_block): q once per k sweep (cached by
the pipeline), k/v streamed — the S×S score matrix never exists in HBM,
which is precisely what removes the memory-roofline term the XLA path
pays (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale, causal, window, block_q, block_k, seq_len):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)           # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)           # (bk, hd)
    s = jnp.dot(q, k.T) * scale                   # (bq, bk)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    mask &= kpos < seq_len
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                           # (bq,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(p, v)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)           # fully-masked rows -> 0
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool | None = None):
    """q: (B,S,H,hd), k/v: (B,S,Hkv,hd) -> (B,S,H,hd)."""
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    # pad sequence to block multiples (masked out via kpos < seq_len)
    s_pad = -(-s // max(block_q, block_k)) * max(block_q, block_k)
    if s_pad != s:
        pad = ((0, 0), (0, s_pad - s), (0, 0), (0, 0))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)

    # (B,H,S,hd) layout for clean blocking
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)

    grid = (b, h, s_pad // block_q, s_pad // block_k)
    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_len=s)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bb, hh, qi, ki, g=g: (bb, hh // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bb, hh, qi, ki, g=g: (bb, hh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s_pad, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),        # running max
            pltpu.VMEM((block_q,), jnp.float32),        # running sum
            pltpu.VMEM((block_q, hd), jnp.float32),     # accumulator
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.transpose(0, 2, 1, 3)[:, :s]

"""RWKV6 (Finch) WKV recurrence Pallas TPU kernel.

Per (batch, head) with head_dim n and data-dependent per-channel decay:
  y_t = r_t · (S_{t-1} + (u ∘ k_t) v_tᵀ);   S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

Grid (batch, heads, seq_blocks), seq innermost; the (n, n) fp32 state
matrix persists in VMEM scratch across sequence blocks. Each time step
is one rank-1 update + one vector-matrix product — n=64 keeps the state
a single (64, 64) VMEM tile; the v-products hit the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 128


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *,
                block_s):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, 0]                   # (block_s, n) fp32
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    w = w_ref[0, 0]                   # decays, already exp()'d
    u = u_ref[0]                      # (n,)

    def step(t, S):
        kv = k[t][:, None] * v[t][None, :]            # (n, n) rank-1
        y = (r[t][None, :] @ (S + u[:, None] * kv))[0]
        o_ref[0, 0, t, :] = y
        return w[t][:, None] * S + kv

    s_ref[...] = jax.lax.fori_loop(0, block_s, step, s_ref[...])


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def rwkv6_scan(r, k, v, log_w, u, *, block_s: int = DEFAULT_BLOCK_S,
               interpret: bool | None = None):
    """r,k,v,log_w: (B,S,H,n); u: (H*n,) or (H,n). Returns (B,S,H,n) fp32."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bsz, s, h, n = r.shape
    block_s = min(block_s, s)
    s_pad = -(-s // block_s) * block_s
    u2 = jnp.asarray(u, jnp.float32).reshape(h, n)

    def prep(t, fill=0.0):
        t = t.astype(jnp.float32).transpose(0, 2, 1, 3)  # (B,H,S,n)
        if s_pad != s:
            t = jnp.pad(t, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)),
                        constant_values=fill)
        return t

    rf, kf, vf = prep(r), prep(k), prep(v)
    wf = jnp.exp(prep(log_w, fill=0.0))  # pad decay=1 -> identity steps

    grid = (bsz, h, s_pad // block_s)
    blk = pl.BlockSpec((1, 1, block_s, n), lambda bb, hh, si: (bb, hh, si, 0))
    out = pl.pallas_call(
        functools.partial(_wkv_kernel, block_s=block_s),
        grid=grid,
        in_specs=[blk, blk, blk, blk,
                  pl.BlockSpec((1, n), lambda bb, hh, si: (hh, 0))],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((bsz, h, s_pad, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, u2)
    return out[:, :, :s].transpose(0, 2, 1, 3)

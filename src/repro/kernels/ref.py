"""Pure-jnp oracles for every kernel — written as straightforward,
obviously-correct (sequential where natural) references. Kernel tests
assert_allclose against these across shape/dtype sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool, window: int = 0,
                        scale: float | None = None):
    """q: (B,S,H,hd), k/v: (B,S,Hkv,hd) -> (B,S,H,hd). GQA by repeat."""
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    g = h // hkv
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kr.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def avg_disp_ref(plane, *, groups: int = 1, alive=None):
    """Fused worker-average + dispersion on the flat (M, P) float32 plane.

    Returns (averaged plane, dispersion). ``groups`` > 1 averages within
    ``groups`` contiguous worker groups (hierarchical inner average);
    the dispersion is ALWAYS measured against the global mean — the
    paper's Eq. 4 diagnostic E||w_i - w̄||², matching
    ``repro.core.averaging.worker_dispersion``.

    ``alive`` ((M,) f32, ``repro.faults``) restricts the event to the
    alive rows: the mean and dispersion are over the alive set, dead
    rows keep their stale values.
    """
    if alive is not None:
        return plane_average_ref(plane, groups=groups, alive=alive)
    m, p = plane.shape
    glob = jnp.mean(plane, axis=0)
    disp = jnp.sum(jnp.square(plane - glob[None])) / m
    if groups > 1:
        gm = jnp.mean(plane.reshape(groups, m // groups, p), axis=1)
        out = jnp.broadcast_to(gm[:, None], (groups, m // groups, p))
        out = out.reshape(m, p)
    else:
        out = jnp.broadcast_to(glob[None], (m, p))
    return out, disp


def mix_disp_ref(plane, W, *, codes=None, alive=None):
    """Gossip mixing event on the flat (M, P) plane: ``W @ plane`` for a
    doubly-stochastic (M, M) mixing matrix — each worker keeps its own
    mixed row, no broadcast — plus the Eq. 4 dispersion of the INPUT
    plane (pre-mix, matching ``avg_disp_ref``'s pre-average diagnostic).
    ``Topology.full``'s W reproduces the mean only up to matmul rounding,
    which is why the engine lowers that kind to the mean path instead.

    ``codes`` (``FlatSpec.rounding_codes``) rounds the mixed rows
    through the leaf dtypes, matching the tree operator
    ``repro.topology.mix_tree``'s ``.astype``. ``alive`` ((M,) f32,
    ``repro.faults``) degrades ``W`` over the alive rows
    (``faults.degraded_matrix`` Metropolis renormalization): dead rows
    keep their stale values, the dispersion is over the alive set.
    Returns (mixed plane, dispersion)."""
    from repro import faults as _faults
    if alive is not None:
        disp = _faults.masked_dispersion(plane, alive)
        Wm = _faults.degraded_matrix(W.astype(jnp.float32), alive)
        out = jnp.dot(Wm, plane, preferred_element_type=jnp.float32)
        if codes is not None:
            out = round_to_codes(out, codes[None])
        return _faults.select_rows(out, plane, alive), disp
    m = plane.shape[0]
    glob = jnp.mean(plane, axis=0)
    disp = jnp.sum(jnp.square(plane - glob[None])) / m
    out = jnp.dot(W.astype(jnp.float32), plane,
                  preferred_element_type=jnp.float32)
    if codes is not None:
        out = round_to_codes(out, codes[None])
    return out, disp


def avg_disp_outer_ref(plane, prev_avg, vel, *, lr: float, momentum: float,
                       nesterov: bool = True, codes=None):
    """avg_disp with the outer-optimizer momentum step folded in: the
    consensus mean becomes the outer gradient target, the updated average
    is broadcast back into the plane. Mirrors
    ``repro.core.averaging.OuterOptimizer.apply`` on flat f32 buffers.

    ``codes`` (``FlatSpec.rounding_codes``) reproduces the tree path's
    dtype rounding for mixed-dtype params: the consensus mean is rounded
    before it becomes the outer gradient target (``consensus`` yields a
    leaf-dtype mean) and the updated average is rounded before carry and
    broadcast (``OuterOptimizer.apply`` ends with ``.astype(p.dtype)``).
    Dispersion stays measured against the unrounded f32 mean, like
    ``worker_dispersion``.

    plane: (M, P); prev_avg/vel: (P,). Returns
    (averaged plane, new_avg, new_vel, dispersion)."""
    m = plane.shape[0]
    avg = jnp.mean(plane, axis=0)
    disp = jnp.sum(jnp.square(plane - avg[None])) / m
    if codes is not None:
        avg = round_to_codes(avg, codes)
    g = prev_avg - avg
    vel = momentum * vel + g
    step = momentum * vel + g if nesterov else vel
    upd = prev_avg - lr * step
    if codes is not None:
        upd = round_to_codes(upd, codes)
    return jnp.broadcast_to(upd[None], plane.shape), upd, vel, disp


def round_to_codes(x, codes):
    """Round each column of ``x`` through its original dtype (codes from
    ``FlatSpec.rounding_codes``: 0 f32, 1 bf16, 2 f16) and back to f32 —
    the plane-resident twin of the pytree optimizers' ``.astype(p.dtype)``
    after every update. ``codes`` broadcasts over leading axes."""
    bf = x.astype(jnp.bfloat16).astype(jnp.float32)
    f16 = x.astype(jnp.float16).astype(jnp.float32)
    return jnp.where(codes == 1.0, bf, jnp.where(codes == 2.0, f16, x))


def plane_update_ref(plane, grads, planes, scalars, *, kind, mu=0.9,
                     nesterov=False, b1=0.9, b2=0.95, eps=1e-8,
                     weight_decay=0.0, codes=None):
    """The local optimizer step on the flat (M, P) plane — bit-exact twin
    of ``repro.optim`` SGD/Momentum/AdamW ``apply`` on the packed tree.

    plane/grads: (M, P) f32 (grads = f32 image of the param-dtype grads,
    i.e. what one vjp through ``FlatSpec.unpack`` yields); planes: tuple
    of S state planes; scalars: (4,) f32 [lr, c1, c2, _]. Returns
    (updated plane, new state planes)."""
    lr, c1, c2 = scalars[0], scalars[1], scalars[2]
    g = grads
    if kind == "sgd":
        upd, planes = plane - lr * g, ()
    elif kind == "momentum":
        v = mu * planes[0] + g
        upd = plane - lr * (g + mu * v if nesterov else v)
        planes = (v,)
    elif kind == "adamw":
        m2 = b1 * planes[0] + (1 - b1) * g
        v2 = b2 * planes[1] + (1 - b2) * g * g
        d = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
        upd = plane - lr * (d + weight_decay * plane)
        planes = (m2, v2)
    else:
        raise ValueError(f"unknown plane optimizer kind {kind!r}")
    if codes is not None:
        upd = round_to_codes(upd, codes[None])
    return upd, planes


def plane_average_ref(plane, *, groups: int = 1, codes=None, alive=None):
    """Worker mean (global, or per contiguous group) + Eq. 4 dispersion
    + broadcast on the (M, P) plane. Like ``avg_disp_ref`` but with the
    per-column dtype rounding the tree operators apply (``average_all``
    casts the mean back to the leaf dtype). ``alive`` ((M,) f32,
    ``repro.faults``) makes the event a masked one: the exact mean over
    alive rows broadcast to alive rows only, dead rows keeping their
    stale values, the dispersion over the alive set."""
    from repro import faults as _faults
    m, p = plane.shape
    if alive is not None:
        disp = _faults.masked_dispersion(plane, alive)
        if groups > 1:
            out = _faults.masked_group_mean(plane, alive, groups)
        else:
            glob = _faults.masked_mean(plane, alive)
            out = jnp.broadcast_to(glob[None], (m, p))
        if codes is not None:
            out = round_to_codes(out, codes[None])
        return _faults.select_rows(out, plane, alive), disp
    glob = jnp.mean(plane, axis=0)
    disp = jnp.sum(jnp.square(plane - glob[None])) / m
    if groups > 1:
        gm = jnp.mean(plane.reshape(groups, m // groups, p), axis=1)
        out = jnp.broadcast_to(gm[:, None], (groups, m // groups, p))
        out = out.reshape(m, p)
    else:
        out = jnp.broadcast_to(glob[None], (m, p))
    if codes is not None:
        out = round_to_codes(out, codes[None])
    return out, disp


def opt_step_ref(plane, grads, planes, scalars, *, kind, mode="none",
                 groups: int = 1, W=None, mu=0.9, nesterov=False, b1=0.9,
                 b2=0.95, eps=1e-8, weight_decay=0.0, codes=None,
                 wire=None, resid=None, u=None,
                 error_feedback: bool = True, alive=None, umask=None):
    """Fused local optimizer step + optional averaging event in one pass
    over the flat (M, P) plane — the jnp twin of
    ``repro.kernels.opt_step``.

    mode: "none" (pure local step), "mean" (step + worker mean + Eq. 4
    dispersion + broadcast), "group" (per-group means; dispersion still
    against the global mean), or "mix" (step + ``W @ plane`` gossip mix
    for the doubly-stochastic (M, M) ``W`` — no broadcast, each worker
    keeps its own mixed row). Returns
    (plane, new state planes, dispersion). The Eq. 4 dispersion of the
    post-update plane is emitted in EVERY mode — "none" measures
    without averaging and "mix" measures pre-mix, so adaptive schedules
    and the per-step diagnostic trace see the true value on every
    step.

    ``wire`` (``repro.core.compress`` format, not "f32") switches the
    averaging event to the compressed twin: the error-feedback encode
    acts on the POST-update plane (``resid`` the (M, P) residual, ``u``
    the int8 ``row_uniforms``), the event operator on the decoded
    ``q``, and the return gains the residual:
    (plane, new state planes, new residual, dispersion).

    ``alive`` / ``umask`` ((M,) f32, ``repro.faults``) make the pass a
    fault-degraded one: only rows with ``umask > 0`` apply the local
    update (dead AND straggling rows keep their params and optimizer
    planes — zeroing the gradient would still advance momentum), the
    event is masked over the alive rows (degraded ``W`` for "mix",
    exact alive means otherwise), and the dispersion is over the alive
    set."""
    from repro import faults as _faults
    upd, new_planes = plane_update_ref(
        plane, grads, planes, scalars, kind=kind, mu=mu, nesterov=nesterov,
        b1=b1, b2=b2, eps=eps, weight_decay=weight_decay, codes=codes)
    if alive is not None:
        if umask is None:
            umask = alive
        upd = _faults.select_rows(upd, plane, umask)
        new_planes = tuple(_faults.select_rows(n, o, umask)
                           for n, o in zip(new_planes, planes))
    planes = new_planes
    if wire is not None and mode != "none":
        kw = dict(wire=wire, u=u, codes=codes,
                  error_feedback=error_feedback, alive=alive)
        if mode == "mix":
            out, resid, disp = compressed_mix_ref(upd, resid, W, **kw)
        else:
            out, resid, disp = compressed_avg_ref(
                upd, resid, groups=groups if mode == "group" else 1, **kw)
        return out, planes, resid, disp
    if mode == "none":
        if alive is not None:
            return upd, planes, _faults.masked_dispersion(upd, alive)
        m = upd.shape[0]
        glob = jnp.mean(upd, axis=0)
        disp = jnp.sum(jnp.square(upd - glob[None])) / m
        return upd, planes, disp
    if mode == "mix":
        out, disp = mix_disp_ref(upd, W, codes=codes, alive=alive)
        return out, planes, disp
    out, disp = plane_average_ref(
        upd, groups=groups if mode == "group" else 1, codes=codes,
        alive=alive)
    return out, planes, disp


def compressed_avg_ref(plane, resid, *, wire, groups: int = 1, u=None,
                       codes=None, error_feedback: bool = True,
                       alive=None):
    """Compressed averaging event on the (M, P) plane: error-feedback
    encode (``v = plane + resid``, ``q = Q(v)``, ``resid' = v - q``,
    ``repro.core.compress``), then the worker mean (global, or per
    contiguous group) of the DECODED ``q`` broadcast back — what every
    worker reconstructs from the bytes actually shipped. The Eq. 4
    dispersion stays measured on the input plane (pre-encode,
    pre-average), like every other event twin. ``u`` is the
    ``row_uniforms`` plane (int8 stochastic rounding); ``codes``
    (``FlatSpec.rounding_codes``) rounds the broadcast mean through the
    leaf dtypes like ``plane_average_ref``. ``alive`` ((M,) f32,
    ``repro.faults``) masks the event: dead rows neither ship bytes nor
    accumulate residual, the mean is over the alive rows' decoded
    ``q``, and dead rows keep their stale params. Returns
    (plane, new residual, dispersion)."""
    from repro.core.compress import encode_decode
    from repro import faults as _faults
    m, p = plane.shape
    if alive is not None:
        disp = _faults.masked_dispersion(plane, alive)
        q, r_new = encode_decode(plane, resid, wire=wire, u=u,
                                 error_feedback=error_feedback)
        resid = _faults.select_rows(r_new, resid, alive)
        if groups > 1:
            out = _faults.masked_group_mean(q, alive, groups)
        else:
            out = jnp.broadcast_to(
                _faults.masked_mean(q, alive)[None], (m, p))
        if codes is not None:
            out = round_to_codes(out, codes[None])
        return _faults.select_rows(out, plane, alive), resid, disp
    glob = jnp.mean(plane, axis=0)
    disp = jnp.sum(jnp.square(plane - glob[None])) / m
    q, resid = encode_decode(plane, resid, wire=wire, u=u,
                             error_feedback=error_feedback)
    if groups > 1:
        gm = jnp.mean(q.reshape(groups, m // groups, p), axis=1)
        out = jnp.broadcast_to(gm[:, None], (groups, m // groups, p))
        out = out.reshape(m, p)
    else:
        out = jnp.broadcast_to(jnp.mean(q, axis=0)[None], (m, p))
    if codes is not None:
        out = round_to_codes(out, codes[None])
    return out, resid, disp


def compressed_mix_ref(plane, resid, W, *, wire, u=None, codes=None,
                       error_feedback: bool = True, alive=None):
    """Compressed gossip mixing event: error-feedback encode, then
    ``W @ q`` on the decoded plane — each worker keeps its own mixed
    row, no broadcast. The Eq. 4 dispersion is of the input plane
    (pre-encode, pre-mix), matching ``mix_disp_ref``. ``alive``
    degrades ``W`` over the alive rows (``repro.faults``): dead rows
    keep their stale params and residual. Returns
    (mixed plane, new residual, dispersion)."""
    from repro.core.compress import encode_decode
    from repro import faults as _faults
    m = plane.shape[0]
    if alive is not None:
        disp = _faults.masked_dispersion(plane, alive)
        q, r_new = encode_decode(plane, resid, wire=wire, u=u,
                                 error_feedback=error_feedback)
        resid = _faults.select_rows(r_new, resid, alive)
        Wm = _faults.degraded_matrix(W.astype(jnp.float32), alive)
        out = jnp.dot(Wm, q, preferred_element_type=jnp.float32)
        if codes is not None:
            out = round_to_codes(out, codes[None])
        return _faults.select_rows(out, plane, alive), resid, disp
    glob = jnp.mean(plane, axis=0)
    disp = jnp.sum(jnp.square(plane - glob[None])) / m
    q, resid = encode_decode(plane, resid, wire=wire, u=u,
                             error_feedback=error_feedback)
    out = jnp.dot(W.astype(jnp.float32), q,
                  preferred_element_type=jnp.float32)
    if codes is not None:
        out = round_to_codes(out, codes[None])
    return out, resid, disp


def rglru_scan_ref(a, b):
    """h_t = a_t h_{t-1} + b_t, h_0 = 0. a,b: (B,S,W) fp32. Sequential."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    a_t = jnp.swapaxes(a, 0, 1)
    b_t = jnp.swapaxes(b, 0, 1)
    _, hs = jax.lax.scan(step, jnp.zeros_like(a[:, 0]), (a_t, b_t))
    return jnp.swapaxes(hs, 0, 1)


def rwkv6_scan_ref(r, k, v, log_w, u):
    """Exact sequential WKV6.
    r,k,v,log_w: (B,S,H,n); u: (H*n,) or (H,n). Returns (B,S,H,n) fp32:
      y_t = r_t · (S_{t-1} + (u∘k_t) v_tᵀ);  S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
    """
    bsz, s, h, n = r.shape
    u = jnp.asarray(u, jnp.float32).reshape(h, n)
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    w = jnp.exp(log_w.astype(jnp.float32))

    def step(S, inp):
        rt, kt, vt, wt = inp  # (B,H,n)
        kv = kt[..., None] * vt[..., None, :]            # (B,H,n,n)
        y = jnp.einsum("bhi,bhij->bhj", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, y

    xs = tuple(jnp.swapaxes(t, 0, 1) for t in (rf, kf, vf, w))
    S0 = jnp.zeros((bsz, h, n, n), jnp.float32)
    _, ys = jax.lax.scan(step, S0, xs)
    return jnp.swapaxes(ys, 0, 1)


# Kernel-twin registry: maps every public Pallas kernel under
# ``repro.kernels`` to the jnp oracle(s) that define its semantics.
# Checked by the ``kernel-twin`` rule of ``repro.analysis`` — adding a
# kernel without registering (and testing) its twin fails CI.
TWINS = {
    "avg_disp": "avg_disp_ref",
    "mix_disp": "mix_disp_ref",
    "avg_disp_outer": "avg_disp_outer_ref",
    "compressed_mix": ("compressed_avg_ref", "compressed_mix_ref"),
    "opt_step": "opt_step_ref",
    "flash_attention": "flash_attention_ref",
    "rglru_scan": "rglru_scan_ref",
    "rwkv6_scan": "rwkv6_scan_ref",
}

"""Pallas fused worker-average + dispersion over the flat (M, P) plane.

One averaging event in the phase engine needs, per the paper: the worker
mean w̄ (or per-group means for the hierarchical schedule), the Eq. 4
dispersion E||w_i - w̄||², the mean broadcast back into every worker row,
and — with the DiLoCo-style outer optimizer — a momentum step on the
mean. The tree path pays 3–4 separate traversals of the params pytree
for that; here it is ONE tiled pass over the contiguous plane.
:func:`mix_disp` generalizes the event to a gossip topology
(``repro.topology``): ``W @ plane`` for a doubly-stochastic (M, M)
mixing matrix, each worker keeping its own mixed row.

Grid (P // block_p,): each program reads a full-height (M, block_p)
column block (M is the worker count, 4–64 — far below a VMEM tile, so
the whole worker axis rides along in one block), reduces over workers on
the VPU, writes the broadcast block back, and emits its partial
dispersion sum into an SMEM scalar slot; the partials are summed outside
the kernel. P is padded to a lane multiple with zero columns, which are
mean-0 / dispersion-0 and sliced off.

On CPU (this container) the kernels run in interpret mode for
correctness validation; on TPU the same calls compile to Mosaic. The
engine's default CPU path uses the jnp twin in ``kernels/ref.py`` —
identical math, no interpreter overhead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_P = 1024


def _avg_disp_kernel(x_ref, o_ref, d_ref, *, groups):
    x = x_ref[...]                                   # (M, block_p) f32
    m, bp = x.shape
    glob = jnp.mean(x, axis=0)                       # (block_p,)
    d_ref[0, 0] = jnp.sum(jnp.square(x - glob[None])) / m
    if groups > 1:
        gm = jnp.mean(x.reshape(groups, m // groups, bp), axis=1)
        out = jnp.broadcast_to(gm[:, None], (groups, m // groups, bp))
        o_ref[...] = out.reshape(m, bp)
    else:
        o_ref[...] = jnp.broadcast_to(glob[None], (m, bp))


def _mix_disp_kernel(x_ref, w_ref, o_ref, d_ref):
    x = x_ref[...]                                   # (M, block_p) f32
    m = x.shape[0]
    glob = jnp.mean(x, axis=0)
    d_ref[0, 0] = jnp.sum(jnp.square(x - glob[None])) / m
    # the (M, M) @ (M, block_p) gossip mix rides the same column sweep:
    # M is tiny, so W lives whole in VMEM and the contraction hits the
    # MXU without extra plane traffic
    o_ref[...] = jnp.dot(w_ref[...], x, preferred_element_type=jnp.float32)


def _avg_disp_outer_kernel(x_ref, p_ref, v_ref, o_ref, a_ref, w_ref, d_ref,
                           *, lr, momentum, nesterov):
    x = x_ref[...]                                   # (M, block_p) f32
    m = x.shape[0]
    avg = jnp.mean(x, axis=0)
    d_ref[0, 0] = jnp.sum(jnp.square(x - avg[None])) / m
    g = p_ref[0] - avg                               # outer gradient
    vel = momentum * v_ref[0] + g
    step = momentum * vel + g if nesterov else vel
    upd = p_ref[0] - lr * step
    a_ref[0, :] = upd
    w_ref[0, :] = vel
    o_ref[...] = jnp.broadcast_to(upd[None], x.shape)


def _round_codes(x, codes):
    bf = x.astype(jnp.bfloat16).astype(jnp.float32)
    f16 = x.astype(jnp.float16).astype(jnp.float32)
    return jnp.where(codes == 1.0, bf, jnp.where(codes == 2.0, f16, x))


def _compressed_mix_kernel(*refs, wire, mode, groups, has_u, has_codes,
                           error_feedback, p):
    i = 0
    x_ref, e_ref = refs[0], refs[1]
    i = 2
    u_ref = refs[i] if has_u else None
    i += int(has_u)
    codes_ref = refs[i] if has_codes else None
    i += int(has_codes)
    w_ref = refs[i] if mode == "mix" else None
    i += int(mode == "mix")
    o_ref, r_ref, d_ref, sc_ref = refs[i], refs[i + 1], refs[i + 2], refs[i + 3]

    ph, j = pl.program_id(0), pl.program_id(1)
    x = x_ref[...]                                   # (M, block_p) f32
    m, bp = x.shape
    v = x + e_ref[...] if error_feedback else x
    glob = jnp.mean(x, axis=0)
    # pre-encode, pre-average Eq. 4 dispersion (identical both phases)
    d_ref[0, 0] = jnp.sum(jnp.square(x - glob[None])) / m

    if wire in ("int8", "one_bit"):
        # phase 0: accumulate the per-row scale statistic across the
        # column blocks into VMEM scratch, which persists over the
        # sequentially-executed grid (amax for int8, abs-sum for one_bit)
        part = (jnp.max(jnp.abs(v), axis=1, keepdims=True)
                if wire == "int8"
                else jnp.sum(jnp.abs(v), axis=1, keepdims=True))

        @pl.when((ph == 0) & (j == 0))
        def _init():
            sc_ref[...] = part

        @pl.when((ph == 0) & (j > 0))
        def _acc():
            sc_ref[...] = (jnp.maximum(sc_ref[...], part)
                           if wire == "int8" else sc_ref[...] + part)

    @pl.when(ph == 1)
    def _emit():
        if wire == "bf16":
            q = v.astype(jnp.bfloat16).astype(jnp.float32)
        elif wire == "int8":
            amax = sc_ref[...]
            s = jnp.where(amax > 0.0, amax / 127.0, 1.0)
            q = jnp.clip(jnp.floor(v / s + u_ref[...]), -127.0, 127.0) * s
        else:  # one_bit
            s = sc_ref[...] / p
            q = jnp.where(v >= 0.0, s, -s)
        if mode == "mix":
            out = jnp.dot(w_ref[...], q,
                          preferred_element_type=jnp.float32)
        elif mode == "group" and groups > 1:
            gm = jnp.mean(q.reshape(groups, m // groups, bp), axis=1)
            out = jnp.broadcast_to(gm[:, None], (groups, m // groups, bp))
            out = out.reshape(m, bp)
        else:
            out = jnp.broadcast_to(jnp.mean(q, axis=0)[None], (m, bp))
        if has_codes:
            out = _round_codes(out, codes_ref[...])
        o_ref[...] = out
        r_ref[...] = v - q if error_feedback else e_ref[...]


def _pad_cols(x, p_pad):
    p = x.shape[-1]
    if p_pad == p:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, p_pad - p)])


@functools.partial(jax.jit,
                   static_argnames=("groups", "block_p", "interpret"))
def avg_disp(plane, *, groups: int = 1, alive=None,
             block_p: int = DEFAULT_BLOCK_P,
             interpret: bool | None = None):
    """plane: (M, P) float32 -> (averaged plane, Eq. 4 dispersion scalar).

    ``groups`` > 1 broadcasts per-group means (hierarchical inner
    average); the dispersion is always against the global mean.

    ``alive`` ((M,) f32, ``repro.faults``) degrades the event over the
    alive rows: the masked (group-)mean lowers to the SAME fused mix
    pass (``faults.masked_event_matrix`` is doubly stochastic with
    identity rows for dead workers), the dispersion is over the alive
    set, and dead rows keep their stale values. Matches the masked
    ``repro.kernels.ref.avg_disp_ref`` up to matmul rounding."""
    if alive is not None:
        from repro import faults as _faults
        A = _faults.masked_event_matrix(alive, groups)
        out, _ = mix_disp(plane, A, block_p=block_p, interpret=interpret)
        out = _faults.select_rows(out, plane, alive)
        return out, _faults.masked_dispersion(plane, alive)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, p = plane.shape
    assert groups >= 1 and m % groups == 0, (m, groups)
    block_p = min(block_p, max(p, 1))
    p_pad = -(-max(p, 1) // block_p) * block_p
    x = _pad_cols(plane.astype(jnp.float32), p_pad)
    nb = p_pad // block_p
    out, dpart = pl.pallas_call(
        functools.partial(_avg_disp_kernel, groups=groups),
        grid=(nb,),
        in_specs=[pl.BlockSpec((m, block_p), lambda i: (0, i))],
        out_specs=[
            pl.BlockSpec((m, block_p), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (i, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, p_pad), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return out[:, :p], jnp.sum(dpart)


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def mix_disp(plane, W, *, alive=None, block_p: int = DEFAULT_BLOCK_P,
             interpret: bool | None = None):
    """Fused gossip mix + dispersion: plane (M, P) f32, W (M, M)
    doubly-stochastic f32 -> (W @ plane, Eq. 4 dispersion of the input
    plane). Each worker keeps its own mixed row — no broadcast. The
    generalization of :func:`avg_disp` to a mixing-matrix topology
    (``repro.topology``); matches ``repro.kernels.ref.mix_disp_ref``.

    ``alive`` ((M,) f32, ``repro.faults``) renormalizes ``W`` over the
    alive rows (``faults.degraded_matrix``) before the same fused pass;
    dead rows keep their stale values and the dispersion is over the
    alive set."""
    if alive is not None:
        from repro import faults as _faults
        Wm = _faults.degraded_matrix(W.astype(jnp.float32), alive)
        out, _ = mix_disp(plane, Wm, block_p=block_p, interpret=interpret)
        out = _faults.select_rows(out, plane, alive)
        return out, _faults.masked_dispersion(plane, alive)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, p = plane.shape
    assert W.shape == (m, m), (W.shape, m)
    block_p = min(block_p, max(p, 1))
    p_pad = -(-max(p, 1) // block_p) * block_p
    x = _pad_cols(plane.astype(jnp.float32), p_pad)
    nb = p_pad // block_p
    out, dpart = pl.pallas_call(
        _mix_disp_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((m, block_p), lambda i: (0, i)),
                  pl.BlockSpec((m, m), lambda i: (0, 0))],
        out_specs=[
            pl.BlockSpec((m, block_p), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (i, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, p_pad), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, W.astype(jnp.float32))
    return out[:, :p], jnp.sum(dpart)


@functools.partial(jax.jit,
                   static_argnames=("lr", "momentum", "nesterov", "block_p",
                                    "interpret"))
def avg_disp_outer(plane, prev_avg, vel, *, lr: float, momentum: float,
                   nesterov: bool = True, block_p: int = DEFAULT_BLOCK_P,
                   interpret: bool | None = None):
    """Fused all-average + dispersion + outer momentum step.

    plane: (M, P) f32; prev_avg/vel: (P,) f32. Returns
    (averaged plane, new_avg, new_vel, dispersion) — the flat twin of
    ``worker_dispersion`` + ``consensus`` + ``OuterOptimizer.apply`` +
    ``replicate`` in one pass."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, p = plane.shape
    block_p = min(block_p, max(p, 1))
    p_pad = -(-max(p, 1) // block_p) * block_p
    x = _pad_cols(plane.astype(jnp.float32), p_pad)
    pa = _pad_cols(prev_avg.astype(jnp.float32)[None], p_pad)
    ve = _pad_cols(vel.astype(jnp.float32)[None], p_pad)
    nb = p_pad // block_p
    row = pl.BlockSpec((1, block_p), lambda i: (0, i))
    out, avg, new_vel, dpart = pl.pallas_call(
        functools.partial(_avg_disp_outer_kernel, lr=lr, momentum=momentum,
                          nesterov=nesterov),
        grid=(nb,),
        in_specs=[pl.BlockSpec((m, block_p), lambda i: (0, i)), row, row],
        out_specs=[
            pl.BlockSpec((m, block_p), lambda i: (0, i)), row, row,
            pl.BlockSpec((1, 1), lambda i: (i, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, p_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, p_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, p_pad), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, pa, ve)
    return out[:, :p], avg[0, :p], new_vel[0, :p], jnp.sum(dpart)


@functools.partial(
    jax.jit,
    static_argnames=("wire", "mode", "groups", "error_feedback", "block_p",
                     "interpret"))
def compressed_mix(plane, resid, *, wire, mode="mean", groups: int = 1,
                   W=None, u=None, codes=None, error_feedback: bool = True,
                   alive=None, block_p: int = DEFAULT_BLOCK_P,
                   interpret: bool | None = None):
    """Fused compressed averaging/mixing event on the (M, P) plane:
    error-feedback encode (``v = plane + resid``, ``q = Q(v)``,
    ``resid' = v - q`` — ``repro.core.compress`` formats ``bf16`` /
    ``int8`` / ``one_bit``), the event operator on the decoded ``q``
    (mode "mean" | "group" | "mix" with the doubly-stochastic (M, M)
    ``W``), dtype-rounding ``codes``, and the pre-encode Eq. 4
    dispersion, in one pass.

    The scaled formats need a per-ROW statistic (amax / abs-mean) that
    spans every column block, so the kernel runs a (2, nb) grid: phase 0
    accumulates the row statistic into VMEM scratch (the grid executes
    sequentially, so scratch persists), phase 1 quantizes, applies the
    event and writes the plane + residual. ``u`` is the int8
    ``row_uniforms`` plane. Returns (plane, new residual, dispersion);
    matches ``repro.kernels.ref.compressed_avg_ref`` /
    ``compressed_mix_ref``.

    ``alive`` ((M,) f32, ``repro.faults``) degrades the event over the
    alive rows: masked means lower to the kernel's own fused ``mix``
    path on ``faults.masked_event_matrix``, gossip ``W`` is
    renormalized by ``faults.degraded_matrix``, and dead rows keep
    their stale params AND residual (they ship no bytes). Matches the
    masked refs up to matmul rounding."""
    assert wire in ("bf16", "int8", "one_bit"), wire
    assert mode in ("mean", "group", "mix"), mode
    assert (W is not None) == (mode == "mix"), (mode, W is None)
    if alive is not None:
        from repro import faults as _faults
        Wm = (_faults.degraded_matrix(W.astype(jnp.float32), alive)
              if mode == "mix"
              else _faults.masked_event_matrix(
                  alive, groups if mode == "group" else 1))
        out, r_new, _ = compressed_mix(
            plane, resid, wire=wire, mode="mix", W=Wm, u=u, codes=codes,
            error_feedback=error_feedback, block_p=block_p,
            interpret=interpret)
        out = _faults.select_rows(out, plane, alive)
        r_new = _faults.select_rows(r_new, resid, alive)
        return out, r_new, _faults.masked_dispersion(plane, alive)
    has_u = wire == "int8"
    assert (u is not None) == has_u, (wire, u is None)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, p = plane.shape
    assert groups >= 1 and m % groups == 0, (m, groups)
    block_p = min(block_p, max(p, 1))
    p_pad = -(-max(p, 1) // block_p) * block_p
    nb = p_pad // block_p
    has_codes = codes is not None

    blk = pl.BlockSpec((m, block_p), lambda ph, i: (0, i))
    ins = [_pad_cols(plane.astype(jnp.float32), p_pad),
           _pad_cols(resid.astype(jnp.float32), p_pad)]
    in_specs = [blk, blk]
    if has_u:
        ins.append(_pad_cols(u.astype(jnp.float32), p_pad))
        in_specs.append(blk)
    if has_codes:
        ins.append(_pad_cols(jnp.asarray(codes, jnp.float32)[None], p_pad))
        in_specs.append(pl.BlockSpec((1, block_p), lambda ph, i: (0, i)))
    if mode == "mix":
        assert W.shape == (m, m), (W.shape, m)
        ins.append(W.astype(jnp.float32))
        in_specs.append(pl.BlockSpec((m, m), lambda ph, i: (0, 0)))

    out, r, dpart = pl.pallas_call(
        functools.partial(_compressed_mix_kernel, wire=wire, mode=mode,
                          groups=groups, has_u=has_u, has_codes=has_codes,
                          error_feedback=error_feedback, p=p),
        grid=(2, nb),
        in_specs=in_specs,
        out_specs=[blk, blk,
                   pl.BlockSpec((1, 1), lambda ph, i: (i, 0),
                                memory_space=pltpu.SMEM)],
        out_shape=[jax.ShapeDtypeStruct((m, p_pad), jnp.float32),
                   jax.ShapeDtypeStruct((m, p_pad), jnp.float32),
                   jax.ShapeDtypeStruct((nb, 1), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((m, 1), jnp.float32)],
        interpret=interpret,
    )(*ins)
    return out[:, :p], r[:, :p], jnp.sum(dpart)

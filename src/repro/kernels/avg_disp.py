"""Pallas fused worker-average + dispersion over the flat (M, P) plane.

One averaging event in the phase engine needs, per the paper: the worker
mean w̄ (or per-group means for the hierarchical schedule), the Eq. 4
dispersion E||w_i - w̄||², the mean broadcast back into every worker row,
and — with the DiLoCo-style outer optimizer — a momentum step on the
mean. The tree path pays 3–4 separate traversals of the params pytree
for that; here it is ONE tiled pass over the contiguous plane.
:func:`mix_disp` generalizes the event to a gossip topology
(``repro.topology``): ``W @ plane`` for a doubly-stochastic (M, M)
mixing matrix, each worker keeping its own mixed row.

Grid (P // block_p,): each program reads a full-height (M, block_p)
column block (M is the worker count, 4–64 — far below a VMEM tile, so
the whole worker axis rides along in one block), reduces over workers on
the VPU, writes the broadcast block back, and emits its partial
dispersion sum into an SMEM scalar slot; the partials are summed outside
the kernel. P is padded to a lane multiple with zero columns, which are
mean-0 / dispersion-0 and sliced off.

On CPU (this container) the kernels run in interpret mode for
correctness validation; on TPU the same calls compile to Mosaic. The
engine's default CPU path uses the jnp twin in ``kernels/ref.py`` —
identical math, no interpreter overhead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_P = 1024


def _avg_disp_kernel(x_ref, o_ref, d_ref, *, groups):
    x = x_ref[...]                                   # (M, block_p) f32
    m, bp = x.shape
    glob = jnp.mean(x, axis=0)                       # (block_p,)
    d_ref[0, 0] = jnp.sum(jnp.square(x - glob[None])) / m
    if groups > 1:
        gm = jnp.mean(x.reshape(groups, m // groups, bp), axis=1)
        out = jnp.broadcast_to(gm[:, None], (groups, m // groups, bp))
        o_ref[...] = out.reshape(m, bp)
    else:
        o_ref[...] = jnp.broadcast_to(glob[None], (m, bp))


def _mix_disp_kernel(x_ref, w_ref, o_ref, d_ref):
    x = x_ref[...]                                   # (M, block_p) f32
    m = x.shape[0]
    glob = jnp.mean(x, axis=0)
    d_ref[0, 0] = jnp.sum(jnp.square(x - glob[None])) / m
    # the (M, M) @ (M, block_p) gossip mix rides the same column sweep:
    # M is tiny, so W lives whole in VMEM and the contraction hits the
    # MXU without extra plane traffic
    o_ref[...] = jnp.dot(w_ref[...], x, preferred_element_type=jnp.float32)


def _avg_disp_outer_kernel(x_ref, p_ref, v_ref, o_ref, a_ref, w_ref, d_ref,
                           *, lr, momentum, nesterov):
    x = x_ref[...]                                   # (M, block_p) f32
    m = x.shape[0]
    avg = jnp.mean(x, axis=0)
    d_ref[0, 0] = jnp.sum(jnp.square(x - avg[None])) / m
    g = p_ref[0] - avg                               # outer gradient
    vel = momentum * v_ref[0] + g
    step = momentum * vel + g if nesterov else vel
    upd = p_ref[0] - lr * step
    a_ref[0, :] = upd
    w_ref[0, :] = vel
    o_ref[...] = jnp.broadcast_to(upd[None], x.shape)


def _pad_cols(x, p_pad):
    p = x.shape[-1]
    if p_pad == p:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, p_pad - p)])


@functools.partial(jax.jit,
                   static_argnames=("groups", "block_p", "interpret"))
def avg_disp(plane, *, groups: int = 1, block_p: int = DEFAULT_BLOCK_P,
             interpret: bool | None = None):
    """plane: (M, P) float32 -> (averaged plane, Eq. 4 dispersion scalar).

    ``groups`` > 1 broadcasts per-group means (hierarchical inner
    average); the dispersion is always against the global mean."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, p = plane.shape
    assert groups >= 1 and m % groups == 0, (m, groups)
    block_p = min(block_p, max(p, 1))
    p_pad = -(-max(p, 1) // block_p) * block_p
    x = _pad_cols(plane.astype(jnp.float32), p_pad)
    nb = p_pad // block_p
    out, dpart = pl.pallas_call(
        functools.partial(_avg_disp_kernel, groups=groups),
        grid=(nb,),
        in_specs=[pl.BlockSpec((m, block_p), lambda i: (0, i))],
        out_specs=[
            pl.BlockSpec((m, block_p), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (i, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, p_pad), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return out[:, :p], jnp.sum(dpart)


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def mix_disp(plane, W, *, block_p: int = DEFAULT_BLOCK_P,
             interpret: bool | None = None):
    """Fused gossip mix + dispersion: plane (M, P) f32, W (M, M)
    doubly-stochastic f32 -> (W @ plane, Eq. 4 dispersion of the input
    plane). Each worker keeps its own mixed row — no broadcast. The
    generalization of :func:`avg_disp` to a mixing-matrix topology
    (``repro.topology``); matches ``repro.kernels.ref.mix_disp_ref``."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, p = plane.shape
    assert W.shape == (m, m), (W.shape, m)
    block_p = min(block_p, max(p, 1))
    p_pad = -(-max(p, 1) // block_p) * block_p
    x = _pad_cols(plane.astype(jnp.float32), p_pad)
    nb = p_pad // block_p
    out, dpart = pl.pallas_call(
        _mix_disp_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((m, block_p), lambda i: (0, i)),
                  pl.BlockSpec((m, m), lambda i: (0, 0))],
        out_specs=[
            pl.BlockSpec((m, block_p), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (i, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, p_pad), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, W.astype(jnp.float32))
    return out[:, :p], jnp.sum(dpart)


@functools.partial(jax.jit,
                   static_argnames=("lr", "momentum", "nesterov", "block_p",
                                    "interpret"))
def avg_disp_outer(plane, prev_avg, vel, *, lr: float, momentum: float,
                   nesterov: bool = True, block_p: int = DEFAULT_BLOCK_P,
                   interpret: bool | None = None):
    """Fused all-average + dispersion + outer momentum step.

    plane: (M, P) f32; prev_avg/vel: (P,) f32. Returns
    (averaged plane, new_avg, new_vel, dispersion) — the flat twin of
    ``worker_dispersion`` + ``consensus`` + ``OuterOptimizer.apply`` +
    ``replicate`` in one pass."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, p = plane.shape
    block_p = min(block_p, max(p, 1))
    p_pad = -(-max(p, 1) // block_p) * block_p
    x = _pad_cols(plane.astype(jnp.float32), p_pad)
    pa = _pad_cols(prev_avg.astype(jnp.float32)[None], p_pad)
    ve = _pad_cols(vel.astype(jnp.float32)[None], p_pad)
    nb = p_pad // block_p
    row = pl.BlockSpec((1, block_p), lambda i: (0, i))
    out, avg, new_vel, dpart = pl.pallas_call(
        functools.partial(_avg_disp_outer_kernel, lr=lr, momentum=momentum,
                          nesterov=nesterov),
        grid=(nb,),
        in_specs=[pl.BlockSpec((m, block_p), lambda i: (0, i)), row, row],
        out_specs=[
            pl.BlockSpec((m, block_p), lambda i: (0, i)), row, row,
            pl.BlockSpec((1, 1), lambda i: (i, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, p_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, p_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, p_pad), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, pa, ve)
    return out[:, :p], avg[0, :p], new_vel[0, :p], jnp.sum(dpart)

"""RG-LRU linear recurrence Pallas TPU kernel:  h_t = a_t h_{t-1} + b_t.

Grid (batch, width_blocks, seq_blocks), seq innermost; the recurrent
state (one (block_w,) fp32 vector) lives in VMEM scratch and persists
across the sequence blocks. Within a block the recurrence is stepped
sequentially over rows with full-width VPU vector ops — the idiomatic
TPU shape for elementwise RNNs (channels on lanes, time sequential),
cf. RecurrentGemma's reference scan kernel.

Channel blocks of 512 lanes x fp32 keep (a, b, h, out) well under VMEM
while giving the VPU full 8x128 tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_W = 512
DEFAULT_BLOCK_S = 256


def _rglru_kernel(a_ref, b_ref, o_ref, h_ref, *, block_s):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0]                      # (block_s, block_w) fp32
    b = b_ref[0]

    def step(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t, :] = h
        return h

    h_ref[...] = jax.lax.fori_loop(0, block_s, step, h_ref[...])


@functools.partial(jax.jit, static_argnames=("block_w", "block_s",
                                             "interpret"))
def rglru_scan(a, b, *, block_w: int = DEFAULT_BLOCK_W,
               block_s: int = DEFAULT_BLOCK_S,
               interpret: bool | None = None):
    """a, b: (B, S, W) (any float dtype; computed in fp32) -> (B, S, W)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bsz, s, w = a.shape
    block_w = min(block_w, w)
    block_s = min(block_s, s)
    assert w % block_w == 0, (w, block_w)
    s_pad = -(-s // block_s) * block_s
    if s_pad != s:
        # pad with identity steps (a=1, b=0) — they do not disturb state
        a = jnp.pad(a, ((0, 0), (0, s_pad - s), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, s_pad - s), (0, 0)))

    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    grid = (bsz, w // block_w, s_pad // block_s)
    out = pl.pallas_call(
        functools.partial(_rglru_kernel, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_w),
                         lambda bb, wi, si: (bb, si, wi)),
            pl.BlockSpec((1, block_s, block_w),
                         lambda bb, wi, si: (bb, si, wi)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_w),
                               lambda bb, wi, si: (bb, si, wi)),
        out_shape=jax.ShapeDtypeStruct((bsz, s_pad, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
    )(af, bf)
    return out[:, :s].astype(a.dtype)

"""Pallas fused optimizer step (+ optional averaging) on the (M, P) plane.

The phase engine's flat-native inner loop (paper Eq. 3: K cheap local
steps, then average) needs, per step: the optimizer update applied to
every worker row, and — on averaging steps — the worker mean (global or
per-group), the Eq. 4 dispersion, and the broadcast. Doing those as
separate passes costs 2–3 extra sweeps of the plane per averaging event
and a tree-mapped optimizer apply per local step; this kernel does
update + mean + dispersion + broadcast in ONE tiled pass.

Grid (P // block_p,): each program reads full-height (M, block_p) column
blocks of the param plane, the grad plane and the S optimizer-state
planes (S=0 SGD, 1 Momentum, 2 AdamW — layouts from
``repro.core.flat.FlatOptSpec``), applies the update on the VPU, reduces
over the worker axis (M rides in-block, as in ``avg_disp``), writes the
updated/broadcast block plus state blocks back, and emits its partial
dispersion into an SMEM slot. Dynamic per-step scalars (lr and the AdamW
bias corrections) arrive as one (1, 4) SMEM vector; per-column dtype
rounding codes (``FlatSpec.rounding_codes``) ride as an f32 row so
bf16/f16 params round exactly like the pytree optimizers.

On CPU the kernel runs in interpret mode for validation; the engine's
CPU path uses the jnp twin ``repro.kernels.ref.opt_step_ref`` (identical
math). On TPU the same call compiles to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_P = 1024
_KINDS = ("sgd", "momentum", "adamw")
_MODES = ("none", "mean", "group", "mix")


def _round_codes(x, codes):
    bf = x.astype(jnp.bfloat16).astype(jnp.float32)
    f16 = x.astype(jnp.float16).astype(jnp.float32)
    return jnp.where(codes == 1.0, bf, jnp.where(codes == 2.0, f16, x))


def _opt_step_kernel(*refs, kind, mode, groups, nstate, has_codes,
                     mu, nesterov, b1, b2, eps, weight_decay,
                     wire, error_feedback, p):
    compressed = wire is not None
    scaled = wire in ("int8", "one_bit")
    has_u = wire == "int8"
    i = 0
    x_ref, g_ref = refs[0], refs[1]
    i = 2
    s_refs = refs[i:i + nstate]
    i += nstate
    codes_ref = refs[i] if has_codes else None
    i += int(has_codes)
    w_ref = refs[i] if mode == "mix" else None
    i += int(mode == "mix")
    u_ref = refs[i] if has_u else None
    i += int(has_u)
    e_ref = refs[i] if compressed else None
    i += int(compressed)
    scal_ref = refs[i]
    i += 1
    o_ref = refs[i]
    s_out = refs[i + 1:i + 1 + nstate]
    i += 1 + nstate
    r_ref = refs[i] if compressed else None
    i += int(compressed)
    d_ref = refs[i]
    sc_ref = refs[i + 1] if scaled else None

    x = x_ref[...]                                   # (M, block_p) f32
    g = g_ref[...]
    lr = scal_ref[0, 0]
    if kind == "sgd":
        upd = x - lr * g
    elif kind == "momentum":
        v = mu * s_refs[0][...] + g
        upd = x - lr * (g + mu * v if nesterov else v)
        s_out[0][...] = v
    else:  # adamw
        c1, c2 = scal_ref[0, 1], scal_ref[0, 2]
        m2 = b1 * s_refs[0][...] + (1 - b1) * g
        v2 = b2 * s_refs[1][...] + (1 - b2) * g * g
        d = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
        upd = x - lr * (d + weight_decay * x)
        s_out[0][...] = m2
        s_out[1][...] = v2
    if has_codes:
        upd = _round_codes(upd, codes_ref[...])

    m, bp = upd.shape
    glob = jnp.mean(upd, axis=0)                     # (block_p,)
    # the Eq. 4 dispersion is emitted in EVERY mode: adaptive schedules
    # and the per-step diagnostic trace consume it on non-averaging
    # steps too (zero-padded columns are mean-0, so they contribute 0)
    d_ref[0, 0] = jnp.sum(jnp.square(upd - glob[None])) / m
    if compressed:
        # (2, nb) grid: the update is recomputed in both phases (same
        # inputs, same values); phase 0 accumulates the per-row scale
        # statistic across column blocks into VMEM scratch, phase 1
        # encodes, applies the event on the decoded q and writes the
        # plane + error-feedback residual
        ph, j = pl.program_id(0), pl.program_id(1)
        ve = upd + e_ref[...] if error_feedback else upd
        if scaled:
            part = (jnp.max(jnp.abs(ve), axis=1, keepdims=True)
                    if wire == "int8"
                    else jnp.sum(jnp.abs(ve), axis=1, keepdims=True))

            @pl.when((ph == 0) & (j == 0))
            def _init():
                sc_ref[...] = part

            @pl.when((ph == 0) & (j > 0))
            def _acc():
                sc_ref[...] = (jnp.maximum(sc_ref[...], part)
                               if wire == "int8" else sc_ref[...] + part)

        @pl.when(ph == 1)
        def _emit():
            if wire == "bf16":
                q = ve.astype(jnp.bfloat16).astype(jnp.float32)
            elif wire == "int8":
                amax = sc_ref[...]
                s = jnp.where(amax > 0.0, amax / 127.0, 1.0)
                q = jnp.clip(jnp.floor(ve / s + u_ref[...]),
                             -127.0, 127.0) * s
            else:  # one_bit
                s = sc_ref[...] / p
                q = jnp.where(ve >= 0.0, s, -s)
            if mode == "mix":
                out = jnp.dot(w_ref[...], q,
                              preferred_element_type=jnp.float32)
            elif mode == "group" and groups > 1:
                gm = jnp.mean(q.reshape(groups, m // groups, bp), axis=1)
                out = jnp.broadcast_to(gm[:, None],
                                       (groups, m // groups, bp))
                out = out.reshape(m, bp)
            else:
                out = jnp.broadcast_to(jnp.mean(q, axis=0)[None], (m, bp))
            if has_codes:
                out = _round_codes(out, codes_ref[...])
            o_ref[...] = out
            r_ref[...] = ve - q if error_feedback else e_ref[...]
        return
    if mode == "none":
        o_ref[...] = upd
        return
    if mode == "mix":
        # gossip topology: (M, M) @ (M, block_p) on the MXU — each
        # worker keeps its own mixed row, no broadcast (the dispersion
        # above stays the pre-mix diagnostic)
        out = jnp.dot(w_ref[...], upd, preferred_element_type=jnp.float32)
        if has_codes:
            out = _round_codes(out, codes_ref[...])
        o_ref[...] = out
        return
    if mode == "group" and groups > 1:
        gm = jnp.mean(upd.reshape(groups, m // groups, bp), axis=1)
        out = jnp.broadcast_to(gm[:, None], (groups, m // groups, bp))
        out = out.reshape(m, bp)
    else:
        out = jnp.broadcast_to(glob[None], (m, bp))
    if has_codes:
        out = _round_codes(out, codes_ref[...])
    o_ref[...] = out


def _pad_cols(x, p_pad):
    p = x.shape[-1]
    if p_pad == p:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, p_pad - p)])


@functools.partial(
    jax.jit,
    static_argnames=("kind", "mode", "groups", "mu", "nesterov", "b1", "b2",
                     "eps", "weight_decay", "wire", "error_feedback",
                     "block_p", "interpret"))
def opt_step(plane, grads, planes, scalars, *, kind, mode="none",
             groups: int = 1, W=None, mu=0.9, nesterov=False, b1=0.9,
             b2=0.95, eps=1e-8, weight_decay=0.0, codes=None,
             wire=None, resid=None, u=None, error_feedback: bool = True,
             alive=None, umask=None,
             block_p: int = DEFAULT_BLOCK_P, interpret: bool | None = None):
    """Fused optimizer step + optional averaging on the (M, P) plane.

    plane/grads: (M, P) f32; planes: tuple of S f32 state planes
    (``FlatOptSpec`` layout); scalars: (4,) f32 [lr, c1, c2, _];
    codes: optional (P,) f32 rounding codes. mode: "none" | "mean" |
    "group" | "mix" — "mix" applies the doubly-stochastic (M, M)
    mixing matrix ``W`` (``repro.topology``) after the update: each
    worker keeps its own mixed row, no broadcast. Returns
    (plane, state planes, Eq. 4 dispersion scalar).
    The dispersion of the post-update plane is emitted in every mode —
    "none" measures without averaging and "mix" pre-mix, so adaptive
    schedules and the per-step diagnostic trace see the true value on
    every step. Matches ``repro.kernels.ref.opt_step_ref``.

    ``wire`` (``repro.core.compress`` format ``bf16`` / ``int8`` /
    ``one_bit``; ``f32`` lowers to ``wire=None`` in the engine) fuses
    the compressed event into the pass: the error-feedback encode acts
    on the post-update plane (``resid`` the (M, P) residual, ``u`` the
    int8 ``row_uniforms`` plane), the event operator on the decoded
    ``q``. The scaled formats need a per-row statistic spanning all
    column blocks, so the grid becomes (2, nb) — phase 0 accumulates
    the row scales into VMEM scratch, phase 1 quantizes and applies the
    event. Returns (plane, state planes, new residual, dispersion).

    ``alive`` / ``umask`` ((M,) f32, ``repro.faults``) run the
    fault-degraded pass: the fused update kernel runs in "none" mode
    and only rows with ``umask > 0`` keep the result (dead and
    straggling rows must not advance optimizer momentum), then the
    masked event rides the SAME fused mix kernels — masked means lower
    to ``faults.masked_event_matrix``, gossip ``W`` to
    ``faults.degraded_matrix`` — with the dispersion over the alive
    set. Matches the masked ``opt_step_ref`` up to matmul rounding.
    """
    assert kind in _KINDS, kind
    assert mode in _MODES, mode
    assert (W is not None) == (mode == "mix"), (mode, W is None)
    if alive is not None:
        from repro import faults as _faults
        from repro.kernels import avg_disp as _avg
        if umask is None:
            umask = alive
        upd, new_planes, _ = opt_step(
            plane, grads, planes, scalars, kind=kind, mode="none",
            mu=mu, nesterov=nesterov, b1=b1, b2=b2, eps=eps,
            weight_decay=weight_decay, codes=codes, block_p=block_p,
            interpret=interpret)
        upd = _faults.select_rows(upd, plane, umask)
        new_planes = tuple(_faults.select_rows(n, o, umask)
                           for n, o in zip(new_planes, planes))
        if wire is not None and mode != "none":
            out, r_new, disp = _avg.compressed_mix(
                upd, resid, wire=wire, mode=mode, groups=groups, W=W,
                u=u, codes=codes, error_feedback=error_feedback,
                alive=alive, block_p=block_p, interpret=interpret)
            return out, new_planes, r_new, disp
        if mode == "none":
            return upd, new_planes, _faults.masked_dispersion(upd, alive)
        if mode == "mix":
            out, disp = _avg.mix_disp(upd, W, alive=alive,
                                      block_p=block_p, interpret=interpret)
        else:
            out, disp = _avg.avg_disp(
                upd, groups=groups if mode == "group" else 1,
                alive=alive, block_p=block_p, interpret=interpret)
        if codes is not None:
            out = _round_codes(out, jnp.asarray(codes, jnp.float32)[None])
            out = _faults.select_rows(out, upd, alive)
        return out, new_planes, disp
    compressed = wire is not None
    assert not compressed or (wire in ("bf16", "int8", "one_bit")
                              and mode != "none"), (wire, mode)
    has_u = wire == "int8"
    assert (u is not None) == has_u, (wire, u is None)
    assert (resid is not None) == compressed, (wire, resid is None)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, p = plane.shape
    assert groups >= 1 and m % groups == 0, (m, groups)
    nstate = len(planes)
    block_p = min(block_p, max(p, 1))
    p_pad = -(-max(p, 1) // block_p) * block_p
    nb = p_pad // block_p
    has_codes = codes is not None

    # the compressed path runs a (2, nb) grid — index maps drop the
    # phase coordinate
    if compressed:
        blk = pl.BlockSpec((m, block_p), lambda ph, i: (0, i))
        row = pl.BlockSpec((1, block_p), lambda ph, i: (0, i))
        whole = lambda shape: pl.BlockSpec(shape, lambda ph, i: (0, 0))
        dspec = pl.BlockSpec((1, 1), lambda ph, i: (i, 0),
                             memory_space=pltpu.SMEM)
        grid = (2, nb)
    else:
        blk = pl.BlockSpec((m, block_p), lambda i: (0, i))
        row = pl.BlockSpec((1, block_p), lambda i: (0, i))
        whole = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))
        dspec = pl.BlockSpec((1, 1), lambda i: (i, 0),
                             memory_space=pltpu.SMEM)
        grid = (nb,)

    x = _pad_cols(plane.astype(jnp.float32), p_pad)
    g = _pad_cols(grads.astype(jnp.float32), p_pad)
    ins = [x, g] + [_pad_cols(s.astype(jnp.float32), p_pad) for s in planes]
    in_specs = [blk, blk] + [blk] * nstate
    if has_codes:
        ins.append(_pad_cols(jnp.asarray(codes, jnp.float32)[None], p_pad))
        in_specs.append(row)
    if mode == "mix":
        assert W.shape == (m, m), (W.shape, m)
        ins.append(W.astype(jnp.float32))
        in_specs.append(whole((m, m)))
    if has_u:
        ins.append(_pad_cols(u.astype(jnp.float32), p_pad))
        in_specs.append(blk)
    if compressed:
        ins.append(_pad_cols(resid.astype(jnp.float32), p_pad))
        in_specs.append(blk)
    ins.append(jnp.asarray(scalars, jnp.float32).reshape(1, 4))
    in_specs.append(pl.BlockSpec((1, 4), (lambda ph, i: (0, 0)) if compressed
                                 else (lambda i: (0, 0)),
                                 memory_space=pltpu.SMEM))

    nplanes_out = 1 + nstate + int(compressed)
    out_shape = ([jax.ShapeDtypeStruct((m, p_pad), jnp.float32)]
                 * nplanes_out
                 + [jax.ShapeDtypeStruct((nb, 1), jnp.float32)])
    out_specs = [blk] * nplanes_out + [dspec]
    outs = pl.pallas_call(
        functools.partial(_opt_step_kernel, kind=kind, mode=mode,
                          groups=groups, nstate=nstate, has_codes=has_codes,
                          mu=mu, nesterov=nesterov, b1=b1, b2=b2, eps=eps,
                          weight_decay=weight_decay, wire=wire,
                          error_feedback=error_feedback, p=p),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=([pltpu.VMEM((m, 1), jnp.float32)]
                        if wire in ("int8", "one_bit") else []),
        interpret=interpret,
    )(*ins)
    out, dpart = outs[0], outs[-1]
    new_planes = tuple(o[:, :p] for o in outs[1:1 + nstate])
    if compressed:
        return (out[:, :p], new_planes, outs[1 + nstate][:, :p],
                jnp.sum(dpart))
    return out[:, :p], new_planes, jnp.sum(dpart)

"""Jit'd dispatch layer over the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the
kernel body runs in Python for correctness validation; on TPU the same
calls compile to Mosaic. The model code (repro.models.*) calls these via
``impl="pallas"``.
"""
from repro.kernels.avg_disp import avg_disp, avg_disp_outer  # noqa: F401
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.rglru_scan import rglru_scan  # noqa: F401
from repro.kernels.rwkv6_scan import rwkv6_scan  # noqa: F401

"""Production meshes.

Single pod:  (16, 16)    axes ("data", "model")          = 256 chips (v5e pod)
Multi-pod:   (2, 16, 16) axes ("pod", "data", "model")   = 512 chips

The local-SGD *worker* axis is "data" (16 workers) on a single pod; on
multiple pods it is either ("pod","data") flat (32 workers) or
hierarchical — inner averages over "data", rare outer averages over
"pod" (DCI-friendly; see repro.core.averaging).
"""
from __future__ import annotations

import jax

from repro.sharding.specs import set_axis_sizes


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    set_axis_sizes(dict(zip(axes, shape)))
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh over host devices for tests (needs
    XLA_FLAGS=--xla_force_host_platform_device_count set in the test
    process *before* jax initializes)."""
    if pod:
        shape, axes = (pod, data, model), ("pod", "data", "model")
    else:
        shape, axes = (data, model), ("data", "model")
    set_axis_sizes(dict(zip(axes, shape)))
    return jax.make_mesh(shape, axes)


def make_worker_mesh(num_workers: int):
    """1-D ("data",) mesh over the available devices for the sharded
    flat engine: uses the largest device count that divides
    ``num_workers`` (every shard must hold the same number of worker
    rows). On CPU, launch with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to validate
    the sharded path without accelerators."""
    n = min(num_workers, len(jax.devices()))
    while num_workers % n:
        n -= 1
    set_axis_sizes({"data": n})
    return jax.make_mesh((n,), ("data",))


def worker_axes(mesh, *, hierarchical: bool = False):
    """Mesh axes that form the local-SGD worker axis."""
    if "pod" in mesh.axis_names:
        return ("data",) if hierarchical else ("pod", "data")
    return ("data",)


def num_workers(mesh, *, hierarchical: bool = False) -> int:
    n = 1
    for a in worker_axes(mesh, hierarchical=hierarchical):
        n *= mesh.shape[a]
    return n

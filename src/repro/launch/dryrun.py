import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before jax initializes: the dry-run builds
# the production mesh (256-chip pod / 512-chip multi-pod) out of host
# placeholder devices. Everything else (tests, benches) sees 1 device.

import argparse   # noqa: E402
import json       # noqa: E402
import sys        # noqa: E402
import time       # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (ARCHS, get_config, get_shape,  # noqa: E402
                           long_context_variant, SHAPES)
from repro.launch import steps  # noqa: E402
from repro.launch.mesh import make_production_mesh, worker_axes  # noqa: E402
from repro.roofline.analysis import model_flops, roofline_report  # noqa: E402
from repro.sharding import specs as S  # noqa: E402


def _axis_entry(axes: tuple):
    return axes[0] if len(axes) == 1 else tuple(axes)


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def serve_batch_specs(batch_t, mesh):
    daxes = tuple(a for a in mesh.axis_names if a != "model")
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    msize = mesh.shape["model"]

    def spec(leaf):
        dims = leaf.shape
        entries = [None] * len(dims)
        start = 0
        if dims and dims[0] % dsize == 0 and dims[0] >= dsize:
            entries[0] = _axis_entry(daxes)
            start = 1
        for i in range(start, len(dims)):
            if dims[i] % msize == 0 and dims[i] >= msize:
                entries[i] = "model"
                break
        return P(*entries)

    return jax.tree.map(spec, batch_t)


def skip_reason(arch: str, shape_name: str) -> str | None:
    if shape_name == "long_500k" and arch == "whisper-small":
        return ("enc-dec audio model: no 500k-token decode configuration "
                "(DESIGN.md §4)")
    return None


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool,
                avg: str = "none", impl: str = "xla", remat: bool = True,
                expert_parallel: bool = False, banded: bool = False,
                score_bf16: bool = False, cache_layout: str = "seq",
                moe_group: int = 0, phase_steps: int = 4,
                verbose: bool = True):
    """Lower + compile one (arch × shape × mesh) combination.
    Returns (compiled, lowered, meta)."""
    reason = skip_reason(arch, shape_name)
    if reason:
        return None, None, {"skipped": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    msize = mesh.shape["model"]
    chips = mesh.devices.size
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if shape_name == "long_500k":
        cfg = long_context_variant(cfg)
    import dataclasses as _dc
    if banded:
        cfg = _dc.replace(cfg, attn_banded=True)
    if score_bf16:
        cfg = _dc.replace(cfg, score_dtype="bfloat16")
    if moe_group:
        cfg = _dc.replace(cfg, moe_group_size=moe_group)

    t0 = time.time()
    if shape.kind == "train":
        # Lower the ENGINE's compiled phase: a scan of phase_steps local
        # steps with the phase-end average fused in (one dispatch per
        # phase, one cross-worker all-reduce) — the program production
        # training actually runs, not a single step.
        waxes = worker_axes(mesh)
        W = 1
        for a in waxes:
            W *= mesh.shape[a]
        wentry = _axis_entry(waxes)
        opt = steps.make_optimizer()
        wp_t, os_t = steps.abstract_worker_state(cfg, opt, W)
        batch_t = steps.input_specs(cfg, shape, num_workers=W)
        phase_batch_t = jax.tree.map(
            lambda s: steps.sds((phase_steps,) + s.shape, s.dtype), batch_t)
        inner = mesh.shape["pod"] if (avg == "hier" and "pod" in mesh.axis_names) else 0
        fn = steps.make_phase_step(
            cfg, phase_len=phase_steps, impl=impl, remat=remat,
            avg={"none": "none", "hier": "inner"}.get(avg, "all"),
            inner_groups=inner, optimizer=opt)
        p_specs = S.param_specs(wp_t, msize, worker_axes=wentry,
                                moe_expert_parallel=expert_parallel)
        o_specs = S.param_specs(os_t, msize, worker_axes=wentry,
                                moe_expert_parallel=expert_parallel)
        b_specs = jax.tree.map(
            lambda sp: P(None, *sp),  # leading K (scan) dim unsharded
            S.batch_specs(batch_t, msize, worker_axes=wentry),
            is_leaf=lambda x: isinstance(x, P))
        step_t = steps.sds((), jnp.int32)
        in_sh = (_ns(mesh, p_specs), _ns(mesh, o_specs),
                 _ns(mesh, b_specs), NamedSharding(mesh, P()))
        out_sh = (_ns(mesh, p_specs), _ns(mesh, o_specs), None)
        args = (wp_t, os_t, phase_batch_t, step_t)
    elif shape.kind == "prefill":
        p_t = steps.abstract_params(cfg)
        batch_t = steps.input_specs(cfg, shape)
        fn = steps.make_prefill_step(cfg, impl=impl)
        p_specs = S.param_specs(p_t, msize,
                                moe_expert_parallel=expert_parallel)
        in_sh = (_ns(mesh, p_specs), _ns(mesh, serve_batch_specs(batch_t, mesh)))
        out_sh = None
        args = (p_t, batch_t)
    else:  # decode
        p_t = steps.abstract_params(cfg)
        batch_t = steps.input_specs(cfg, shape)
        cache_t = steps.abstract_cache(cfg, shape)
        fn = steps.make_decode_step(cfg)
        p_specs = S.param_specs(p_t, msize,
                                moe_expert_parallel=expert_parallel)
        daxes = tuple(a for a in mesh.axis_names if a != "model")
        c_specs = S.cache_specs(cache_t, msize, data_axes=_axis_entry(daxes),
                                long_layout=cache_layout)
        in_sh = (_ns(mesh, p_specs),
                 _ns(mesh, serve_batch_specs(batch_t, mesh)["tokens"]),
                 _ns(mesh, c_specs))
        out_sh = (None, _ns(mesh, c_specs))
        args = (p_t, batch_t["tokens"], cache_t)

    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    flops = model_flops(cfg, shape, training=shape.kind == "train")
    if shape.kind == "train":
        flops *= phase_steps  # the lowered program is a whole phase
    meta = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "avg": avg, "chips": chips,
        "phase_steps": phase_steps if shape.kind == "train" else 0,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "model_flops": flops,
        "expert_parallel": expert_parallel,
        "variant": "+".join(filter(None, [
            "banded" if banded else "", "bf16scores" if score_bf16 else "",
            f"cache-{cache_layout}" if cache_layout != "seq" else "",
            "ep" if expert_parallel else "",
            f"moegroup{moe_group}" if moe_group else "",
            "" if remat else "no-remat"])) or "baseline",
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {meta['mesh']} avg={avg} "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s", flush=True)
    return compiled, lowered, meta


def run_one(arch, shape_name, *, multi_pod, avg="none",
            expert_parallel=False, banded=False, score_bf16=False,
            cache_layout="seq", remat=True, moe_group=0, phase_steps=4,
            verbose=True):
    compiled, lowered, meta = lower_combo(
        arch, shape_name, multi_pod=multi_pod, avg=avg,
        expert_parallel=expert_parallel, banded=banded,
        score_bf16=score_bf16, cache_layout=cache_layout, remat=remat,
        moe_group=moe_group, phase_steps=phase_steps, verbose=verbose)
    if compiled is None:
        return meta
    rep = roofline_report(compiled, model_flops=meta["model_flops"],
                          chips=meta["chips"])
    meta.update(rep)
    if verbose:
        print("         memory_analysis: " +
              ", ".join(f"{k.removeprefix('mem_')}={v/2**30:.2f}GiB"
                        for k, v in meta.items() if k.startswith("mem_")),
              flush=True)
        print(f"         flops/dev={rep['flops_per_device']:.3e} "
              f"bytes/dev={rep['bytes_per_device']:.3e} "
              f"coll/dev={rep['collective_bytes_per_device']:.3e} "
              f"bottleneck={rep['bottleneck']}", flush=True)
    return meta


def main(argv=None):
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, choices=ARCHS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--avg", default="none", choices=["none", "all", "hier"])
    ap.add_argument("--expert-parallel", action="store_true")
    ap.add_argument("--banded", action="store_true",
                    help="banded sliding-window attention (perf variant)")
    ap.add_argument("--score-bf16", action="store_true",
                    help="bf16 attention score traffic (perf variant)")
    ap.add_argument("--cache-layout", default="seq",
                    choices=["seq", "heads"],
                    help="long-context decode cache layout (perf variant)")
    ap.add_argument("--moe-group", type=int, default=0,
                    help="MoE dispatch group size (perf variant; 0 = "
                         "global capacity baseline)")
    ap.add_argument("--phase-steps", type=int, default=4,
                    help="local steps per compiled averaging phase for "
                         "train shapes (the engine's scan length K)")
    ap.add_argument("--no-remat", action="store_true",
                    help="disable per-block remat (used for the multi-pod "
                         "compile-proof pass on the largest archs, where "
                         "remat doubles XLA compile time; noted in the "
                         "output row)")
    ap.add_argument("--out", default=None, help="append JSONL results here")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    variant = "+".join(filter(None, [
        "banded" if args.banded else "",
        "bf16scores" if args.score_bf16 else "",
        f"cache-{args.cache_layout}" if args.cache_layout != "seq" else "",
        "ep" if args.expert_parallel else "",
        f"moegroup{args.moe_group}" if args.moe_group else "",
        "no-remat" if args.no_remat else ""])) or "baseline"

    done = set()
    if args.out and args.skip_done and os.path.exists(args.out):
        for line in open(args.out):
            try:
                r = json.loads(line)
                done.add((r["arch"], r["shape"], r["mesh"],
                          r.get("avg", "none"), r.get("variant", "baseline")))
            except Exception:
                pass

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                key = (arch, shape_name, mesh_name, args.avg, variant)
                if key in done:
                    continue
                try:
                    meta = run_one(arch, shape_name, multi_pod=mp,
                                   avg=args.avg,
                                   expert_parallel=args.expert_parallel,
                                   banded=args.banded,
                                   score_bf16=args.score_bf16,
                                   cache_layout=args.cache_layout,
                                   remat=not args.no_remat,
                                   moe_group=args.moe_group,
                                   phase_steps=args.phase_steps)
                except Exception as e:  # a failure here is a bug — surface it
                    failures.append((key, repr(e)))
                    print(f"[dryrun] FAIL {key}: {e!r}", flush=True)
                    continue
                if args.out:
                    meta.setdefault("arch", arch)
                    meta.setdefault("shape", shape_name)
                    meta.setdefault("mesh", mesh_name)
                    with open(args.out, "a") as f:
                        f.write(json.dumps(meta) + "\n")
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES", flush=True)
        sys.exit(1)
    print("[dryrun] all combinations lowered + compiled OK", flush=True)


if __name__ == "__main__":
    main()

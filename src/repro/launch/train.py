"""Training CLI: local-SGD training of any assigned architecture.

On this CPU container use ``--reduced`` (the full configs are exercised
by the dry-run); on a real TPU mesh the same driver shards the worker
axis over ("pod","data") via the dry-run's sharding rules.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --reduced --steps 100 --workers 4 --avg periodic --phase-len 10
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (load_engine_state, save_checkpoint,
                              save_engine_state)
from repro.configs import ARCHS, get_config
from repro.core import (AveragingSchedule, Compression, OuterOptimizer,
                        PhaseEngine, WIRE_FORMATS)
from repro.topology import KINDS as TOPOLOGY_KINDS
from repro.topology import Topology
from repro.data import token_stream
from repro.launch.mesh import make_worker_mesh
from repro.models import init_params, lm_loss
from repro.optim import AdamW, Momentum
from repro.telemetry import (JsonlSink, make_record, profile_trace,
                             run_meta_record)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--avg", default="periodic",
                    choices=["oneshot", "minibatch", "periodic",
                             "stochastic", "hierarchical",
                             "adaptive_threshold", "adaptive_budget",
                             "adaptive_bytes"])
    ap.add_argument("--phase-len", type=int, default=10)
    ap.add_argument("--zeta", type=float, default=0.01)
    ap.add_argument("--disp-threshold", type=float, default=0.0,
                    help="adaptive_threshold: average when the running "
                         "EMA of the Eq. 4 worker dispersion crosses "
                         "this level (required > 0)")
    ap.add_argument("--disp-ema-beta", type=float, default=0.9,
                    help="adaptive schedules: dispersion EMA decay "
                         "(0 <= beta < 1)")
    ap.add_argument("--comm-budget", type=int, default=0,
                    help="adaptive_budget: max averaging events over "
                         "the budget horizon (required >= 1)")
    ap.add_argument("--budget-horizon", type=int, default=0,
                    help="adaptive_budget / adaptive_bytes: steps the "
                         "budget spans (default 0 -> --steps)")
    ap.add_argument("--comm-dtype", default="f32",
                    choices=list(WIRE_FORMATS),
                    help="wire precision of averaging/mixing events "
                         "(repro.core.compress): f32 ships the rows "
                         "uncompressed (bit-identical to no "
                         "compression); bf16/int8/one_bit quantize "
                         "them, int8/one_bit with an error-feedback "
                         "residual plane")
    ap.add_argument("--byte-budget", type=int, default=0,
                    help="adaptive_bytes: max bytes ONE worker puts on "
                         "the wire over the budget horizon (required "
                         ">= the cost of one event at the chosen "
                         "topology x --comm-dtype)")
    ap.add_argument("--error-feedback", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="carry the error-feedback residual plane "
                         "(required for int8/one_bit wire formats; "
                         "--no-error-feedback is only valid for bf16)")
    ap.add_argument("--topology", default=None,
                    choices=list(TOPOLOGY_KINDS),
                    help="mixing topology for the averaging events "
                         "(repro.topology): every event becomes one "
                         "doubly-stochastic W @ plane mix over this "
                         "communication graph; 'full' is bit-identical "
                         "to the default mean, 'groups' to the "
                         "inner-groups block mean")
    ap.add_argument("--topology-groups", type=int, default=2,
                    help="--topology groups: number of block-diagonal "
                         "worker groups (must divide --workers)")
    ap.add_argument("--inner-groups", type=int, default=2,
                    help="hierarchical averaging: number of inner worker "
                         "groups (must divide --workers)")
    ap.add_argument("--outer-phase-len", type=int, default=0,
                    help="hierarchical averaging: all-worker period "
                         "(default 0 -> 8 x --phase-len)")
    ap.add_argument("--optimizer", default="momentum",
                    choices=["momentum", "adamw"])
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--outer-momentum", type=float, default=0.0,
                    help=">0 enables the beyond-paper DiLoCo-style outer "
                         "optimizer at averaging steps")
    ap.add_argument("--scan-unroll", type=int, default=1,
                    help="lax.scan unroll for the phase engine (0 = full "
                         "unroll; speeds up compute-heavy bodies on CPU)")
    ap.add_argument("--tree-engine", action="store_true",
                    help="carry the params pytree through the phase scan "
                         "instead of the default flat (M, P) plane "
                         "(PR 1 baseline path)")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="stage phase blocks synchronously instead of via "
                         "the double-buffered prefetch thread")
    ap.add_argument("--no-fused-opt", action="store_true",
                    help="disable the flat-native fused optimizer planes "
                         "(PR 2 behavior: per-step pack/unpack around the "
                         "tree-mapped optimizer)")
    ap.add_argument("--shard", action="store_true",
                    help="shard the flat (M, P) plane's worker axis over "
                         "the available devices via shard_map (on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N first)")
    ap.add_argument("--collective", default="psum",
                    choices=["psum", "gather"],
                    help="sharded averaging collective: psum (production; "
                         "one psum of column sums per event) or gather "
                         "(validation; bit-identical to single-device)")
    ap.add_argument("--faults", default=None,
                    help="deterministic fault script (repro.faults): "
                         "comma-separated kind:m=<row>@t=<step> events, "
                         "e.g. 'crash:m=3@t=100,rejoin:m=3@t=200' — "
                         "crashed rows drop out of every update and "
                         "averaging event, rejoining rows warm-start "
                         "from the alive consensus")
    ap.add_argument("--straggle-prob", type=float, default=0.0,
                    help="per-worker per-step probability of skipping "
                         "the local update (still receives the mix); "
                         "drawn from the deterministic fold_in stream, "
                         "so every engine path replays the identical "
                         "straggler pattern")
    ap.add_argument("--rejoin", type=int, default=0,
                    help="auto-rejoin every scripted crash N steps "
                         "later (crashes with a later scripted event "
                         "for the same worker are left alone)")
    ap.add_argument("--shrink-at", action="append", default=[],
                    metavar="STEP:M'",
                    help="elastic membership (repro.elastic): shrink "
                         "the live worker plane to M' rows before STEP "
                         "runs — the dropped rows' memory, compute and "
                         "collective bandwidth are actually freed "
                         "(repeatable; composes with --grow-at)")
    ap.add_argument("--grow-at", action="append", default=[],
                    metavar="STEP:M'",
                    help="elastic membership: grow the live worker "
                         "plane to M' rows before STEP runs; new rows "
                         "warm-start from the mixing-cohort consensus "
                         "with optimizer planes zeroed (repeatable)")
    ap.add_argument("--rejoin-curriculum", type=int, default=0,
                    help="solo steps a rejoined or grown worker trains "
                         "before its iterate re-enters averaging (it "
                         "updates locally but is masked out of every "
                         "mix, the loss and the dispersion)")
    ap.add_argument("--straggle-aware", action="store_true",
                    help="adaptive schedules only: discount the "
                         "measured dispersion by the fraction of the "
                         "mixing cohort that actually updated, so "
                         "straggler-widened dispersion does not "
                         "trigger spurious averaging events")
    ap.add_argument("--non-iid-alpha", type=float, default=0.0,
                    help="> 0 enables Dirichlet(alpha) label-skewed "
                         "(non-IID) worker shards for dataset-backed "
                         "runs; the synthetic token stream has no "
                         "labels, so this CLI only validates and "
                         "records the setting")
    ap.add_argument("--impl", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="write structured run telemetry to this JSONL "
                         "file (repro.telemetry): a run_meta header, "
                         "one phase_metrics record per compiled phase "
                         "(flushed from the on-device accumulator with "
                         "the phase's single trace fetch), plus "
                         "averaging/fault/resize/checkpoint events — "
                         "render with python -m repro.telemetry.report")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the run into "
                         "this directory (TensorBoard-loadable)")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--resume", default=None,
                    help="path of a full-EngineState checkpoint "
                         "(--checkpoint writes <path>.state) to resume "
                         "from; --steps counts additional steps")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.avg == "hierarchical":
        if args.inner_groups < 1 or args.workers % args.inner_groups:
            ap.error(f"--workers ({args.workers}) must be divisible by "
                     f"--inner-groups ({args.inner_groups})")
        outer_len = args.outer_phase_len or args.phase_len * 8
        if args.phase_len >= outer_len:
            # every multiple of the outer period wins the decision, so an
            # inner period >= the outer one silently never (or only
            # degenerately) inner-averages — refuse at parse time
            ap.error(f"--avg hierarchical needs the inner period "
                     f"(--phase-len, {args.phase_len}) < the outer period "
                     f"(--outer-phase-len, {outer_len}); as given it "
                     "would never inner-average")
    if args.avg == "stochastic" and not 0.0 < args.zeta <= 1.0:
        ap.error(f"--avg stochastic needs 0 < --zeta <= 1, got "
                 f"{args.zeta} (other schedules ignore --zeta)")
    if args.avg == "adaptive_threshold" and args.disp_threshold <= 0.0:
        ap.error("--avg adaptive_threshold needs --disp-threshold > 0 "
                 "(the Eq. 4 dispersion level that triggers averaging)")
    if args.avg == "adaptive_budget":
        horizon = args.budget_horizon or args.steps
        if args.comm_budget < 1:
            ap.error("--avg adaptive_budget needs --comm-budget >= 1")
        if args.comm_budget > horizon:
            ap.error(f"--comm-budget ({args.comm_budget}) cannot exceed "
                     f"the budget horizon ({horizon} steps): at most one "
                     "averaging event per step")
    if args.avg == "adaptive_bytes" and args.byte_budget < 1:
        ap.error("--avg adaptive_bytes needs --byte-budget >= 1 (bytes "
                 "one worker may put on the wire over the horizon)")
    try:
        # int8/one_bit without the error-feedback residual diverge —
        # Compression refuses the combination; surface its message at
        # parse time instead of deep inside engine setup
        compression = Compression(args.comm_dtype,
                                  error_feedback=args.error_feedback)
    except ValueError as e:
        ap.error(f"--comm-dtype {args.comm_dtype}: {e}")
    if args.outer_momentum > 0 and args.comm_dtype != "f32":
        ap.error(f"--outer-momentum steps on the exact consensus mean, "
                 f"which a {args.comm_dtype} wire never forms — use "
                 "--comm-dtype f32 or drop the outer optimizer")
    faults = None
    if args.faults or args.straggle_prob > 0:
        from repro.faults import FaultPlan
        if not 0.0 <= args.straggle_prob <= 1.0:
            ap.error(f"--straggle-prob must be in [0, 1], got "
                     f"{args.straggle_prob}")
        if args.rejoin < 0:
            ap.error(f"--rejoin must be >= 0, got {args.rejoin}")
        try:
            # FaultPlan validates eagerly: rows in [0, workers), steps
            # >= 1, crash/rejoin alternation per worker (a rejoin
            # needs a prior crash), never-all-dead — surface its
            # message at parse time instead of deep inside a trace
            faults = FaultPlan.parse(
                args.faults or "", args.workers,
                straggle_prob=args.straggle_prob,
                rejoin_after=args.rejoin,
                rejoin_curriculum=max(args.rejoin_curriculum, 0))
        except ValueError as e:
            ap.error(f"--faults: {e}")
        if args.outer_momentum > 0:
            ap.error("--outer-momentum steps on the full-membership "
                     "consensus mean, which a faulty run never forms — "
                     "drop --faults/--straggle-prob or the outer "
                     "optimizer")
    elif args.rejoin:
        ap.error("--rejoin without --faults has no crash to rejoin "
                 "from")
    if args.rejoin_curriculum < 0:
        ap.error(f"--rejoin-curriculum must be >= 0, got "
                 f"{args.rejoin_curriculum}")
    if args.straggle_aware:
        if args.avg not in ("adaptive_threshold", "adaptive_budget",
                            "adaptive_bytes"):
            ap.error(f"--straggle-aware discounts the dispersion fed to "
                     f"the adaptive schedules; --avg {args.avg} never "
                     "consumes dispersion — use an adaptive_* schedule "
                     "or drop the flag")
        if args.straggle_prob <= 0.0:
            ap.error("--straggle-aware needs --straggle-prob > 0 — "
                     "with no stragglers there is nothing to discount")
    elastic = None
    if args.shrink_at or args.grow_at:
        from repro.elastic import ElasticPlan
        try:
            # ElasticPlan.parse validates eagerly: step:M' syntax,
            # strictly increasing steps >= 2, shrinks shrink and grows
            # grow relative to the running membership
            elastic = ElasticPlan.parse(
                args.workers, shrink_at=args.shrink_at,
                grow_at=args.grow_at,
                curriculum=args.rejoin_curriculum)
        except ValueError as e:
            ap.error(f"--shrink-at/--grow-at: {e}")
        if args.outer_momentum > 0:
            ap.error("--outer-momentum steps on a fixed-membership "
                     "consensus mean, which an elastic run never keeps "
                     "— drop --shrink-at/--grow-at or the outer "
                     "optimizer")
        for m in elastic.sizes():
            # every membership the run passes through must satisfy the
            # same topology / inner-groups constraints as the initial M
            if args.avg == "hierarchical" and m % args.inner_groups:
                ap.error(f"resize target M'={m} is not divisible by "
                         f"--inner-groups ({args.inner_groups}) — "
                         "hierarchical averaging needs every membership "
                         "the run passes through to split evenly")
            if args.topology and m != args.workers:
                try:
                    Topology.build(args.topology, m,
                                   groups=args.topology_groups)
                except ValueError as e:
                    ap.error(f"resize target M'={m} is incompatible "
                             f"with --topology {args.topology}: {e}")
    elif args.rejoin_curriculum and not (faults and faults.has_rejoin):
        ap.error("--rejoin-curriculum without --grow-at or a rejoin "
                 "fault event has no worker to run a curriculum for")
    if args.non_iid_alpha < 0:
        ap.error(f"--non-iid-alpha must be >= 0, got "
                 f"{args.non_iid_alpha}")
    topology = None
    if args.topology:
        # invalid topology/worker-count combinations (ring needs M >= 3,
        # torus a composite M, gossip_pairs an even M, ...) surface here
        # at parse time with the builders' actionable messages instead
        # of deep inside a trace
        try:
            topology = Topology.build(args.topology, args.workers,
                                      groups=args.topology_groups)
        except ValueError as e:
            ap.error(f"--topology {args.topology}: {e}")
        if args.outer_momentum > 0 and args.topology != "full":
            ap.error(f"--outer-momentum steps on the consensus mean, "
                     f"which --topology {args.topology} never forms — "
                     "use --topology full or drop the outer optimizer")

    cfg = get_config(args.arch, reduced=args.reduced)
    if args.reduced:
        cfg = dataclasses.replace(cfg, dtype="float32")
    print(f"[train] {cfg.name}: {cfg.num_params()/1e6:.1f}M params, "
          f"{args.workers} workers, avg={args.avg}")

    if args.avg == "adaptive_bytes":
        # one event's wire cost at this topology x precision: a budget
        # below it silently never averages — refuse up front
        from repro.topology import comm_bytes
        event_cost = comm_bytes(topology or Topology.full(args.workers),
                                1, int(cfg.num_params()), args.comm_dtype)
        if args.byte_budget < event_cost:
            ap.error(f"--byte-budget ({args.byte_budget}) is below the "
                     f"cost of ONE averaging event at this configuration "
                     f"({event_cost} B/worker: "
                     f"{args.topology or 'full'} topology, "
                     f"{args.comm_dtype} wire, "
                     f"{int(cfg.num_params())} params) — the schedule "
                     "would never fire")

    params = init_params(cfg, jax.random.PRNGKey(args.seed))

    def loss_fn(p, batch, rng):
        return lm_loss(cfg, p, batch, impl=args.impl)

    opt = (Momentum(lr=args.lr, mu=0.9) if args.optimizer == "momentum"
           else AdamW(lr=args.lr))
    sch = AveragingSchedule(
        kind=args.avg, phase_len=args.phase_len, zeta=args.zeta,
        inner_phase_len=args.phase_len,
        outer_phase_len=args.outer_phase_len or args.phase_len * 8,
        # only hierarchical consumes inner groups, but the lax.switch
        # traces the inner branch for every kind — a non-dividing
        # (dead) group count would still fail the reshape under trace
        inner_groups=(args.inner_groups if args.avg == "hierarchical"
                      else 1),
        disp_threshold=args.disp_threshold,
        disp_ema_beta=args.disp_ema_beta,
        comm_budget=args.comm_budget,
        byte_budget=args.byte_budget,
        budget_horizon=args.budget_horizon or args.steps,
        straggle_aware=args.straggle_aware)
    outer = (OuterOptimizer(lr=1.0, momentum=args.outer_momentum)
             if args.outer_momentum > 0 else None)
    mesh = None
    if args.shard:
        mesh = make_worker_mesh(args.workers)
        shards = mesh.shape["data"]
        print(f"[train] sharding {args.workers} workers over {shards} "
              f"devices ({args.workers // shards} rows/shard, "
              f"collective={args.collective})")
    sink = None
    if args.telemetry:
        sink = JsonlSink(args.telemetry)
        sink.emit(run_meta_record(config={
            "arch": args.arch, "workers": args.workers,
            "steps": args.steps, "avg": args.avg,
            "phase_len": args.phase_len, "lr": args.lr,
            "optimizer": args.optimizer,
            "momentum": 0.9 if args.optimizer == "momentum" else 0.0,
            "topology": args.topology,
            "spectral_gap": (topology.spectral_gap
                             if topology is not None else None),
            "comm_dtype": args.comm_dtype, "seed": args.seed}))
        print(f"[train] telemetry -> {args.telemetry}")
    engine = PhaseEngine(loss_fn, opt, sch, outer=outer,
                         scan_unroll=args.scan_unroll or True,
                         flat=not args.tree_engine,
                         fused_opt=not args.no_fused_opt,
                         mesh=mesh, collective=args.collective,
                         topology=topology, compression=compression,
                         faults=faults, telemetry=sink is not None)
    if faults is not None and not faults.is_trivial:
        crashes = sum(ev.kind == "crash" for ev in faults.events)
        rejoins = sum(ev.kind == "rejoin" for ev in faults.events)
        print(f"[train] faults: {crashes} crash / {rejoins} rejoin "
              f"events, straggle_prob={faults.straggle_prob}")
    if topology is not None:
        print(f"[train] topology={topology.kind} "
              f"(spectral gap {topology.spectral_gap:.3f}, "
              f"{topology.comm_degree:.1f} msgs/worker/event)")
    if not compression.is_identity:
        print(f"[train] wire={compression.wire} "
              f"(error_feedback={compression.error_feedback})")

    # per-worker independent data streams (paper §3.2: distinct
    # shuffles); under an elastic plan a row keeps its stream across
    # resizes (row indices are stable identities), so a re-grown worker
    # continues where it left off instead of replaying data
    streams = {}

    def stream(i):
        if i not in streams:
            streams[i] = token_stream(cfg.vocab_size, args.batch,
                                      args.seq, seed=args.seed * 131 + i)
        return streams[i]

    def batches(m, k):
        for _ in range(k):
            toks = np.stack([next(stream(i)) for i in range(m)])
            yield {"tokens": jnp.asarray(toks)}

    resume_state = None
    at = 0
    if args.resume:
        if elastic is not None:
            import json
            with open(args.resume + ".json") as f:
                meta = json.load(f)
            at = int(meta["step"])
            saved_m = (meta.get("extra") or {}).get("num_workers")
            # a save at an exact resize boundary may hold either the
            # pre- or post-resize plane; the recorded row count picks
            # the matching segment's like-state
            from repro.elastic import segment_engine
            seg_eng, m = segment_engine(engine, elastic, at,
                                        at + args.steps)
            if saved_m is not None and int(saved_m) != m:
                seg_eng, m = segment_engine(engine, elastic, at + 1,
                                            at + args.steps)
            like = seg_eng.init(params, m, args.seed)
        else:
            like = engine.init(params, args.workers, args.seed)
        resume_state, at = load_engine_state(args.resume, like)
        print(f"[train] resuming from {args.resume} at step {at}")

    t0 = time.time()
    with profile_trace(args.profile_dir):
        if elastic is not None:
            from repro.elastic import run_elastic
            final, hist, state = run_elastic(
                engine, params, lambda m, t_start, k: batches(m, k),
                elastic, steps=at + args.steps, seed=args.seed,
                record_every=10, state=resume_state, return_state=True,
                sink=sink)
            for t, old_m, new_m in hist["resizes"]:
                kind = "shrink" if new_m < old_m else "grow"
                print(f"[train] {kind} {old_m} -> {new_m} workers "
                      f"before step {t}")
        else:
            final, hist, state = engine.run(
                params, batches(args.workers, args.steps),
                num_workers=args.workers, seed=args.seed,
                record_every=10, prefetch=not args.no_prefetch,
                state=resume_state, return_state=True, sink=sink)
    dt = time.time() - t0
    if args.profile_dir:
        print(f"[train] profiler trace -> {args.profile_dir}")
    losses = hist["loss"]
    print(f"[train] {args.steps} steps in {dt:.1f}s "
          f"({dt / args.steps * 1e3:.0f} ms/step), "
          f"{hist['averages']} averaging ops")
    if losses:
        print(f"[train] loss {losses[0][1]:.4f} -> {losses[-1][1]:.4f}")
    if hist["dispersion"]:
        print(f"[train] final pre-average worker dispersion: "
              f"{hist['dispersion'][-1][1]:.3e}")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, final, step=int(state.step))
        save_engine_state(args.checkpoint + ".state", state,
                          elastic=elastic is not None)
        print(f"[train] saved consensus model to {args.checkpoint} "
              f"(+ resumable EngineState at {args.checkpoint}.state)")
        if sink is not None:
            from repro.checkpoint.io import ENGINE_STATE_VERSION
            sink.emit(make_record(
                "checkpoint_event", step=int(state.step),
                path=args.checkpoint + ".state",
                layout_version=ENGINE_STATE_VERSION))
    if sink is not None:
        sink.close()
    return final, hist


if __name__ == "__main__":
    main()

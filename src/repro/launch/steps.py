"""Step functions + abstract input specs for training, prefill and decode.

Everything here is mesh-agnostic: functions take/return pytrees whose
sharding is declared by the launcher (dryrun/train/serve) via the rules
in repro.sharding.specs. No real allocation happens for the dry-run —
inputs are ShapeDtypeStructs (the shannon/kernels pattern).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, ShapeConfig
from repro.core.averaging import average_all, average_inner
from repro.core.engine import make_plane_step, make_worker_step
from repro.core.flat import FlatOptSpec, FlatSpec
from repro.kernels.ref import avg_disp_ref, plane_average_ref, plane_update_ref
from repro.models import transformer as tfm
from repro.models.layers import cdtype
from repro.optim import Momentum


# --------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStruct stand-ins; weak-type-correct, shardable)
# --------------------------------------------------------------------------

def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                num_workers: int = 0) -> dict:
    """Abstract model inputs for one step.

    train:   {"tokens": (W, B/W, S)} (+ audio/media per family)
    prefill: {"tokens": (B, S)}      (+ audio/media)
    decode:  {"tokens": (B, 1)}      (cache is built separately)
    """
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        assert num_workers > 0 and b % num_workers == 0, (b, num_workers)
        bw = b // num_workers
        lead = (num_workers, bw)
    elif shape.kind == "prefill":
        lead = (b,)
    else:
        lead = (b,)
        s = 1  # decode: one new token
    batch = {"tokens": sds(lead + (s,), jnp.int32)}
    dt = cdtype(cfg)
    if cfg.family == "audio":
        batch["audio"] = sds(lead + (cfg.encoder_seq, cfg.d_model), dt)
    if cfg.family == "vlm":
        batch["media"] = sds(lead + (cfg.num_media_tokens, cfg.d_model), dt)
    return batch


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))


def abstract_worker_state(cfg: ModelConfig, optimizer, num_workers: int):
    """(worker_params, opt_state) ShapeDtypeStruct trees."""
    p = abstract_params(cfg)
    def build():
        wp = jax.tree.map(
            lambda x: jnp.zeros((num_workers,) + x.shape, x.dtype), p)
        os = jax.vmap(optimizer.init)(wp)
        return wp, os
    return jax.eval_shape(build)


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig):
    """Decode cache template; cross-attn K/V sized from the stub memory."""
    b = shape.global_batch
    p = abstract_params(cfg)
    mem = None
    if cfg.family == "audio":
        mem = sds((b, cfg.encoder_seq, cfg.d_model), cdtype(cfg))
    if cfg.family == "vlm":
        mem = sds((b, cfg.num_media_tokens, cfg.d_model), cdtype(cfg))
    return jax.eval_shape(
        lambda pp, m: tfm.init_cache(cfg, b, shape.seq_len, memory=m, params=pp),
        p, mem)


# --------------------------------------------------------------------------
# Steps
# --------------------------------------------------------------------------

def make_optimizer():
    """Paper-faithful default: momentum SGD (paper §3.2 recipe)."""
    return Momentum(lr=0.01, mu=0.9)


def _lm_loss_fn(cfg: ModelConfig, *, impl: str, remat: bool):
    """Engine-signature loss: (params, batch, rng) -> (loss, aux)."""
    def loss_fn(params, batch, rng):
        return tfm.lm_loss(cfg, params, batch, impl=impl, remat=remat)
    return loss_fn


def make_train_step(cfg: ModelConfig, *, impl: str = "xla",
                    remat: bool = True, do_avg: bool = False,
                    inner_groups: int = 0, optimizer=None):
    """Local-SGD step over the worker axis (paper Eq. 3), built on the
    engine's shared worker step. With ``do_avg`` the phase-end model
    average (one all-reduce) is fused in; ``inner_groups`` > 0 averages
    hierarchically instead (beyond-paper)."""
    opt = optimizer or make_optimizer()
    wstep = make_worker_step(_lm_loss_fn(cfg, impl=impl, remat=remat), opt)

    def train_step(worker_params, opt_state, batch, step):
        wp, os, loss, _ = wstep(worker_params, opt_state, batch, step)
        if do_avg:
            wp = average_inner(wp, inner_groups) if inner_groups else average_all(wp)
        return wp, os, jnp.mean(loss)

    return train_step


def make_phase_step(cfg: ModelConfig, *, phase_len: int, impl: str = "xla",
                    remat: bool = True, avg: str = "all",
                    inner_groups: int = 0, optimizer=None,
                    flat: bool = False):
    """The engine's compiled phase as a lowerable function: scan
    ``phase_len`` local steps over a stacked (K, W, ...) batch block, then
    fuse the phase-end average ("all" | "inner" | "none") into the same
    program — one dispatch, one cross-worker all-reduce per phase.

    ``flat`` runs the scan flat-NATIVE, mirroring the production
    engine's default path when lowered for a mesh: params AND optimizer
    state ride as (W, P) planes, grads come from one vjp through the
    unpacked view (``make_plane_step``), each local step is one fused
    plane update, and the phase-end average is the fused single-pass op.
    Optimizers without plane support fall back to per-step pack/unpack
    around the tree-mapped apply.

    batches: leaves (K, W, ...); step0: steps completed before the phase.
    Returns (worker_params, opt_state, per-step mean losses (K,)).
    """
    opt = optimizer or make_optimizer()
    loss_fn = _lm_loss_fn(cfg, impl=impl, remat=remat)
    wstep = make_worker_step(loss_fn, opt)

    def phase_step(worker_params, opt_state, batches, step0):
        spec = FlatSpec.of(worker_params) if flat else None
        opt_spec = (FlatOptSpec.of(spec, opt_state)
                    if flat and getattr(opt, "plane_kind", None) else None)
        native = opt_spec is not None
        grads_fn = make_plane_step(loss_fn, spec) if native else None
        groups = inner_groups if avg == "inner" and inner_groups else 1

        def body(carry, inp):
            wp_c, os = carry
            batch, i = inp
            step = step0 + i + 1
            if native:
                losses, _, gplane = grads_fn(wp_c, batch)
                wp_c, os = plane_update_ref(
                    wp_c, gplane, os, opt.plane_scalars(step),
                    kind=opt.plane_kind, codes=spec.rounding_codes(),
                    **opt.plane_hypers())
                return (wp_c, os), jnp.mean(losses)
            wp = spec.unpack(wp_c) if flat else wp_c
            wp, os, loss, _ = wstep(wp, os, batch, step)
            return ((spec.pack(wp) if flat else wp), os), jnp.mean(loss)

        carry0 = (spec.pack(worker_params) if flat else worker_params,
                  opt_spec.pack(opt_state) if native else opt_state)
        (wp_c, os), losses = jax.lax.scan(
            body, carry0, (batches, jnp.arange(phase_len, dtype=jnp.int32)))
        if native and avg != "none":
            wp_c, _ = plane_average_ref(wp_c, groups=groups,
                                        codes=spec.rounding_codes())
        elif flat and not native and avg != "none":
            wp_c, _ = avg_disp_ref(wp_c, groups=groups)
        if flat:
            wp = spec.unpack(wp_c)
            os = opt_spec.unpack(os) if native else os
        elif avg == "inner" and inner_groups:
            wp = average_inner(wp_c, inner_groups)
        elif avg != "none":  # "all", or "inner" on a mesh with one group
            wp = average_all(wp_c)
        else:
            wp = wp_c
        return wp, os, losses

    return phase_step


def make_prefill_step(cfg: ModelConfig, *, impl: str = "xla"):
    def prefill_step(params, batch):
        logits, _ = tfm.forward(cfg, params, batch, impl=impl, remat=False)
        return logits[:, -1]
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, tokens, cache):
        return tfm.decode_step(cfg, params, tokens, cache)
    return decode_step

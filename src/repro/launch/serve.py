"""Serving CLI: batched greedy decoding with per-layer KV/state caches.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-2b \
      --reduced --batch 4 --prompt-len 8 --gen 24
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models import decode_step, init_params


def generate(cfg, params, prompt, *, max_len: int, greedy: bool = True,
             seed: int = 0, batch_extra=None):
    """prompt: (B, P) int32. True prefill (one full-sequence forward with
    cache capture), then auto-regressive decode — the production path."""
    from repro.models import forward
    b, plen = prompt.shape
    batch = {"tokens": prompt}
    if batch_extra:
        batch.update(batch_extra)
    logits, _, cache = forward(cfg, params, batch, return_cache=True,
                               cache_len=plen + max_len)
    logits = logits[:, -1:]
    step = jax.jit(lambda p, t, c: decode_step(cfg, p, t, c))
    out = []
    key = jax.random.PRNGKey(seed)
    tok = jnp.argmax(logits[:, -1], -1, keepdims=True).astype(jnp.int32)
    for _ in range(max_len):
        out.append(tok[:, 0])
        logits, cache = step(params, tok, cache)
        if greedy:
            tok = jnp.argmax(logits[:, -1], -1, keepdims=True).astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1])[:, None].astype(jnp.int32)
    return jnp.stack(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    if args.reduced:
        cfg = dataclasses.replace(cfg, dtype="float32")
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    extra = {}
    if cfg.family == "audio":
        extra["audio"] = jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model)) * 0.3
    if cfg.family == "vlm":
        extra["media"] = jax.random.normal(
            key, (args.batch, cfg.num_media_tokens, cfg.d_model)) * 0.3

    t0 = time.time()
    toks = generate(cfg, params, prompt, max_len=args.gen,
                    greedy=not args.sample, seed=args.seed,
                    batch_extra=extra)
    dt = time.time() - t0
    print(f"[serve] {cfg.name}: batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen} in {dt:.1f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(f"[serve] sample output ids: {toks[0][:12].tolist()}")
    assert int(toks.max()) < cfg.vocab_size  # padded vocab never sampled
    return toks


if __name__ == "__main__":
    main()

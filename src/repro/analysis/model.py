"""Parsed-repo model shared by all analysis rules.

``RepoModel.load(root)`` parses every ``.py`` file under ``src/`` and
``tests/`` (plus ``benchmarks/`` when present) once, and exposes cheap
indexes the rules share: per-module function tables with qualified names,
import-alias maps, module-level integer/string constants, and a global
method-name index used for conservative call resolution.

Nothing here imports the analyzed code; it is text + ``ast`` only, so the
analyzer runs in environments without jax installed.
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Tuple

SCAN_DIRS = ("src", "tests", "benchmarks")


def dotted_call_name(node: ast.AST) -> Optional[str]:
    """'jax.random.fold_in' for an Attribute/Name chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclasses.dataclass
class FunctionInfo:
    qualname: str  # "Cls.method" / "outer.inner" / "fn"
    node: ast.AST  # FunctionDef | AsyncFunctionDef
    cls: Optional[str]  # enclosing class name, if a method


@dataclasses.dataclass
class ModuleInfo:
    path: Path
    rel: str  # repo-relative posix path
    tree: ast.Module
    lines: List[str]
    functions: Dict[str, FunctionInfo]
    imports: Dict[str, str]  # local alias -> dotted origin
    constants: Dict[str, object]  # module-level NAME = <int|float|str>

    @property
    def is_test(self) -> bool:
        return self.rel.startswith("tests/")

    @property
    def is_src(self) -> bool:
        return self.rel.startswith("src/")


def _collect_functions(tree: ast.Module) -> Dict[str, FunctionInfo]:
    out: Dict[str, FunctionInfo] = {}

    def visit(node: ast.AST, prefix: str, cls: Optional[str]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qn = f"{prefix}{child.name}"
                out[qn] = FunctionInfo(qn, child, cls)
                visit(child, f"{qn}.", cls)
            elif isinstance(child, ast.ClassDef):
                visit(child, f"{prefix}{child.name}.", child.name)

    visit(tree, "", None)
    return out


def _collect_imports(tree: ast.Module) -> Dict[str, str]:
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for a in node.names:
                if a.name != "*":
                    out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def _collect_constants(tree: ast.Module) -> Dict[str, object]:
    out: Dict[str, object] = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
            if isinstance(tgt, ast.Name) and isinstance(node.value, ast.Constant):
                if isinstance(node.value.value, (int, float, str)):
                    out[tgt.id] = node.value.value
    return out


@dataclasses.dataclass
class RepoModel:
    root: Path
    modules: Dict[str, ModuleInfo]  # rel path -> info
    # method/function name -> [(rel, qualname)] across src modules
    name_index: Dict[str, List[Tuple[str, str]]]

    @classmethod
    def load(cls, root) -> "RepoModel":
        root = Path(root).resolve()
        modules: Dict[str, ModuleInfo] = {}
        for d in SCAN_DIRS:
            base = root / d
            if not base.is_dir():
                continue
            for path in sorted(base.rglob("*.py")):
                if "__pycache__" in path.parts:
                    continue
                rel = path.relative_to(root).as_posix()
                try:
                    text = path.read_text(encoding="utf-8")
                    tree = ast.parse(text, filename=str(path))
                except (SyntaxError, UnicodeDecodeError) as e:
                    raise SyntaxError(f"{rel}: cannot parse for analysis: {e}")
                modules[rel] = ModuleInfo(
                    path=path,
                    rel=rel,
                    tree=tree,
                    lines=text.splitlines(),
                    functions=_collect_functions(tree),
                    imports=_collect_imports(tree),
                    constants=_collect_constants(tree),
                )
        name_index: Dict[str, List[Tuple[str, str]]] = {}
        for rel, mod in modules.items():
            if not mod.is_src:
                continue
            # Skip the analyzer itself: it is host-side tooling.
            if "/analysis/" in rel:
                continue
            for qn, fi in mod.functions.items():
                name = qn.rsplit(".", 1)[-1]
                name_index.setdefault(name, []).append((rel, qn))
        return cls(root=root, modules=modules, name_index=name_index)

    def src_modules(self) -> List[ModuleInfo]:
        return [
            m
            for rel, m in sorted(self.modules.items())
            if m.is_src and "/analysis/" not in rel
        ]

    def test_modules(self) -> List[ModuleInfo]:
        return [m for rel, m in sorted(self.modules.items()) if m.is_test]

    def find(self, rel_suffix: str) -> Optional[ModuleInfo]:
        """Module whose rel path ends with ``rel_suffix`` (posix)."""
        for rel, mod in self.modules.items():
            if rel == rel_suffix or rel.endswith("/" + rel_suffix):
                return mod
        return None

    def resolve_constant(self, mod: ModuleInfo, name: str):
        """Value of NAME in ``mod``, following one from-import hop."""
        if name in mod.constants:
            return mod.constants[name]
        origin = mod.imports.get(name)
        if origin and "." in origin:
            src_mod, attr = origin.rsplit(".", 1)
            target = self.find(src_mod.replace(".", "/") + ".py")
            if target and attr in target.constants:
                return target.constants[attr]
        return None

"""rng-salt: every ``jax.random.fold_in`` stream must be uniquely salted.

Contract (docs/INVARIANTS.md §2): bit-reproducible replay hangs off pure
``fold_in`` streams derived from the decision key.  Each subsystem owns a
distinct module-level salt constant (``_GOSSIP_SALT``, ``_ENC_SALT``,
``_STRAGGLE_SALT``, ...); two call sites folding the same ``(key, salt)``
chain would draw correlated randomness (topology events correlated with
quantization rounding, say) and silently bias Eq. 4 dispersion traces.

Checks:
  * registry: every ``fold_in`` site is collected with its resolved salt
    chain (exposed as :func:`registry` for tests/tooling);
  * two *stream heads* (outermost folds) in different locations with an
    identical resolved chain -> finding;
  * two ``*_SALT`` module constants sharing a value -> finding;
  * a raw key used again in a ``jax.random.*`` call after being consumed
    by ``jax.random.split`` without rebinding -> finding.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, List, Tuple

from repro.analysis.base import Finding, register
from repro.analysis.model import ModuleInfo, RepoModel, dotted_call_name

RULE_ID = "rng-salt"
SALT_NAME_RE = re.compile(r"(^|_)SALT$")
_MAX_CHAIN = 8


def _resolve_dotted(mod: ModuleInfo, name: str) -> str:
    parts = name.split(".")
    return ".".join([mod.imports.get(parts[0], parts[0])] + parts[1:])


def _is_jax_random(mod: ModuleInfo, func: ast.AST, leaf: str) -> bool:
    name = dotted_call_name(func)
    if name is None:
        return False
    return _resolve_dotted(mod, name) == f"jax.random.{leaf}"


@dataclasses.dataclass
class FoldSite:
    mod: ModuleInfo
    qualname: str  # enclosing function ('' = module level)
    node: ast.Call
    chain: Tuple  # (("root", name), ("const", v) | "VAR", ...)
    is_head: bool

    @property
    def line(self) -> int:
        return self.node.lineno

    def describe(self) -> str:
        parts = []
        for el in self.chain:
            if isinstance(el, tuple) and el[0] == "root":
                parts.append(f"root={el[1]}")
            elif isinstance(el, tuple) and el[0] == "const":
                parts.append(hex(el[1]) if isinstance(el[1], int) else repr(el[1]))
            else:
                parts.append("<var>")
        return " -> ".join(parts)


def _scopes(mod: ModuleInfo):
    """(qualname, body-statements) for module level and each function."""
    yield "", mod.tree
    for qn, fi in mod.functions.items():
        yield qn, fi.node


def _own_calls(scope_node: ast.AST):
    # Nested defs are their own scopes, but lambda bodies (vmap'd per-row
    # draws) stay in the enclosing scope: they cannot rebind names.
    stack = list(ast.iter_child_nodes(scope_node))
    while stack:
        n = stack.pop()
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if isinstance(n, ast.Call):
            yield n
        stack.extend(ast.iter_child_nodes(n))


def _single_assignments(scope_node: ast.AST) -> Dict[str, ast.AST]:
    """name -> value expr, for names assigned exactly once in this scope."""
    counts: Dict[str, int] = {}
    values: Dict[str, ast.AST] = {}
    stack = list(ast.iter_child_nodes(scope_node))
    while stack:
        n = stack.pop()
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue
        if isinstance(n, ast.Assign) and len(n.targets) == 1:
            t = n.targets[0]
            if isinstance(t, ast.Name):
                counts[t.id] = counts.get(t.id, 0) + 1
                values[t.id] = n.value
        elif isinstance(n, (ast.AugAssign, ast.AnnAssign, ast.For)):
            tgt = getattr(n, "target", None)
            if isinstance(tgt, ast.Name):
                counts[tgt.id] = counts.get(tgt.id, 0) + 99
        stack.extend(ast.iter_child_nodes(n))
    return {k: v for k, v in values.items() if counts.get(k) == 1}


def _collect_sites(model: RepoModel, mod: ModuleInfo) -> List[FoldSite]:
    sites: List[FoldSite] = []
    for qn, scope in _scopes(mod):
        assigns = _single_assignments(scope)
        fold_calls = [
            c for c in _own_calls(scope) if _is_jax_random(mod, c.func, "fold_in")
        ]
        consumed = set()
        for c in fold_calls:
            if c.args and isinstance(c.args[0], ast.Call):
                consumed.add(id(c.args[0]))

        def classify(expr) -> object:
            if isinstance(expr, ast.Constant) and isinstance(expr.value, (int, str)):
                return ("const", expr.value)
            if isinstance(expr, ast.Name):
                val = model.resolve_constant(mod, expr.id)
                if val is not None and isinstance(val, (int, str)):
                    return ("const", val)
            return "VAR"

        def chain_of(call: ast.Call, depth: int) -> Tuple:
            salt = classify(call.args[1]) if len(call.args) > 1 else "VAR"
            base = call.args[0] if call.args else None
            if depth < _MAX_CHAIN and isinstance(base, ast.Call) and _is_jax_random(
                mod, base.func, "fold_in"
            ):
                return chain_of(base, depth + 1) + (salt,)
            if depth < _MAX_CHAIN and isinstance(base, ast.Name):
                sub = assigns.get(base.id)
                if (
                    isinstance(sub, ast.Call)
                    and _is_jax_random(mod, sub.func, "fold_in")
                    and base.id not in {n.id for n in ast.walk(sub) if isinstance(n, ast.Name)}
                ):
                    return chain_of(sub, depth + 1) + (salt,)
            root = ast.unparse(base) if base is not None else "?"
            return (("root", root), salt)

        for c in fold_calls:
            sites.append(
                FoldSite(
                    mod=mod,
                    qualname=qn,
                    node=c,
                    chain=chain_of(c, 0),
                    is_head=id(c) not in consumed,
                )
            )
    return sites


def registry(model: RepoModel) -> List[FoldSite]:
    """Every fold_in site across src/, with resolved salt chains."""
    out: List[FoldSite] = []
    for mod in model.src_modules():
        out.extend(_collect_sites(model, mod))
    return out


def _normalize(site: FoldSite) -> Tuple:
    """Signature used for collision grouping.

    Roots keep their source name (``key`` vs ``dec_key`` are distinct
    streams by convention); salts keep resolved constants; everything
    else collapses to VAR.
    """
    out = []
    for el in site.chain:
        if isinstance(el, tuple):
            out.append(el)
        else:
            out.append("VAR")
    return tuple(out)


def _check_split_reuse(mod: ModuleInfo) -> List[Finding]:
    findings: List[Finding] = []
    for qn, scope in _scopes(mod):
        events: List[Tuple[int, int, str, str]] = []  # (line, prio, kind, name)
        stack = list(ast.iter_child_nodes(scope))
        while stack:
            n = stack.pop()
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                continue
            if isinstance(n, ast.Call) and dotted_call_name(n.func):
                resolved = _resolve_dotted(mod, dotted_call_name(n.func))
                if resolved.startswith("jax.random."):
                    is_split = resolved == "jax.random.split"
                    for i, a in enumerate(n.args):
                        if not isinstance(a, ast.Name):
                            continue
                        if is_split and i == 0:
                            events.append((n.lineno, 1, "split", a.id))
                        else:
                            events.append((n.lineno, 0, "use", a.id))
            if isinstance(n, ast.Assign):
                for t in n.targets:
                    elts = t.elts if isinstance(t, (ast.Tuple, ast.List)) else [t]
                    for e in elts:
                        if isinstance(e, ast.Name):
                            events.append((n.lineno, 2, "assign", e.id))
            stack.extend(ast.iter_child_nodes(n))
        state: Dict[str, str] = {}
        for line, _prio, kind, name in sorted(events):
            if kind == "use" and state.get(name) == "spent":
                findings.append(
                    Finding(
                        RULE_ID,
                        mod.rel,
                        line,
                        f"{qn or '<module>'}: raw key `{name}` used after "
                        f"`jax.random.split({name})` without rebinding",
                    )
                )
                state[name] = "flagged"
            elif kind == "split":
                if state.get(name) != "flagged":
                    state[name] = "spent"
            elif kind == "assign":
                state[name] = "fresh"
    return findings


@register(RULE_ID, "unique fold_in salt streams; no raw-key reuse after split")
def check(model: RepoModel) -> List[Finding]:
    findings: List[Finding] = []

    # 1. salt-constant value uniqueness across src/
    salts: Dict[object, Tuple[str, str]] = {}
    for mod in model.src_modules():
        for name, val in mod.constants.items():
            if SALT_NAME_RE.search(name) and isinstance(val, int):
                prev = salts.get(val)
                if prev is not None and prev[1] != name:
                    findings.append(
                        Finding(
                            RULE_ID,
                            mod.rel,
                            0,
                            f"salt constant {name}={hex(val)} duplicates "
                            f"{prev[1]} in {prev[0]}; streams would collide",
                        )
                    )
                else:
                    salts.setdefault(val, (mod.rel, name))

    # 2. stream-head collisions
    heads = [s for s in registry(model) if s.is_head]
    groups: Dict[Tuple, List[FoldSite]] = {}
    for s in heads:
        groups.setdefault(_normalize(s), []).append(s)
    for sig, sites in groups.items():
        distinct = {(s.mod.rel, s.line) for s in sites}
        if len(distinct) < 2:
            continue
        first = min(sites, key=lambda s: (s.mod.rel, s.line))
        for s in sites:
            if (s.mod.rel, s.line) == (first.mod.rel, first.line):
                continue
            findings.append(
                Finding(
                    RULE_ID,
                    s.mod.rel,
                    s.line,
                    f"{s.qualname or '<module>'}: fold_in stream "
                    f"[{s.describe()}] collides with "
                    f"{first.mod.rel}:{first.qualname or '<module>'} "
                    f"(identical (key, salt) chain)",
                )
            )

    # 3. raw key reuse after split
    for mod in model.src_modules():
        findings.extend(_check_split_reuse(mod))
    return findings

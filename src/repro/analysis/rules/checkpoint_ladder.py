"""checkpoint-ladder: the v0..vN loader ladder must stay complete.

Contract (docs/INVARIANTS.md §4): ``checkpoint/io.py`` owns
``ENGINE_STATE_VERSION`` (= N).  Every historical version ``0..N-1`` must
keep an explicit loader branch in ``load_engine_state`` (``version == k``
or ``version in (..k..)``; the latest version may be the fall-through),
there must be a future-version refusal (``version > ENGINE_STATE_VERSION``
raising), the ``EngineState`` fields with defaults must equal
``_OPTIONAL_FIELDS``, every ``EngineState`` field must be handled
somewhere in io.py, and tests must round-trip each historical version.
"""

from __future__ import annotations

import ast
import re
from typing import List, Optional, Set

from repro.analysis.base import Finding, register
from repro.analysis.model import ModuleInfo, RepoModel

RULE_ID = "checkpoint-ladder"


def _namedtuple_fields(cls: ast.ClassDef):
    """[(name, has_default)] for a NamedTuple class body."""
    out = []
    for node in cls.body:
        if isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
            out.append((node.target.id, node.value is not None))
    return out


def _find_class(mod: ModuleInfo, name: str) -> Optional[ast.ClassDef]:
    for node in mod.tree.body:
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def _version_compare_ints(fn: ast.AST, version_names: Set[str]) -> Set[int]:
    """Ints k appearing as ``<ver> == k`` / ``<ver> in (..k..)`` in fn."""
    out: Set[int] = set()
    for node in ast.walk(fn):
        if not isinstance(node, ast.Compare):
            continue
        sides = [node.left] + list(node.comparators)
        if not any(
            isinstance(s, ast.Name) and s.id in version_names for s in sides
        ):
            continue
        for op, comp in zip(node.ops, node.comparators):
            if isinstance(op, ast.Eq):
                if isinstance(comp, ast.Constant) and isinstance(comp.value, int):
                    out.add(comp.value)
                if isinstance(node.left, ast.Constant) and isinstance(
                    node.left.value, int
                ):
                    out.add(node.left.value)
            elif isinstance(op, ast.In) and isinstance(comp, (ast.Tuple, ast.List, ast.Set)):
                for e in comp.elts:
                    if isinstance(e, ast.Constant) and isinstance(e.value, int):
                        out.add(e.value)
    return out


def _has_future_guard(fn: ast.AST, version_names: Set[str], const_name: str) -> bool:
    for node in ast.walk(fn):
        if not isinstance(node, ast.If):
            continue
        test = node.test
        if not isinstance(test, ast.Compare) or len(test.ops) != 1:
            continue
        lhs, op, rhs = test.left, test.ops[0], test.comparators[0]
        pair_gt = (
            isinstance(op, ast.Gt)
            and isinstance(lhs, ast.Name)
            and lhs.id in version_names
            and isinstance(rhs, ast.Name)
            and rhs.id == const_name
        )
        pair_lt = (
            isinstance(op, ast.Lt)
            and isinstance(rhs, ast.Name)
            and rhs.id in version_names
            and isinstance(lhs, ast.Name)
            and lhs.id == const_name
        )
        if (pair_gt or pair_lt) and any(
            isinstance(n, ast.Raise) for n in ast.walk(node)
        ):
            return True
    return False


def _test_version_literals(model: RepoModel) -> Set[int]:
    """Version ints test modules exercise.

    Evidence accepted, in any test module: a dict literal entry keyed by
    ``"engine_state_version"``; a ``version=``/``engine_state_version=``
    keyword argument; an equality comparison whose other side mentions
    the version key; or a ``test_*v<k>*`` test-function name in a module
    that references the version key (v1 is *defined* by the absence of a
    version field, so only a named test can witness it).
    """
    out: Set[int] = set()
    name_re = re.compile(r"(?:^|_)v(\d+)(?:_|$)")
    for mod in model.test_modules():
        mentions_key = any(
            isinstance(n, ast.Constant) and n.value == "engine_state_version"
            for n in ast.walk(mod.tree)
        )
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Dict):
                for k, v in zip(node.keys, node.values):
                    if (
                        isinstance(k, ast.Constant)
                        and k.value == "engine_state_version"
                        and isinstance(v, ast.Constant)
                        and isinstance(v.value, int)
                    ):
                        out.add(v.value)
            elif isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg in ("version", "engine_state_version") and isinstance(
                        kw.value, ast.Constant
                    ) and isinstance(kw.value.value, int):
                        out.add(kw.value.value)
            elif isinstance(node, ast.Compare):
                sides = [node.left] + list(node.comparators)
                texts = [ast.unparse(s) for s in sides]
                if any("engine_state_version" in t for t in texts):
                    for s in sides:
                        if isinstance(s, ast.Constant) and isinstance(s.value, int):
                            out.add(s.value)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if mentions_key and node.name.startswith("test"):
                    for m in name_re.finditer(node.name):
                        out.add(int(m.group(1)))
    return out


@register(RULE_ID, "complete v0..vN checkpoint loader ladder + field coverage")
def check(model: RepoModel) -> List[Finding]:
    io = model.find("checkpoint/io.py")
    if io is None:
        return []  # nothing to check on trees without the checkpoint layer
    findings: List[Finding] = []

    latest = io.constants.get("ENGINE_STATE_VERSION")
    if not isinstance(latest, int):
        return [
            Finding(
                RULE_ID,
                io.rel,
                0,
                "checkpoint/io.py must define an integer "
                "ENGINE_STATE_VERSION module constant",
            )
        ]

    load = io.functions.get("load_engine_state")
    if load is None:
        return [
            Finding(RULE_ID, io.rel, 0, "load_engine_state is missing from checkpoint/io.py")
        ]
    version_names = {"version", "ver", "v"}
    covered = _version_compare_ints(load.node, version_names)
    missing = sorted(set(range(latest)) - covered)
    for k in missing:
        findings.append(
            Finding(
                RULE_ID,
                io.rel,
                load.node.lineno,
                f"load_engine_state has no loader branch for layout "
                f"version {k} (ladder must cover v0..v{latest - 1} "
                f"explicitly; v{latest} may be the fall-through)",
            )
        )
    if not _has_future_guard(load.node, version_names, "ENGINE_STATE_VERSION"):
        findings.append(
            Finding(
                RULE_ID,
                io.rel,
                load.node.lineno,
                "load_engine_state must refuse payloads with version > "
                "ENGINE_STATE_VERSION (raise on unknown future layouts)",
            )
        )

    # EngineState field coverage.
    eng = model.find("core/engine.py")
    cls = _find_class(eng, "EngineState") if eng else None
    if cls is not None:
        fields = _namedtuple_fields(cls)
        optional = tuple(n for n, has_default in fields if has_default)
        declared = io.tree.body
        opt_const: Optional[tuple] = None
        for node in declared:
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                t = node.targets[0]
                if isinstance(t, ast.Name) and t.id == "_OPTIONAL_FIELDS":
                    if isinstance(node.value, (ast.Tuple, ast.List)):
                        opt_const = tuple(
                            e.value
                            for e in node.value.elts
                            if isinstance(e, ast.Constant)
                        )
        if opt_const is None:
            findings.append(
                Finding(
                    RULE_ID,
                    io.rel,
                    0,
                    "checkpoint/io.py must declare _OPTIONAL_FIELDS naming "
                    "the EngineState fields with defaults",
                )
            )
        elif set(opt_const) != set(optional):
            findings.append(
                Finding(
                    RULE_ID,
                    io.rel,
                    0,
                    f"_OPTIONAL_FIELDS {sorted(opt_const)} does not match "
                    f"EngineState defaulted fields {sorted(optional)}; the "
                    "ladder no longer maps the latest layout",
                )
            )
        io_idents: Set[str] = set()
        for node in ast.walk(io.tree):
            if isinstance(node, ast.Attribute):
                io_idents.add(node.attr)
            elif isinstance(node, ast.Constant) and isinstance(node.value, str):
                io_idents.add(node.value)
            elif isinstance(node, ast.Name):
                io_idents.add(node.id)
        # io.py may (and does) serialize the state generically — pytree
        # flatten plus NamedTuple._replace — in which case per-field
        # coverage is structural, not textual.
        generic = io_idents & {"_replace", "_asdict", "_fields"}
        if not generic:
            for name, _ in fields:
                if name not in io_idents:
                    findings.append(
                        Finding(
                            RULE_ID,
                            io.rel,
                            0,
                            f"EngineState field `{name}` is never referenced "
                            "in checkpoint/io.py; the latest layout does not "
                            "map the full state",
                        )
                    )

    # Round-trip test coverage for historical versions.
    if model.test_modules():
        tested = _test_version_literals(model)
        untested = sorted(set(range(latest)) - tested)
        if untested:
            findings.append(
                Finding(
                    RULE_ID,
                    io.rel,
                    0,
                    f"no test constructs layout version(s) {untested} "
                    "(expected a round-trip test per historical version)",
                )
            )
    return findings

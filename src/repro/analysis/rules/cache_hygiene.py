"""jit-cache-hygiene: per-module executable cleanup is a convention.

Contract (docs/INVARIANTS.md §6): the tier-1 suite compiles hundreds of
jitted executables; without cleanup, CPU-host runs accumulate live
executables until the suite OOMs.  The convention: ``tests/conftest.py``
owns a module-scoped autouse fixture that calls ``jax.clear_caches()``
after every test module, so no test module may leak more than N=0 live
executables past its own scope.  Structurally that means:

  * ``tests/conftest.py`` must define the fixture
    (``@pytest.fixture(autouse=True, scope="module")`` +
    ``jax.clear_caches()``);
  * no other test module calls ``jax.clear_caches()`` ad hoc — cleanup
    has one owner;
  * no test module builds a jitted/pallas executable at import time
    (module-level ``jax.jit(...)`` / ``pl.pallas_call(...)`` calls):
    import-time executables outlive the per-module clear.
"""

from __future__ import annotations

import ast
from typing import List

from repro.analysis.base import Finding, register
from repro.analysis.model import RepoModel, dotted_call_name

RULE_ID = "jit-cache-hygiene"
MAX_LEAKED_EXECUTABLES = 0


def _is_module_scoped_autouse(fn: ast.AST) -> bool:
    for dec in getattr(fn, "decorator_list", []):
        if not isinstance(dec, ast.Call):
            continue
        name = dotted_call_name(dec.func) or ""
        if name.rsplit(".", 1)[-1] != "fixture":
            continue
        autouse = False
        module_scoped = False
        for kw in dec.keywords:
            if kw.arg == "autouse" and isinstance(kw.value, ast.Constant):
                autouse = bool(kw.value.value)
            if kw.arg == "scope" and isinstance(kw.value, ast.Constant):
                module_scoped = kw.value.value == "module"
        if autouse and module_scoped:
            return True
    return False


def _calls_clear_caches(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            name = dotted_call_name(node.func) or ""
            if name.rsplit(".", 1)[-1] == "clear_caches":
                return True
    return False


@register(RULE_ID, "conftest owns per-module jax.clear_caches(); no leaks")
def check(model: RepoModel) -> List[Finding]:
    if not model.test_modules():
        return []
    findings: List[Finding] = []

    conftest = model.find("tests/conftest.py")
    has_fixture = False
    if conftest is not None:
        for qn, fi in conftest.functions.items():
            if _is_module_scoped_autouse(fi.node) and _calls_clear_caches(fi.node):
                has_fixture = True
                break
    if not has_fixture:
        findings.append(
            Finding(
                RULE_ID,
                conftest.rel if conftest else "tests/conftest.py",
                1,
                "tests/conftest.py must define a module-scoped autouse "
                "fixture calling jax.clear_caches() (per-module executable "
                f"cleanup; leak budget N={MAX_LEAKED_EXECUTABLES})",
            )
        )

    for mod in model.test_modules():
        is_conftest = mod.rel.endswith("conftest.py")
        # ad-hoc cache clearing outside conftest
        if not is_conftest:
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.Call):
                    name = dotted_call_name(node.func) or ""
                    if name.rsplit(".", 1)[-1] == "clear_caches":
                        findings.append(
                            Finding(
                                RULE_ID,
                                mod.rel,
                                node.lineno,
                                "ad-hoc jax.clear_caches(): cleanup is owned "
                                "by the conftest module-scoped fixture",
                            )
                        )
        # import-time executables escape the per-module clear
        for stmt in mod.tree.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                continue
            for node in ast.walk(stmt):
                if isinstance(node, ast.Call):
                    name = dotted_call_name(node.func) or ""
                    tail = name.rsplit(".", 1)[-1]
                    if tail in ("jit", "pallas_call"):
                        findings.append(
                            Finding(
                                RULE_ID,
                                mod.rel,
                                node.lineno,
                                f"import-time `{tail}` executable in a test "
                                "module outlives the per-module cache clear; "
                                "build it inside the test",
                            )
                        )
    return findings

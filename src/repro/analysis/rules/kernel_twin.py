"""kernel-twin: every Pallas kernel has a registered jnp reference twin.

Contract (docs/INVARIANTS.md §3): each fused Pallas pass under
``src/repro/kernels/`` must have a pure-jnp twin in ``kernels/ref.py`` —
the twin is the semantics; the kernel is the fast path — plus an
equivalence test in ``tests/``.  The mapping is explicit: ``ref.py``
exports a ``TWINS`` dict literal mapping kernel name to twin name(s).

Checks:
  * a public module-level function calling ``pl.pallas_call`` with no
    ``TWINS`` entry -> finding;
  * a ``TWINS`` entry whose twin is not defined in ``ref.py`` -> finding;
  * a stale ``TWINS`` key naming no discovered kernel -> finding;
  * twin-signature drift: every kernel parameter (minus launch-only
    parameters in ``EXEMPT_PARAMS``) must appear in the union of its
    twins' signatures -> finding;
  * no test module mentioning both the kernel and one of its twins
    -> finding.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis.base import Finding, register
from repro.analysis.model import ModuleInfo, RepoModel, dotted_call_name

RULE_ID = "kernel-twin"

# Launch-geometry / dispatch parameters that have no meaning for a jnp twin.
EXEMPT_PARAMS = {
    "block_p", "block_m", "block_q", "block_k", "block_s", "block_w",
    "interpret", "mode",
}


def _param_names(fn: ast.AST) -> Set[str]:
    a = fn.args
    return {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}


def _calls_pallas(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            name = dotted_call_name(node.func)
            if name and name.rsplit(".", 1)[-1] == "pallas_call":
                return True
    return False


def discover_kernels(model: RepoModel) -> List[Tuple[ModuleInfo, str, ast.AST]]:
    """Public module-level defs under kernels/ that launch a pallas_call."""
    out = []
    for mod in model.src_modules():
        if "/kernels/" not in mod.rel:
            continue
        if mod.rel.endswith(("/ref.py", "/__init__.py")):
            continue
        for qn, fi in sorted(mod.functions.items()):
            if "." in qn or qn.startswith("_"):
                continue
            if _calls_pallas(fi.node):
                out.append((mod, qn, fi.node))
    return out


def _twins_table(ref: ModuleInfo):
    """(assign_line, {kernel: [twin, ...]}) from the TWINS dict literal."""
    for node in ref.tree.body:
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        t = node.targets[0]
        if not (isinstance(t, ast.Name) and t.id == "TWINS"):
            continue
        if not isinstance(node.value, ast.Dict):
            return node.lineno, None
        table: Dict[str, List[str]] = {}
        for k, v in zip(node.value.keys, node.value.values):
            if not (isinstance(k, ast.Constant) and isinstance(k.value, str)):
                continue
            names: List[str] = []
            vals = v.elts if isinstance(v, (ast.Tuple, ast.List)) else [v]
            for e in vals:
                if isinstance(e, ast.Constant) and isinstance(e.value, str):
                    names.append(e.value)
            table[k.value] = names
        return node.lineno, table
    return 0, None


def _test_identifiers(mod: ModuleInfo) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Name):
            out.add(node.id)
        elif isinstance(node, ast.Attribute):
            out.add(node.attr)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for a in node.names:
                out.add((a.asname or a.name).split(".")[-1])
    return out


@register(RULE_ID, "every Pallas kernel has a ref.py twin + equivalence test")
def check(model: RepoModel) -> List[Finding]:
    kernels = discover_kernels(model)
    if not kernels:
        return []
    ref = model.find("kernels/ref.py")
    if ref is None:
        mod = kernels[0][0]
        return [
            Finding(
                RULE_ID,
                mod.rel,
                0,
                "kernels/ref.py is missing: Pallas kernels have no jnp twins",
            )
        ]
    twins_line, table = _twins_table(ref)
    if table is None:
        return [
            Finding(
                RULE_ID,
                ref.rel,
                twins_line,
                "kernels/ref.py must define a TWINS dict literal mapping "
                "each Pallas kernel to its jnp twin(s)",
            )
        ]

    findings: List[Finding] = []
    ref_defs = {qn for qn in ref.functions if "." not in qn}
    test_ids = {m.rel: _test_identifiers(m) for m in model.test_modules()}
    kernel_names = {qn for _, qn, _ in kernels}

    for mod, name, fn in kernels:
        if name not in table:
            findings.append(
                Finding(
                    RULE_ID,
                    mod.rel,
                    fn.lineno,
                    f"Pallas kernel `{name}` has no TWINS entry in "
                    "kernels/ref.py (register its jnp twin)",
                )
            )
            continue
        twin_names = table[name]
        missing = [t for t in twin_names if t not in ref_defs]
        for t in missing:
            findings.append(
                Finding(
                    RULE_ID,
                    ref.rel,
                    twins_line,
                    f"TWINS maps `{name}` to `{t}`, which is not defined in "
                    "kernels/ref.py",
                )
            )
        present = [t for t in twin_names if t in ref_defs]
        if present:
            twin_params: Set[str] = set()
            for t in present:
                twin_params |= _param_names(ref.functions[t].node)
            drift = sorted(_param_names(fn) - twin_params - EXEMPT_PARAMS)
            if drift:
                findings.append(
                    Finding(
                        RULE_ID,
                        mod.rel,
                        fn.lineno,
                        f"twin-signature drift: kernel `{name}` parameters "
                        f"{drift} missing from twin(s) {present}",
                    )
                )
        covered = any(
            name in ids and any(t in ids for t in twin_names)
            for ids in test_ids.values()
        )
        if not covered:
            findings.append(
                Finding(
                    RULE_ID,
                    mod.rel,
                    fn.lineno,
                    f"no equivalence test references kernel `{name}` together "
                    f"with twin(s) {twin_names} under tests/",
                )
            )

    for key in sorted(table):
        if key not in kernel_names:
            findings.append(
                Finding(
                    RULE_ID,
                    ref.rel,
                    twins_line,
                    f"stale TWINS entry `{key}`: no Pallas kernel of that "
                    "name found under kernels/",
                )
            )
    return findings

"""eager-validation: public entry points validate before tracing.

Contract (docs/INVARIANTS.md §5): configuration errors must surface as
eager Python exceptions at construction/parse time, never as shape errors
three layers into a jit trace.  Each registered entry point (constructor
class or function) must contain at least one ``raise ValueError`` /
``raise TypeError`` — directly, or one call deep into a same-module
helper.  ``train.main`` may equivalently use ``argparse``'s
``parser.error(...)``.

The registry below names the entry points of *this* repo; on trees where
a registered file does not exist the entry is skipped, so the rule also
works on the miniature fixture trees used by tests/test_analysis.py.
"""

from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.base import Finding, register
from repro.analysis.model import ModuleInfo, RepoModel

RULE_ID = "eager-validation"

# (module rel-path suffix, class name or function name)
ENTRY_POINTS = (
    ("core/averaging.py", "AveragingSchedule"),
    ("core/compress.py", "Compression"),
    ("topology.py", "Topology"),
    ("faults.py", "FaultPlan"),
    ("elastic.py", "ElasticPlan"),
    ("core/engine.py", "PhaseEngine"),
    ("launch/train.py", "main"),
)

_EAGER_EXC = {"ValueError", "TypeError", "KeyError", "NotImplementedError"}


def _raises_eagerly(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Raise) and node.exc is not None:
            exc = node.exc
            name = None
            if isinstance(exc, ast.Call) and isinstance(exc.func, ast.Name):
                name = exc.func.id
            elif isinstance(exc, ast.Name):
                name = exc.id
            if name in _EAGER_EXC:
                return True
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            if node.func.attr == "error":  # argparse parser.error(...)
                return True
    return False


def _validates(mod: ModuleInfo, fn: ast.AST) -> bool:
    """Direct raise, or a call into a same-module function that raises."""
    if _raises_eagerly(fn):
        return True
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        callee: Optional[str] = None
        if isinstance(node.func, ast.Name):
            callee = node.func.id
        elif isinstance(node.func, ast.Attribute) and isinstance(
            node.func.value, ast.Name
        ) and node.func.value.id in ("self", "cls"):
            callee = node.func.attr
        if callee is None:
            continue
        for qn, fi in mod.functions.items():
            if qn.rsplit(".", 1)[-1] == callee and _raises_eagerly(fi.node):
                return True
    return False


def _class_validates(mod: ModuleInfo, cls_name: str) -> bool:
    methods = [
        fi
        for qn, fi in mod.functions.items()
        if fi.cls == cls_name
    ]
    return any(_validates(mod, fi.node) for fi in methods)


@register(RULE_ID, "entry points raise on bad config before any tracing")
def check(model: RepoModel) -> List[Finding]:
    findings: List[Finding] = []
    for suffix, name in ENTRY_POINTS:
        mod = model.find(suffix)
        if mod is None:
            continue
        cls = None
        for node in mod.tree.body:
            if isinstance(node, ast.ClassDef) and node.name == name:
                cls = node
                break
        if cls is not None:
            if not _class_validates(mod, name):
                findings.append(
                    Finding(
                        RULE_ID,
                        mod.rel,
                        cls.lineno,
                        f"entry point `{name}` performs no eager validation: "
                        "no method raises ValueError/TypeError on bad "
                        "configuration before tracing",
                    )
                )
            continue
        fi = mod.functions.get(name)
        if fi is None:
            findings.append(
                Finding(
                    RULE_ID,
                    mod.rel,
                    0,
                    f"registered entry point `{name}` not found in {suffix}",
                )
            )
            continue
        if not _validates(mod, fi.node):
            findings.append(
                Finding(
                    RULE_ID,
                    mod.rel,
                    fi.node.lineno,
                    f"entry point `{name}` performs no eager validation "
                    "(expected raise ValueError/TypeError or parser.error)",
                )
            )
    return findings

"""trace-purity: no host-side control flow or impurity in traced code.

Contract (docs/INVARIANTS.md §1): every function reachable from a
``PhaseEngine`` scan body, a ``jax.jit`` entry point, or a Pallas kernel
body must be trace-pure.  Python ``if``/``while``/``assert`` on traced
values raise ``TracerBoolConversionError`` at best and silently bake in a
single trace at worst; ``.item()``/``float()``/``np.*`` coercions force a
device sync; ``time``/``random``/``print``/``global`` make replay
non-deterministic.

Implementation: AST-level taint analysis.  Roots are discovered
syntactically (functions passed to ``lax.scan`` & friends, ``jax.jit``
decorations including ``functools.partial(jax.jit, static_argnames=...)``,
``pl.pallas_call`` bodies, and ``*_kernel`` functions under ``kernels/``).
Taint propagates interprocedurally through a conservative intra-repo call
graph (module-level defs, ``self.`` methods, imported names, plus
unique-method-name resolution); static arguments (``static_argnames``,
keyword arguments bound by ``functools.partial`` around a kernel body,
string/``is None`` comparisons, ``.shape``/``.dtype``/``.ndim`` reads,
``len()``) are untainted.  Return-value taint is tracked per callee so a
helper that reduces tracers to static metadata does not taint its caller.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.base import Finding, register
from repro.analysis.model import (
    FunctionInfo,
    ModuleInfo,
    RepoModel,
    dotted_call_name,
)
from repro.analysis.rules.rng_salt import _single_assignments

RULE_ID = "trace-purity"

# HOF name (last dotted component) -> positions of callee arguments.
HOF_CALLEE_ARGS = {
    "scan": (0,),
    "while_loop": (0, 1),
    "fori_loop": (2,),
    "cond": (1, 2),
    "switch": (1,),
    "map": (0,),
    "vmap": (0,),
    "pmap": (0,),
    "jit": (0,),
    "shard_map": (0,),
    "pallas_call": (0,),
    "checkpoint": (0,),
    "remat": (0,),
    "grad": (0,),
    "value_and_grad": (0,),
}

UNTAINT_ATTRS = {
    "shape", "ndim", "dtype", "size", "sharding", "weak_type", "itemsize",
}
UNTAINT_CALLS = {"len", "isinstance", "type", "hasattr", "callable", "repr"}
IMPURE_CALLS = {"print", "input", "open", "breakpoint", "exec", "eval"}
IMPURE_MODULES = {"time", "random", "os", "sys", "io", "logging"}
COERCE_CALLS = {"float", "int", "bool"}
# Method names never resolved via the unique-name fallback (too generic).
NO_FALLBACK = {
    "get", "update", "items", "keys", "values", "append", "extend", "pop",
    "copy", "sum", "mean", "max", "min", "reshape", "astype", "at", "set",
    "add", "dot", "tolist", "item", "split", "join", "format", "apply",
    "init", "build", "read", "write", "close", "encode", "decode",
}

QualKey = Tuple[str, str]  # (module rel path, function qualname)


def _params(fn: ast.AST) -> List[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args]
    if a.vararg:
        names.append(a.vararg.arg)
    names += [p.arg for p in a.kwonlyargs]
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def _pos_params(fn: ast.AST) -> List[str]:
    a = fn.args
    return [p.arg for p in a.posonlyargs + a.args]


def _iter_own(node: ast.AST):
    """Walk ``node`` without descending into nested function/lambda bodies."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        yield n
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(n))


def _const_str_tuple(node: ast.AST) -> List[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [
            e.value
            for e in node.elts
            if isinstance(e, ast.Constant) and isinstance(e.value, str)
        ]
    return []


def _const_int_tuple(node: ast.AST) -> List[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [
            e.value
            for e in node.elts
            if isinstance(e, ast.Constant) and isinstance(e.value, int)
        ]
    return []


def _is_jit_expr(node: ast.AST, mod: ModuleInfo) -> bool:
    name = dotted_call_name(node)
    if name is None:
        return False
    if name in ("jax.jit", "jit"):
        return True
    return mod.imports.get(name, "") == "jax.jit"


def _jit_static_names(dec: ast.AST, fn: ast.AST, mod: ModuleInfo):
    """If ``dec`` marks ``fn`` as jitted, return its static param names."""
    if _is_jit_expr(dec, mod):
        return set()
    if not isinstance(dec, ast.Call):
        return None
    callee = dotted_call_name(dec.func) or ""
    is_partial = callee.rsplit(".", 1)[-1] == "partial"
    is_jit_call = _is_jit_expr(dec.func, mod)
    if not (is_jit_call or (is_partial and dec.args and _is_jit_expr(dec.args[0], mod))):
        return None
    static: Set[str] = set()
    pos = _pos_params(fn)
    for kw in dec.keywords:
        if kw.arg == "static_argnames":
            static.update(_const_str_tuple(kw.value))
        elif kw.arg == "static_argnums":
            for i in _const_int_tuple(kw.value):
                if 0 <= i < len(pos):
                    static.add(pos[i])
        elif kw.arg == "donate_argnums":
            pass
    return static


class _Resolver:
    """Conservative intra-repo call resolution."""

    def __init__(self, model: RepoModel):
        self.model = model
        # dotted module path ("repro.core.flat") -> ModuleInfo
        self.by_dotted: Dict[str, ModuleInfo] = {}
        for mod in model.src_modules():
            rel = mod.rel
            if rel.startswith("src/") and rel.endswith(".py"):
                dotted = rel[len("src/"):-len(".py")].replace("/", ".")
                self.by_dotted[dotted] = mod
                if dotted.endswith(".__init__"):
                    self.by_dotted[dotted[: -len(".__init__")]] = mod

    def resolve_local(self, mod, caller_qn, name) -> Optional[QualKey]:
        parts = caller_qn.split(".") if caller_qn else []
        for i in range(len(parts), -1, -1):
            cand = ".".join(parts[:i] + [name]) if i else name
            if cand in mod.functions:
                return (mod.rel, cand)
        return None

    def resolve_dotted(self, origin: str) -> Optional[QualKey]:
        """'repro.core.flat.FlatSpec.supports' / 'repro.topology.build'."""
        if not origin.startswith("repro."):
            return None
        parts = origin.split(".")
        for cut in range(len(parts) - 1, 0, -1):
            mod = self.by_dotted.get(".".join(parts[:cut]))
            if mod is None:
                continue
            qn = ".".join(parts[cut:])
            if qn in mod.functions:
                return (mod.rel, qn)
            return None
        return None

    def resolve_call(self, mod, caller: FunctionInfo, func) -> Optional[QualKey]:
        if isinstance(func, ast.Name):
            hit = self.resolve_local(mod, caller.qualname, func.id)
            if hit:
                return hit
            origin = mod.imports.get(func.id)
            if origin:
                return self.resolve_dotted(origin)
            return None
        if not isinstance(func, ast.Attribute):
            return None
        attr = func.attr
        base = func.value
        if isinstance(base, ast.Name):
            if base.id in ("self", "cls") and caller.cls:
                qn = f"{caller.cls}.{attr}"
                if qn in mod.functions:
                    return (mod.rel, qn)
            origin = mod.imports.get(base.id)
            if origin:
                hit = self.resolve_dotted(f"{origin}.{attr}")
                if hit:
                    return hit
        # Unique-method fallback: e.g. ``sched.decision_state(...)`` when
        # ``decision_state`` is defined exactly once across src/.
        if attr not in NO_FALLBACK:
            cands = self.model.name_index.get(attr, [])
            if len(cands) == 1:
                rel, qn = cands[0]
                return (rel, qn)
        return None


def _discover_roots(model: RepoModel, resolver: _Resolver):
    """qualkey -> set of static param names (union over discovery sites)."""
    roots: Dict[QualKey, Set[str]] = {}

    def add(key: Optional[QualKey], static: Set[str]):
        if key is None:
            return
        roots.setdefault(key, set()).update(static)

    for mod in model.src_modules():
        # 1. decorated defs
        for qn, fi in mod.functions.items():
            for dec in getattr(fi.node, "decorator_list", []):
                static = _jit_static_names(dec, fi.node, mod)
                if static is not None:
                    add((mod.rel, qn), static)
            if "/kernels/" in mod.rel and qn.rsplit(".", 1)[-1].endswith("_kernel"):
                add((mod.rel, qn), set())
        # 2. higher-order call sites (scan bodies, pallas_call, cond, ...)
        scopes = [("", FunctionInfo("", mod.tree, None))] + [
            (qn, fi) for qn, fi in mod.functions.items()
        ]
        for qn, fi in scopes:
            assigns = _single_assignments(fi.node)
            for node in _iter_own(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                name = dotted_call_name(node.func)
                if name is None:
                    continue
                tail = name.rsplit(".", 1)[-1]
                if tail not in HOF_CALLEE_ARGS:
                    continue
                if tail == "partial":
                    continue
                for pos in HOF_CALLEE_ARGS[tail]:
                    if pos >= len(node.args):
                        continue
                    arg = node.args[pos]
                    cands = arg.elts if isinstance(arg, (ast.Tuple, ast.List)) else [arg]
                    for cand in cands:
                        # `kernel = functools.partial(_f, causal=...)` —
                        # follow the local binding to the partial call.
                        if isinstance(cand, ast.Name) and isinstance(
                            assigns.get(cand.id), ast.Call
                        ):
                            cand = assigns[cand.id]
                        static: Set[str] = set()
                        if isinstance(cand, ast.Call):
                            cn = dotted_call_name(cand.func) or ""
                            if cn.rsplit(".", 1)[-1] == "partial" and cand.args:
                                static = {k.arg for k in cand.keywords if k.arg}
                                cand = cand.args[0]
                        if isinstance(cand, ast.Name):
                            add(resolver.resolve_local(mod, qn, cand.id), static)
    return roots


class _FnAnalysis:
    """One walk of a function body given a tainted-param set."""

    def __init__(self, model, resolver, mod, fi, tainted_params,
                 returns_tainted: Dict[QualKey, bool]):
        self.model = model
        self.resolver = resolver
        self.mod = mod
        self.fi = fi
        self.env: Set[str] = set(tainted_params)
        self.containers: Set[str] = set()
        self.returns_tainted_map = returns_tainted
        self.callee_taints: Dict[QualKey, Set[str]] = {}
        self.callees: Set[QualKey] = set()
        self.returns_tainted = False
        self.findings: List[Tuple[int, str]] = []

    # -- taint evaluation ------------------------------------------------
    def tainted(self, node) -> bool:
        if node is None or isinstance(node, (ast.Constant, ast.Lambda)):
            return False
        if isinstance(node, ast.Name):
            # Host containers of tracers (jax.tree.leaves results): the
            # container itself is static (`not leaves`, `len(leaves)`),
            # its elements are traced (see Subscript below).
            if node.id in self.containers:
                return False
            return node.id in self.env
        if isinstance(node, ast.Attribute):
            if node.attr in UNTAINT_ATTRS:
                return False
            return self.tainted(node.value)
        if isinstance(node, ast.Compare):
            ops_static = any(isinstance(o, (ast.Is, ast.IsNot)) for o in node.ops)
            vals = [node.left] + list(node.comparators)
            if ops_static:
                return False
            if any(isinstance(v, ast.Constant) and isinstance(v.value, str) for v in vals):
                return False
            # `x != ()` / `x == []`: structural pytree checks, host-side.
            if any(
                isinstance(v, (ast.Tuple, ast.List)) and not v.elts for v in vals
            ):
                return False
            return any(self.tainted(v) for v in vals)
        if isinstance(node, ast.Call):
            return self.call_taint(node)
        if isinstance(node, ast.BoolOp):
            return any(self.tainted(v) for v in node.values)
        if isinstance(node, ast.BinOp):
            return self.tainted(node.left) or self.tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.tainted(node.operand)
        if isinstance(node, ast.IfExp):
            return (self.tainted(node.body) or self.tainted(node.orelse)
                    or self.tainted(node.test))
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(self.tainted(e) for e in node.elts)
        if isinstance(node, ast.Dict):
            return any(self.tainted(v) for v in list(node.keys) + list(node.values) if v)
        if isinstance(node, ast.Subscript):
            if isinstance(node.value, ast.Name) and node.value.id in self.containers:
                return True  # element of a host container of tracers
            return self.tainted(node.value)
        if isinstance(node, ast.Starred):
            return self.tainted(node.value)
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp, ast.DictComp)):
            added = []
            for gen in node.generators:
                if self.tainted(gen.iter):
                    for nm in self._target_names(gen.target):
                        if nm not in self.env:
                            self.env.add(nm)
                            added.append(nm)
            if isinstance(node, ast.DictComp):
                out = self.tainted(node.key) or self.tainted(node.value)
            else:
                out = self.tainted(node.elt)
            for nm in added:
                self.env.discard(nm)
            return out
        if isinstance(node, ast.JoinedStr):
            return False
        # Conservative default: any tainted Name inside.
        return any(
            isinstance(n, ast.Name) and n.id in self.env for n in ast.walk(node)
        )

    def call_taint(self, node: ast.Call) -> bool:
        name = dotted_call_name(node.func) or ""
        tail = name.rsplit(".", 1)[-1]
        self.record_call(node)
        if tail in UNTAINT_CALLS:
            return False
        key = self.resolver.resolve_call(self.mod, self.fi, node.func)
        args_tainted = any(self.tainted(a) for a in node.args) or any(
            self.tainted(k.value) for k in node.keywords
        )
        recv_tainted = isinstance(node.func, ast.Attribute) and self.tainted(
            node.func.value
        )
        if key is not None:
            # Optimistic until the callee is analyzed: the fixpoint loop
            # re-enqueues callers whenever a callee's return taint flips
            # to True, so starting at False converges without baking an
            # early over-approximation into the monotone taint sets.
            return self.returns_tainted_map.get(key, False)
        return args_tainted or recv_tainted

    # -- call graph ------------------------------------------------------
    def record_call(self, node: ast.Call) -> None:
        key = self.resolver.resolve_call(self.mod, self.fi, node.func)
        if key is None:
            return
        self.callees.add(key)
        rel, qn = key
        callee = self.model.modules[rel].functions[qn]
        pos = _pos_params(callee.node)
        offset = 0
        if callee.cls and isinstance(node.func, ast.Attribute):
            if pos and pos[0] in ("self", "cls"):
                offset = 1
        sink = self.callee_taints.setdefault(key, set())
        for i, arg in enumerate(node.args):
            if isinstance(arg, ast.Starred):
                continue
            if self.tainted(arg):
                j = i + offset
                if j < len(pos):
                    sink.add(pos[j])
                elif callee.node.args.vararg:
                    sink.add(callee.node.args.vararg.arg)
        for kw in node.keywords:
            if kw.arg and self.tainted(kw.value):
                sink.add(kw.arg)

    # -- impurity / branch checks ---------------------------------------
    def flag(self, node, msg: str) -> None:
        self.findings.append((getattr(node, "lineno", 0), msg))

    def check_call(self, node: ast.Call) -> None:
        name = dotted_call_name(node.func) or ""
        parts = name.split(".")
        tail = parts[-1]
        root_origin = self.mod.imports.get(parts[0], parts[0])
        src = ast.unparse(node)
        if len(src) > 60:
            src = src[:57] + "..."
        if tail in IMPURE_CALLS and len(parts) == 1:
            self.flag(node, f"impure call in traced code: `{src}`")
            return
        if root_origin.split(".")[0] in IMPURE_MODULES and len(parts) > 1:
            self.flag(node, f"host-side `{root_origin.split('.')[0]}` call in traced code: `{src}`")
            return
        if tail in COERCE_CALLS and len(parts) == 1:
            if any(self.tainted(a) for a in node.args):
                self.flag(node, f"`{tail}()` coerces a traced value: `{src}`")
            return
        if tail == "item" and isinstance(node.func, ast.Attribute):
            if self.tainted(node.func.value):
                self.flag(node, f"`.item()` forces a device sync on a traced value: `{src}`")
            return
        if name in ("jax.device_get", "device_get") and any(
            self.tainted(a) for a in node.args
        ):
            self.flag(node, f"`jax.device_get` on a traced value: `{src}`")
            return
        if root_origin.split(".")[0] == "numpy" and len(parts) > 1:
            if any(self.tainted(a) for a in node.args):
                self.flag(node, f"`np.*` coercion of a traced value: `{src}`")

    # -- statement walk --------------------------------------------------
    @staticmethod
    def _target_names(t) -> List[str]:
        if isinstance(t, ast.Name):
            return [t.id]
        if isinstance(t, (ast.Tuple, ast.List)):
            out = []
            for e in t.elts:
                out.extend(_FnAnalysis._target_names(e))
            return out
        if isinstance(t, ast.Starred):
            return _FnAnalysis._target_names(t.value)
        return []

    def assign(self, targets, value_tainted: bool) -> None:
        for t in targets:
            names = self._target_names(t)
            if value_tainted:
                self.env.update(names)
            else:
                for nm in names:
                    self.env.discard(nm)

    def _tree_destructure(self, s: ast.Assign) -> bool:
        """Handle ``leaves = jax.tree.leaves(x)`` (host container of
        tracers) and ``leaves, treedef = jax.tree.flatten(x)`` (the
        treedef is pure host metadata).  Returns True when handled."""
        if not isinstance(s.value, ast.Call) or len(s.targets) != 1:
            return False
        name = dotted_call_name(s.value.func) or ""
        parts = name.split(".")
        resolved = ".".join([self.mod.imports.get(parts[0], parts[0])] + parts[1:])
        if not resolved.startswith("jax."):
            return False
        tail = resolved.rsplit(".", 1)[-1]
        tgt = s.targets[0]
        if tail in ("leaves", "tree_leaves") and isinstance(tgt, ast.Name):
            self.containers.add(tgt.id)
            self.env.discard(tgt.id)
            return True
        if tail in ("flatten", "tree_flatten") and isinstance(
            tgt, (ast.Tuple, ast.List)
        ) and len(tgt.elts) == 2:
            first, second = tgt.elts
            if isinstance(first, ast.Name):
                self.containers.add(first.id)
                self.env.discard(first.id)
            if isinstance(second, ast.Name):
                self.env.discard(second.id)
            return True
        return False

    def eval_calls(self, expr) -> None:
        """Record+check every call in an arbitrary expression."""
        if expr is None:
            return
        for node in _iter_own_expr(expr):
            if isinstance(node, ast.Call):
                self.record_call(node)
                self.check_call(node)

    def walk(self, body) -> None:
        for stmt in body:
            self.stmt(stmt)

    def stmt(self, s) -> None:
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            return
        if isinstance(s, ast.Global):
            self.flag(s, "`global` mutation in traced code")
            return
        if isinstance(s, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = s.value
            self.eval_calls(value)
            if isinstance(s, ast.Assign) and self._tree_destructure(s):
                return
            if isinstance(s, ast.Assign):
                self.assign(s.targets, self.tainted(value))
            elif isinstance(s, ast.AnnAssign):
                if value is not None:
                    self.assign([s.target], self.tainted(value))
            else:  # AugAssign: x += v
                t = self.tainted(value) or self.tainted(s.target)
                self.assign([s.target], t)
            return
        if isinstance(s, (ast.If, ast.While)):
            self.eval_calls(s.test)
            if self.tainted(s.test):
                kw = "if" if isinstance(s, ast.If) else "while"
                src = ast.unparse(s.test)
                if len(src) > 60:
                    src = src[:57] + "..."
                self.flag(s, f"Python `{kw}` on a traced value: `{src}`")
            self.walk(s.body)
            self.walk(s.orelse)
            return
        if isinstance(s, ast.Assert):
            self.eval_calls(s.test)
            if self.tainted(s.test):
                src = ast.unparse(s.test)
                if len(src) > 60:
                    src = src[:57] + "..."
                self.flag(s, f"`assert` on a traced value: `{src}`")
            return
        if isinstance(s, ast.For):
            self.eval_calls(s.iter)
            iter_container = (
                isinstance(s.iter, ast.Name) and s.iter.id in self.containers
            )
            self.assign([s.target], iter_container or self.tainted(s.iter))
            self.walk(s.body)
            self.walk(s.orelse)
            return
        if isinstance(s, ast.With):
            for item in s.items:
                self.eval_calls(item.context_expr)
                if item.optional_vars is not None:
                    self.assign([item.optional_vars], self.tainted(item.context_expr))
            self.walk(s.body)
            return
        if isinstance(s, ast.Try):
            self.walk(s.body)
            for h in s.handlers:
                self.walk(h.body)
            self.walk(s.orelse)
            self.walk(s.finalbody)
            return
        if isinstance(s, ast.Return):
            self.eval_calls(s.value)
            if s.value is not None and self.tainted(s.value):
                self.returns_tainted = True
            return
        if isinstance(s, ast.Expr):
            self.eval_calls(s.value)
            return
        if isinstance(s, ast.Raise):
            return
        # Delete, Pass, Break, Continue, Import, Nonlocal: nothing to do.

    def run(self) -> None:
        # Two passes so loop-carried taint propagates.
        body = self.fi.node.body if not isinstance(self.fi.node, ast.Module) else []
        self.walk(body)
        self.findings.clear()
        self.walk(body)


def _iter_own_expr(expr):
    stack = [expr]
    while stack:
        n = stack.pop()
        yield n
        if isinstance(n, ast.Lambda):
            continue
        stack.extend(ast.iter_child_nodes(n))


@register(RULE_ID, "no host-side control flow/impurity in traced functions")
def check(model: RepoModel) -> List[Finding]:
    resolver = _Resolver(model)
    roots = _discover_roots(model, resolver)

    taints: Dict[QualKey, Set[str]] = {}
    returns_tainted: Dict[QualKey, bool] = {}
    for key, static in roots.items():
        mod = model.modules[key[0]]
        fn = mod.functions[key[1]].node
        tainted = {
            p for p in _params(fn) if p not in static and p not in ("self", "cls")
        }
        taints[key] = tainted

    worklist = list(taints)
    analyses: Dict[QualKey, _FnAnalysis] = {}
    steps = 0
    while worklist and steps < 10000:
        steps += 1
        key = worklist.pop()
        rel, qn = key
        mod = model.modules[rel]
        fi = mod.functions[qn]
        an = _FnAnalysis(model, resolver, mod, fi, taints.get(key, set()),
                         returns_tainted)
        an.run()
        analyses[key] = an
        if returns_tainted.get(key) != an.returns_tainted:
            returns_tainted[key] = an.returns_tainted
            # Re-analyze callers that saw a different return taint.
            for ck, ca in analyses.items():
                if key in ca.callees and ck not in worklist:
                    worklist.append(ck)
        for callee, names in an.callee_taints.items():
            crel = callee[0]
            if "/analysis/" in crel:
                continue
            have = taints.setdefault(callee, set())
            if (names - have) or callee not in analyses:
                have.update(names)
                if callee not in worklist:
                    worklist.append(callee)

    findings: List[Finding] = []
    seen = set()
    for key, an in analyses.items():
        rel, qn = key
        for line, msg in an.findings:
            full = f"{qn}: {msg}"
            sig = (rel, line, full)
            if sig in seen:
                continue
            seen.add(sig)
            findings.append(Finding(RULE_ID, rel, line, full))
    return findings

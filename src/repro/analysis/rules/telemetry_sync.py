"""telemetry-host-sync: device values cross to the host only at flush.

Contract (docs/INVARIANTS.md §7): the telemetry plane accumulates ON
DEVICE and flushes to the host ONCE per phase, riding the phase trace's
existing ``device_get``.  A stray host round-trip inside the telemetry
modules — ``float()`` / ``int()`` coercion, ``.item()``,
``jax.device_get``, or a numpy ``asarray``/``array`` materialization —
would silently re-introduce per-step device syncs, eroding the engine's
one-transfer-per-phase design rule without failing any numerics test.

Structurally: in every module under ``src/repro/telemetry/`` that
imports jax, those calls are only legal inside the flush functions
registered in ``FLUSH_FUNCTIONS`` (``src/repro/telemetry/metrics.py``).
Modules that never import jax (e.g. the report renderer, which only
reads JSON) handle host floats by definition and are out of scope.
"""

from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.base import Finding, register
from repro.analysis.model import ModuleInfo, RepoModel, dotted_call_name

RULE_ID = "telemetry-host-sync"
SCOPE_PREFIX = "src/repro/telemetry/"
METRICS_MODULE = "src/repro/telemetry/metrics.py"
# Host coercions of a (possibly device-resident) scalar.
COERCION_NAMES = ("float", "int")
# Numpy materializations of a device array; jnp.* stays on device.
NUMPY_MATERIALIZERS = ("asarray", "array", "asanyarray")


def _flush_registry(model: RepoModel) -> Optional[Set[str]]:
    """The FLUSH_FUNCTIONS tuple parsed from the metrics module's AST
    (the model's constant index only carries scalars), or None when the
    registry is missing/malformed."""
    mod = model.find(METRICS_MODULE)
    if mod is None:
        return None
    for node in mod.tree.body:
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1):
            continue
        tgt = node.targets[0]
        if not (isinstance(tgt, ast.Name) and tgt.id == "FLUSH_FUNCTIONS"):
            continue
        if not isinstance(node.value, (ast.Tuple, ast.List)):
            return None
        names: Set[str] = set()
        for elt in node.value.elts:
            if not (isinstance(elt, ast.Constant)
                    and isinstance(elt.value, str)):
                return None
            names.add(elt.value)
        return names
    return None


def _imports_jax(mod: ModuleInfo) -> bool:
    return any(origin == "jax" or origin.startswith("jax.")
               for origin in mod.imports.values())


def _violation(mod: ModuleInfo, call: ast.Call) -> Optional[str]:
    """Why this call is a host round-trip, or None."""
    func = call.func
    if isinstance(func, ast.Name) and func.id in COERCION_NAMES:
        return (f"`{func.id}()` coerces to a host scalar (a device sync "
                "on traced/device values)")
    if isinstance(func, ast.Attribute):
        if func.attr == "item":
            return "`.item()` is a host round-trip"
        if func.attr == "device_get":
            return "`device_get` fetches to the host"
        if func.attr in NUMPY_MATERIALIZERS:
            root = func.value
            while isinstance(root, ast.Attribute):
                root = root.value
            if (isinstance(root, ast.Name)
                    and mod.imports.get(root.id) == "numpy"):
                return (f"numpy `.{func.attr}()` materializes a device "
                        "array on the host")
    elif isinstance(func, ast.Name):
        if mod.imports.get(func.id, "").rsplit(".", 1)[-1] == "device_get":
            return "`device_get` fetches to the host"
    return None


@register(RULE_ID, "telemetry host round-trips only in registered flush "
                   "functions")
def check(model: RepoModel) -> List[Finding]:
    in_scope = [m for m in model.src_modules()
                if m.rel.startswith(SCOPE_PREFIX) and _imports_jax(m)]
    if not in_scope and model.find(METRICS_MODULE) is None:
        return []

    findings: List[Finding] = []
    flush = _flush_registry(model)
    if flush is None:
        findings.append(Finding(
            RULE_ID, METRICS_MODULE, 1,
            "FLUSH_FUNCTIONS registry missing or not a literal tuple of "
            "function-name strings — the rule cannot whitelist flush "
            "sites without it"))
        flush = set()
    else:
        metrics = model.find(METRICS_MODULE)
        defined = {qn.rsplit(".", 1)[-1] for qn in metrics.functions}
        for name in sorted(flush - defined):
            findings.append(Finding(
                RULE_ID, METRICS_MODULE, 1,
                f"FLUSH_FUNCTIONS names {name!r}, which is not defined "
                "in the metrics module — stale registry entries hide "
                "real violations"))

    for mod in in_scope:
        exempt_calls = set()
        for qn, fi in mod.functions.items():
            if qn.rsplit(".", 1)[-1] in flush:
                exempt_calls.update(
                    id(n) for n in ast.walk(fi.node)
                    if isinstance(n, ast.Call))
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call) or id(node) in exempt_calls:
                continue
            why = _violation(mod, node)
            if why:
                name = dotted_call_name(node.func) or "<call>"
                findings.append(Finding(
                    RULE_ID, mod.rel, node.lineno,
                    f"{why} — telemetry accumulates on device and "
                    "flushes once per phase; move this into a "
                    "FLUSH_FUNCTIONS-registered flush function "
                    f"(call: {name})"))
    return findings

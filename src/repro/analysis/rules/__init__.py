"""Rule modules self-register on import; importing this package loads all."""

from repro.analysis.rules import (  # noqa: F401
    cache_hygiene,
    checkpoint_ladder,
    eager_validation,
    kernel_twin,
    rng_salt,
    telemetry_sync,
    trace_safety,
)

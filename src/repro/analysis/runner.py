"""Drive all registered rules over a repo tree and produce a report."""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence

from repro.analysis.base import Finding, Rule, all_rules, is_suppressed
from repro.analysis.baseline import load_baseline, split_by_baseline
from repro.analysis.model import RepoModel


@dataclasses.dataclass
class Report:
    findings: List[Finding]  # all unsuppressed findings
    new: List[Finding]  # not covered by the baseline
    accepted: List[Finding]  # covered by the baseline
    stale_baseline: List[str]  # baseline fingerprints with no match
    rules: List[str]

    @property
    def ok(self) -> bool:
        return not self.new and not self.stale_baseline

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "rules": self.rules,
            "counts": {
                "total": len(self.findings),
                "new": len(self.new),
                "accepted": len(self.accepted),
                "stale_baseline": len(self.stale_baseline),
            },
            "new": [f.to_dict() for f in self.new],
            "accepted": [f.to_dict() for f in self.accepted],
            "stale_baseline": self.stale_baseline,
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)

    def to_text(self) -> str:
        out: List[str] = []
        for f in self.new:
            out.append(f.render())
        for f in self.accepted:
            out.append(f"{f.render()}  [baseline]")
        for fp in self.stale_baseline:
            out.append(f"analysis-baseline.json: stale entry {fp} (prune it)")
        status = "OK" if self.ok else "FAIL"
        out.append(
            f"{status}: {len(self.new)} new, {len(self.accepted)} baseline, "
            f"{len(self.stale_baseline)} stale baseline "
            f"({len(self.rules)} rules)"
        )
        return "\n".join(out)


def run_rules(
    model: RepoModel, rules: Optional[Sequence[Rule]] = None
) -> List[Finding]:
    """All findings from ``rules`` (default: every registered rule),
    with suppression comments applied."""
    rules = list(rules) if rules is not None else all_rules()
    findings: List[Finding] = []
    for rule in rules:
        for f in rule.check(model):
            mod = model.modules.get(f.path)
            if mod is not None and is_suppressed(f, mod.lines):
                continue
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.message))
    return findings


def analyze(
    root,
    rules: Optional[Sequence[Rule]] = None,
    use_baseline: bool = True,
) -> Report:
    model = RepoModel.load(root)
    findings = run_rules(model, rules)
    baseline: Dict[str, str] = load_baseline(root) if use_baseline else {}
    new, accepted, stale = split_by_baseline(findings, baseline)
    rule_ids = [r.id for r in (rules if rules is not None else all_rules())]
    return Report(
        findings=findings,
        new=new,
        accepted=accepted,
        stale_baseline=stale,
        rules=rule_ids,
    )

"""Committed-baseline handling for the analysis pass.

The baseline file (``analysis-baseline.json`` at the repo root) records
deliberately-accepted findings by fingerprint, each with a one-line
justification.  The CI gate fails only on findings *not* in the baseline,
and reports baseline entries that no longer match anything (stale entries
must be pruned so the file never accretes dead exceptions).
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Tuple

from repro.analysis.base import Finding

BASELINE_NAME = "analysis-baseline.json"


def load_baseline(root) -> Dict[str, str]:
    """fingerprint -> justification; empty dict when no baseline exists."""
    path = Path(root) / BASELINE_NAME
    if not path.is_file():
        return {}
    data = json.loads(path.read_text(encoding="utf-8"))
    out: Dict[str, str] = {}
    for entry in data.get("findings", []):
        fp = entry["fingerprint"]
        just = entry.get("justification", "")
        if not just:
            raise ValueError(
                f"{BASELINE_NAME}: entry {fp} has no justification; every "
                "baseline exception must say why it is deliberate"
            )
        out[fp] = just
    return out


def save_baseline(root, findings: List[Finding], justifications=None) -> Path:
    """Write findings as the new baseline (used by ``--update-baseline``)."""
    justifications = justifications or {}
    entries = []
    for f in sorted(findings, key=lambda f: (f.path, f.rule, f.line)):
        entries.append(
            {
                "rule": f.rule,
                "path": f.path,
                "message": f.message,
                "fingerprint": f.fingerprint,
                "justification": justifications.get(
                    f.fingerprint, "TODO: justify or fix"
                ),
            }
        )
    path = Path(root) / BASELINE_NAME
    path.write_text(
        json.dumps({"version": 1, "findings": entries}, indent=2) + "\n",
        encoding="utf-8",
    )
    return path


def split_by_baseline(
    findings: List[Finding], baseline: Dict[str, str]
) -> Tuple[List[Finding], List[Finding], List[str]]:
    """(new, accepted, stale_fingerprints)."""
    seen = {f.fingerprint for f in findings}
    new = [f for f in findings if f.fingerprint not in baseline]
    accepted = [f for f in findings if f.fingerprint in baseline]
    stale = sorted(fp for fp in baseline if fp not in seen)
    return new, accepted, stale

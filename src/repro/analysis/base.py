"""Core types for the repo-invariant static-analysis pass.

The analyzer is deliberately stdlib-only (``ast`` + ``json``): it must run
in a CI job that has not installed jax, and it must never import the code
it inspects.  Rules receive a :class:`~repro.analysis.model.RepoModel`
(parsed ASTs plus cheap cross-module indexes) and emit :class:`Finding`
objects.

Suppression
-----------
A finding is suppressed by a comment on the same line or the line above::

    x = float(loss)  # analysis: ignore[trace-purity] -- host-side metric

Multiple rule ids may be listed comma-separated.  ``ignore[*]`` suppresses
every rule on that line.

Fingerprints
------------
Baseline entries match findings by a line-insensitive fingerprint
(rule id + path + normalized message), so unrelated edits that shift line
numbers do not invalidate the baseline.
"""

from __future__ import annotations

import dataclasses
import hashlib
import re
from typing import Callable, Dict, List, Optional

SUPPRESS_RE = re.compile(r"#\s*analysis:\s*ignore\[([^\]]*)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str  # repo-relative, posix separators
    line: int  # 1-based; 0 for whole-file findings
    message: str

    @property
    def fingerprint(self) -> str:
        norm = re.sub(r"\s+", " ", self.message.strip())
        raw = f"{self.rule}::{self.path}::{norm}"
        return hashlib.sha256(raw.encode("utf-8")).hexdigest()[:16]

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "message": self.message,
            "fingerprint": self.fingerprint,
        }

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Rule:
    """A registered analysis rule."""

    id: str
    description: str
    check: Callable  # (RepoModel) -> List[Finding]


_REGISTRY: Dict[str, Rule] = {}


def register(rule_id: str, description: str):
    """Decorator: register ``check(model) -> [Finding]`` under ``rule_id``."""

    def deco(fn):
        if rule_id in _REGISTRY:
            raise ValueError(f"duplicate rule id: {rule_id}")
        _REGISTRY[rule_id] = Rule(rule_id, description, fn)
        return fn

    return deco


def all_rules() -> List[Rule]:
    # Import for side effect: rule modules self-register on first use.
    from repro.analysis import rules as _rules  # noqa: F401

    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


def get_rule(rule_id: str) -> Rule:
    from repro.analysis import rules as _rules  # noqa: F401

    if rule_id not in _REGISTRY:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown rule {rule_id!r} (known: {known})")
    return _REGISTRY[rule_id]


def suppressed_rules(lines: List[str], line: int) -> Optional[set]:
    """Rule ids suppressed at 1-based ``line`` (same line or line above)."""
    out: set = set()
    for ln in (line, line - 1):
        if 1 <= ln <= len(lines):
            m = SUPPRESS_RE.search(lines[ln - 1])
            if m:
                out.update(p.strip() for p in m.group(1).split(",") if p.strip())
    return out


def is_suppressed(finding: Finding, lines: List[str]) -> bool:
    sup = suppressed_rules(lines, finding.line)
    return bool(sup) and (finding.rule in sup or "*" in sup)

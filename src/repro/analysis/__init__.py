"""repro.analysis: repo-invariant static analysis for the parallel-SGD repro.

Run from the repo root::

    PYTHONPATH=src python -m repro.analysis            # text report
    PYTHONPATH=src python -m repro.analysis --format json

or import from tests::

    from repro.analysis import analyze, get_rule, RepoModel

The pass is pure ``ast`` — it never imports the analyzed code and has no
third-party dependencies, so it runs before jax is even installed.  See
``docs/INVARIANTS.md`` for the contracts each rule encodes.
"""

from repro.analysis.base import (  # noqa: F401
    Finding,
    Rule,
    all_rules,
    get_rule,
    register,
)
from repro.analysis.baseline import (  # noqa: F401
    BASELINE_NAME,
    load_baseline,
    save_baseline,
)
from repro.analysis.model import RepoModel  # noqa: F401
from repro.analysis.runner import Report, analyze, run_rules  # noqa: F401

"""CLI for the static-analysis pass: ``python -m repro.analysis``."""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.base import all_rules, get_rule
from repro.analysis.baseline import save_baseline
from repro.analysis.runner import analyze


def find_root(start: Path) -> Path:
    """Walk up from ``start`` to the directory holding ``src/repro``."""
    cur = start.resolve()
    for cand in (cur, *cur.parents):
        if (cand / "src" / "repro").is_dir():
            return cand
    return cur


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Repo-invariant static analysis (trace-safety, RNG-salt, "
        "kernel-twin, checkpoint-ladder, eager-validation, test-hygiene).",
    )
    ap.add_argument("--root", default=None, help="repo root (default: auto)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--output", default=None, help="also write report here")
    ap.add_argument(
        "--rules",
        default=None,
        help="comma-separated rule ids to run (default: all)",
    )
    ap.add_argument(
        "--no-baseline",
        action="store_true",
        help="ignore analysis-baseline.json (report every finding as new)",
    )
    ap.add_argument(
        "--update-baseline",
        action="store_true",
        help="rewrite analysis-baseline.json from current findings; "
        "existing justifications are kept, new entries get a TODO",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="list rule ids and exit"
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.id}: {rule.description}")
        return 0

    root = Path(args.root) if args.root else find_root(Path.cwd())
    rules = None
    if args.rules:
        rules = [get_rule(r.strip()) for r in args.rules.split(",")]

    report = analyze(root, rules=rules, use_baseline=not args.no_baseline)

    if args.update_baseline:
        from repro.analysis.baseline import load_baseline

        old = load_baseline(root)
        path = save_baseline(root, report.findings, justifications=old)
        print(f"wrote {path} ({len(report.findings)} findings)")
        return 0

    text = report.to_json() if args.format == "json" else report.to_text()
    print(text)
    if args.output:
        out = Path(args.output)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(report.to_dict(), indent=2) + "\n")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())

"""Worker-sharded batching.

The paper's setups use (a) sampling with replacement from a common pool
(theory, Eq. 2) and (b) a distinct permutation of the dataset per worker
(§3.2 CNN). ``WorkerSharder`` implements both; ``worker_batches`` adapts
any single-stream iterator into per-worker batches with a leading worker
axis — the layout the LocalSGD runtime shards over the mesh worker axes.
"""
from __future__ import annotations

import numpy as np


class WorkerSharder:
    """Deterministic per-worker sampler over an in-memory dataset."""

    def __init__(self, num_samples: int, num_workers: int, *, seed: int = 0,
                 mode: str = "permute"):
        assert mode in ("permute", "replacement")
        self.n = num_samples
        self.m = num_workers
        self.mode = mode
        self.rngs = [np.random.default_rng(seed * 10_007 + i)
                     for i in range(num_workers)]
        self._perms = [r.permutation(num_samples) for r in self.rngs]
        self._cursor = [0] * num_workers

    def next_indices(self, batch: int) -> np.ndarray:
        """(num_workers, batch) int — each worker's next sample indices."""
        out = np.empty((self.m, batch), np.int64)
        for i in range(self.m):
            if self.mode == "replacement":
                out[i] = self.rngs[i].integers(0, self.n, batch)
            else:
                idx = []
                while len(idx) < batch:
                    take = min(batch - len(idx), self.n - self._cursor[i])
                    idx.extend(self._perms[i][self._cursor[i]:self._cursor[i] + take])
                    self._cursor[i] += take
                    if self._cursor[i] >= self.n:  # re-shuffle per epoch
                        self._perms[i] = self.rngs[i].permutation(self.n)
                        self._cursor[i] = 0
                out[i] = np.asarray(idx)
        return out


def worker_batches(stream, num_workers: int):
    """Group a single-batch iterator into (num_workers, ...) stacked
    batches: one independent batch per worker per step."""
    while True:
        yield np.stack([next(stream) for _ in range(num_workers)], axis=0)

"""Worker-sharded batching and the on-device data plane.

The paper's setups use (a) sampling with replacement from a common pool
(theory, Eq. 2) and (b) a distinct permutation of the dataset per worker
(§3.2 CNN). ``WorkerSharder`` implements both; ``worker_batches`` adapts
any single-stream iterator into per-worker batches with a leading worker
axis — the layout the LocalSGD runtime shards over the mesh worker axes.

Two pieces keep the phase engine's hot path free of host staging:

- :class:`DeviceDataset` pins an in-memory dataset on device ONCE and
  feeds the engine `(K, M, B)` *index* blocks; batches are gathered
  on-device inside the phase scan (``jnp.take``), so a phase dispatch
  transfers K·M·B int32 indices instead of K stacked batches.
- :class:`Prefetcher` double-buffers streaming sources: a daemon thread
  stacks and stages block t+1 while block t computes.
"""
from __future__ import annotations

import queue
import threading

import numpy as np


class WorkerSharder:
    """Deterministic per-worker sampler over an in-memory dataset.

    Modes: ``permute`` (distinct per-worker epoch permutations, §3.2),
    ``replacement`` (common-pool i.i.d. draws, Eq. 2), and
    ``dirichlet`` — heterogeneous (non-IID) shards via per-class
    Dirichlet(α) label skew: each class's probability mass is split
    across workers by one Dirichlet draw, giving every worker its own
    biased pool to sample (with replacement) from. Small α → near
    single-class workers; large α → approaches ``replacement``.
    ``dirichlet`` requires ``labels`` (the (N,) integer class array)."""

    def __init__(self, num_samples: int, num_workers: int, *, seed: int = 0,
                 mode: str = "permute", labels=None, alpha: float = 0.5):
        assert mode in ("permute", "replacement", "dirichlet")
        self.n = num_samples
        self.m = num_workers
        self.mode = mode
        self.alpha = float(alpha)
        if mode == "permute":
            self.rngs = [np.random.default_rng(seed * 10_007 + i)
                         for i in range(num_workers)]
            self._perms = [r.permutation(num_samples) for r in self.rngs]
            self._cursor = [0] * num_workers
        elif mode == "dirichlet":
            if labels is None:
                raise ValueError(
                    "mode='dirichlet' needs the (N,) labels array to "
                    "build label-skewed worker pools")
            labels = np.asarray(labels).reshape(-1)
            if labels.shape[0] != num_samples:
                raise ValueError(
                    f"labels cover {labels.shape[0]} samples, dataset "
                    f"has {num_samples}")
            if self.alpha <= 0:
                raise ValueError(f"dirichlet alpha must be > 0, "
                                 f"got {alpha}")
            self._rng = np.random.default_rng(seed * 10_007)
            self._pools = self._dirichlet_pools(labels)
        else:
            # replacement mode draws all workers (and all steps of a
            # block) from ONE stacked stream in a single batched
            # ``integers`` call — no per-worker generators/permutations
            self._rng = np.random.default_rng(seed * 10_007)

    def _dirichlet_pools(self, labels) -> list[np.ndarray]:
        """Per-worker index pools: each class's samples are dealt to
        workers in proportion to one Dirichlet(α) draw. Every pool is
        guaranteed non-empty (a worker dealt nothing steals one sample
        from the largest pool), so degenerate α never strands a
        worker."""
        pools = [[] for _ in range(self.m)]
        for cls in np.unique(labels):
            idx = np.flatnonzero(labels == cls)
            idx = self._rng.permutation(idx)
            p = self._rng.dirichlet(np.full(self.m, self.alpha))
            # cumulative proportional split (exact partition of idx)
            cuts = np.floor(np.cumsum(p) * len(idx)).astype(int)
            start = 0
            for i, end in enumerate(cuts):
                pools[i].extend(idx[start:end])
                start = end
            pools[-1].extend(idx[start:])
        pools = [np.asarray(sorted(pl), np.int64) for pl in pools]
        for i in range(self.m):
            if len(pools[i]) == 0:
                donor = int(np.argmax([len(pl) for pl in pools]))
                pools[i] = pools[donor][-1:]
                pools[donor] = pools[donor][:-1]
        return pools

    def class_fractions(self, labels) -> np.ndarray:
        """(M, C) per-worker class composition of the dirichlet pools —
        the heterogeneity diagnostic benchmarks record."""
        assert self.mode == "dirichlet"
        labels = np.asarray(labels).reshape(-1)
        classes = np.unique(labels)
        out = np.zeros((self.m, len(classes)))
        for i, pool in enumerate(self._pools):
            for j, cls in enumerate(classes):
                out[i, j] = np.mean(labels[pool] == cls)
        return out

    def next_indices(self, batch: int) -> np.ndarray:
        """(num_workers, batch) int — each worker's next sample indices."""
        if self.mode == "replacement":
            return self._rng.integers(0, self.n, (self.m, batch))
        if self.mode == "dirichlet":
            # one stream, worker-major — same draw order as a stacked
            # next_index_block, so blocks equal successive calls
            return np.stack([
                pool[self._rng.integers(0, len(pool), batch)]
                for pool in self._pools])
        out = np.empty((self.m, batch), np.int64)
        for i in range(self.m):
            idx = []
            while len(idx) < batch:
                take = min(batch - len(idx), self.n - self._cursor[i])
                idx.extend(self._perms[i][self._cursor[i]:self._cursor[i] + take])
                self._cursor[i] += take
                if self._cursor[i] >= self.n:  # re-shuffle per epoch
                    self._perms[i] = self.rngs[i].permutation(self.n)
                    self._cursor[i] = 0
            out[i] = np.asarray(idx)
        return out

    def next_index_block(self, steps: int, batch: int) -> np.ndarray:
        """(steps, num_workers, batch) int — a whole phase block of
        indices. In replacement mode this is ONE batched draw (numpy
        fills C-order from the bit stream, so it equals ``steps``
        successive :meth:`next_indices` calls); permute and dirichlet
        modes walk their per-worker state step by step."""
        if self.mode == "replacement":
            return self._rng.integers(0, self.n, (steps, self.m, batch))
        return np.stack([self.next_indices(batch) for _ in range(steps)])


def worker_batches(stream, num_workers: int):
    """Group a single-batch iterator into (num_workers, ...) stacked
    batches: one independent batch per worker per step. Ends (dropping
    any partial worker group) when the stream ends."""
    while True:
        group = []
        for _ in range(num_workers):
            try:
                group.append(next(stream))
            except StopIteration:
                # under PEP 479 letting StopIteration escape a generator
                # raises RuntimeError — end the generator instead
                return
        yield np.stack(group, axis=0)


class DeviceDataset:
    """In-memory dataset resident on device; the engine gathers batches
    on-device from index blocks — zero per-phase host staging.

    arrays: pytree of (N, ...) arrays (``device_put`` once, here).
    Either pass ``batch_size`` (+ ``mode``/``seed``) to sample via
    :class:`WorkerSharder`, or ``indices`` — a precomputed (S, M, B) or
    (S, M) int array — for paired-draw protocols (bench_fig2).
    """

    def __init__(self, arrays, num_workers: int, *, batch_size: int = 0,
                 seed: int = 0, mode: str = "replacement", indices=None,
                 labels=None, alpha: float = 0.5):
        import jax
        import jax.numpy as jnp
        self.arrays = jax.tree.map(
            lambda a: jax.device_put(jnp.asarray(a)), arrays)
        sizes = {x.shape[0] for x in jax.tree.leaves(self.arrays)}
        assert len(sizes) == 1, f"inconsistent leading dims {sizes}"
        self.num_samples = sizes.pop()
        self.num_workers = num_workers
        self.batch_size = batch_size
        self._indices = None
        self._cursor = 0
        self.sharder = None
        if indices is None:
            assert batch_size > 0, "batch_size required without indices"
            self.sharder = WorkerSharder(self.num_samples, num_workers,
                                         seed=seed, mode=mode,
                                         labels=labels, alpha=alpha)
        else:
            self._indices = np.asarray(indices)
            assert self._indices.shape[1] == num_workers, \
                (self._indices.shape, num_workers)

    @property
    def num_steps(self) -> int | None:
        """Steps still available from the precomputed index list (the
        cursor advances across runs); None = unbounded sampler."""
        if self._indices is None:
            return None
        return len(self._indices) - self._cursor

    def index_block(self, steps: int) -> np.ndarray:
        """(steps, M, B) (or (steps, M) for single-sample batches) int32
        sample indices for the next phase block."""
        if self._indices is not None:
            blk = self._indices[self._cursor:self._cursor + steps]
            assert len(blk) == steps, "index list exhausted"
            self._cursor += steps
            return np.asarray(blk, np.int32)
        return self.sharder.next_index_block(
            steps, self.batch_size).astype(np.int32)


class Prefetcher:
    """Double-buffered background staging: a daemon thread materialises
    the wrapped iterator's items (e.g. host-stacked + device_put phase
    blocks) up to ``depth`` ahead of the consumer. Exceptions from the
    producer re-raise at the consumer's ``next()``. Call :meth:`close`
    (or exhaust the iterator) if the consumer stops early, so the
    producer thread exits instead of blocking on a full queue with
    staged device blocks pinned."""

    _END = object()

    def __init__(self, it, *, depth: int = 2):
        self._q = queue.Queue(maxsize=max(depth, 1))
        self._err = None
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._work, args=(iter(it),), daemon=True)
        self._thread.start()

    def _put(self, item) -> bool:
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _work(self, it):
        try:
            for item in it:
                if not self._put(item):
                    return
        except BaseException as e:  # surfaced in __next__
            self._err = e
        finally:
            self._put(self._END)

    def close(self):
        """Stop the producer and drop any staged items."""
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)

    def __iter__(self):
        return self

    def __next__(self):
        if self._stop.is_set():
            raise StopIteration
        item = self._q.get()
        if item is self._END:
            # the stream is over either way: stop BEFORE raising, so a
            # consumer that catches the producer's error and calls
            # next() again gets StopIteration instead of blocking
            # forever on the now-empty queue
            self._stop.set()
            if self._err is not None:
                err, self._err = self._err, None
                raise err
            raise StopIteration
        return item

"""Deterministic synthetic datasets (offline container — see DESIGN.md §6).

- ``token_stream``: markov-ish token sequences with learnable structure
  (next token depends on the current token through a fixed random
  permutation, plus noise) so LM training loss measurably decreases.
- ``mnist_like``: class-conditional Gaussian blobs rendered as 28×28
  images — preserves the statistics that matter for the paper's §3.2
  experiment (10 classes, separable but noisy).
- ``convex_dataset``: LS/LR data with *controllable* gradient-variance
  envelope: sparse features make β²‖w₀-w*‖² dominate (large ρ, like
  E2006-tfidf), dense features with label noise make σ² dominate
  (small ρ, like YearPrediction).
"""
from __future__ import annotations

import numpy as np


def token_stream(vocab: int, batch: int, seq: int, *, seed: int = 0,
                 noise: float = 0.1):
    """Infinite iterator of (batch, seq) int32 token arrays."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(vocab)
    while True:
        t = rng.integers(0, vocab, size=(batch, 1))
        cols = [t[:, 0]]
        for _ in range(seq - 1):
            nxt = perm[cols[-1]]
            flip = rng.random(batch) < noise
            nxt = np.where(flip, rng.integers(0, vocab, batch), nxt)
            cols.append(nxt)
        yield np.stack(cols, axis=1).astype(np.int32)


def mnist_like(num: int, *, seed: int = 0, image_size: int = 28,
               num_classes: int = 10, noise: float = 0.35,
               proto_seed: int = 777):
    """(images (N,28,28,1) float32, labels (N,) int32).

    Class prototypes come from ``proto_seed`` (shared between train and
    test splits); ``seed`` only controls sample noise/labels."""
    rng = np.random.default_rng(seed)
    rng_p = np.random.default_rng(proto_seed)
    protos = rng_p.normal(0, 1, size=(num_classes, image_size, image_size, 1))
    # low-pass the prototypes so they look like strokes, not static
    k = np.ones((3, 3)) / 9.0
    for c in range(num_classes):
        img = protos[c, :, :, 0]
        for _ in range(2):
            img = _conv2_same(img, k)
        protos[c, :, :, 0] = img
    labels = rng.integers(0, num_classes, size=num)
    images = protos[labels] + noise * rng.normal(0, 1, size=(num, image_size, image_size, 1))
    return images.astype(np.float32), labels.astype(np.int32)


def _conv2_same(img, k):
    from numpy.lib.stride_tricks import sliding_window_view
    p = k.shape[0] // 2
    pad = np.pad(img, p)
    win = sliding_window_view(pad, k.shape)
    return np.einsum("ijkl,kl->ij", win, k)


def convex_dataset(kind: str, num: int, dim: int, *, sparsity: float = 1.0,
                   noise: float = 0.1, seed: int = 0, w_scale: float = 1.0):
    """Returns (X (N,D), y (N,), w_true (D,)).

    sparsity < 1 zeroes out a random (1-sparsity) fraction of features per
    sample (tf-idf-like): per-sample gradients then live in small random
    subspaces, so Δ(w) grows fast with ‖w-w*‖ (large β², large ρ)."""
    rng = np.random.default_rng(seed)
    X = rng.normal(0, 1, size=(num, dim))
    if sparsity < 1.0:
        mask = rng.random((num, dim)) < sparsity
        # keep at least one feature per row
        empty = ~mask.any(axis=1)
        mask[empty, rng.integers(0, dim, empty.sum())] = True
        X = X * mask / np.sqrt(max(sparsity, 1e-12))
    w_true = w_scale * rng.normal(0, 1, size=dim) / np.sqrt(dim)
    z = X @ w_true
    if kind == "ls":
        y = z + noise * rng.normal(0, 1, size=num)
    elif kind == "lr":
        p = 1.0 / (1.0 + np.exp(-z / max(np.std(z), 1e-9)))
        y = np.where(rng.random(num) < p, 1.0, -1.0)
        if noise > 0:  # label flips
            flip = rng.random(num) < noise
            y = np.where(flip, -y, y)
    else:
        raise ValueError(kind)
    return X.astype(np.float32), y.astype(np.float32), w_true.astype(np.float32)

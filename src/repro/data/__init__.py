from repro.data.synthetic import (  # noqa: F401
    convex_dataset,
    mnist_like,
    token_stream,
)
from repro.data.pipeline import (  # noqa: F401
    DeviceDataset,
    Prefetcher,
    WorkerSharder,
    worker_batches,
)

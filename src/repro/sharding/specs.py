"""PartitionSpec rules.

Train (local SGD): every state leaf carries a leading worker axis sharded
over the worker mesh axes; *within* a worker group the largest
model-divisible dim of each tensor is sharded over "model" (FSDP-flavored
— one dim sharded, XLA SPMD inserts the all-gathers). Batches shard their
first model-divisible dim over "model" too so activations stay small.

Serve: params have no worker axis; same within-group rule; the batch
shards over the data axes and KV caches shard sequence (long-context) or
head dims over "model".

These are the *baseline* rules — EXPERIMENTS.md §Perf iterates on them
(e.g. expert-dim sharding for MoE, sequence- vs batch-sharding).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def leaf_spec(shape, msize: int, *, model_axis="model", prefix=(),
              prefer_axis: int | None = None) -> P:
    """Shard the largest dim divisible by ``msize`` over the model axis
    (``prefer_axis`` overrides). ``prefix`` are specs for leading dims."""
    n = len(shape) - len(prefix)
    dims = shape[len(prefix):]
    best = None
    if prefer_axis is not None and dims[prefer_axis] % msize == 0:
        best = prefer_axis
    else:
        for i, s in enumerate(dims):
            if s % msize == 0 and s >= msize:
                if best is None or s > dims[best]:
                    best = i
    spec = [None] * n
    if best is not None:
        spec[best] = model_axis
    return P(*prefix, *spec)


def first_divisible_spec(shape, msize: int, *, model_axis="model",
                         prefix=()) -> P:
    """Shard the leading (batch) dim over the model axis when divisible;
    otherwise replicate within the worker group (FSDP-style). Sharding a
    *sequence* dim here is deliberately avoided: seq-sharded activations
    force SPMD to partition scans/attention along time, which explodes
    both collectives and compile time (measured: 20x+ on the multi-pod
    mesh; see EXPERIMENTS.md §Perf notes)."""
    n = len(shape) - len(prefix)
    dims = shape[len(prefix):]
    spec = [None] * n
    if dims and dims[0] % msize == 0 and dims[0] >= msize:
        spec[0] = model_axis
    return P(*prefix, *spec)


def tree_specs(template, msize: int, *, prefix=(), rule=leaf_spec,
               moe_expert_parallel: bool = False):
    """Map a pytree of ShapeDtypeStruct/arrays to PartitionSpecs."""
    def spec_of(path, leaf):
        prefer = None
        if moe_expert_parallel:
            names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
            if any(n in ("w_in", "w_out", "w_gate") for n in names) and \
                    len(leaf.shape) - len(prefix) == 3:
                prefer = 0  # expert dim
        if rule is leaf_spec:
            return leaf_spec(leaf.shape, msize, prefix=prefix, prefer_axis=prefer)
        return rule(leaf.shape, msize, prefix=prefix)
    return jax.tree_util.tree_map_with_path(spec_of, template)


def param_specs(params_template, msize: int, *, worker_axes=None,
                moe_expert_parallel: bool = False):
    prefix = (worker_axes,) if worker_axes is not None else ()
    return tree_specs(params_template, msize, prefix=prefix,
                      moe_expert_parallel=moe_expert_parallel)


def batch_specs(batch_template, msize: int, *, worker_axes=None):
    """Inputs: leading worker axis (train) then first-divisible rule."""
    prefix = (worker_axes,) if worker_axes is not None else ()
    return tree_specs(batch_template, msize, prefix=prefix,
                      rule=first_divisible_spec)


def cache_specs(cache_template, msize: int, *, data_axes,
                long_layout: str = "seq"):
    """Decode caches: batch over data axes when divisible; otherwise
    (batch=1 long-context) the k/v layout is governed by ``long_layout``:

      "seq"   — shard the sequence dim over data+model jointly (baseline;
                maximum capacity, but the dynamic cache update at a traced
                position forces an SPMD reshard — see EXPERIMENTS.md §Perf)
      "heads" — keep sequence unsharded, shard the largest head/hd dim
                over model (update is shard-local; no reshard collectives)
    """
    def spec_of(path, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        dsize = _axes_size(data_axes)
        if shape[0] % dsize == 0 and shape[0] >= dsize:
            # batch shards over data; biggest remaining dim over model
            if long_layout == "heads" and ("k" in names or "v" in names) \
                    and len(shape) == 4:
                sub = leaf_spec(shape[2:], msize, prefix=())
                return P(data_axes, None, *sub)
            sub = leaf_spec(shape[1:], msize, prefix=())
            return P(data_axes, *sub)
        # batch=1 long-context k/v
        if "k" in names or "v" in names:
            if (long_layout == "seq" and len(shape) >= 2
                    and shape[1] % (dsize * msize) == 0):
                return P(None, (_flat(data_axes) + ("model",)),
                         *([None] * (len(shape) - 2)))
            if long_layout == "heads" and len(shape) == 4:
                sub = leaf_spec(shape[2:], msize, prefix=())
                return P(None, None, *sub)
        return leaf_spec(shape, msize, prefix=())
    return jax.tree_util.tree_map_with_path(spec_of, cache_template)


def _flat(axes):
    if isinstance(axes, str):
        return (axes,)
    out = []
    for a in axes:
        out.extend(_flat(a))
    return tuple(out)


# --------------------------------------------------------------------------
# Flat (M, P) plane sharding (the phase engine's worker-axis layout)
# --------------------------------------------------------------------------

def mesh_worker_axes(mesh) -> tuple:
    """The mesh axes that form the local-SGD worker axis: ("pod","data")
    when both exist, else ("data",), else the mesh's first axis."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes or tuple(mesh.axis_names[:1])


def plane_sharding(mesh, *, axes=None):
    """NamedSharding for the flat (M, P) plane — and for any engine leaf
    with a leading worker axis: M splits over the worker mesh axes, all
    trailing dims (the P columns) stay replicated within a worker
    shard."""
    axes = tuple(axes) if axes else mesh_worker_axes(mesh)
    return jax.sharding.NamedSharding(mesh, P(axes))


def engine_state_sharding(mesh, state, *, axes=None):
    """Shardings for a full ``repro.core.EngineState``: worker-axis
    leaves (params + optimizer state + the error-feedback residual
    plane + the per-worker fault rows) via :func:`plane_sharding`,
    everything else (outer state, PRNG keys, step, schedule state)
    replicated."""
    ws = plane_sharding(mesh, axes=axes)
    repl = jax.sharding.NamedSharding(mesh, P())
    return type(state)(
        jax.tree.map(lambda _: ws, state.worker_params),
        jax.tree.map(lambda _: ws, state.opt_state),
        jax.tree.map(lambda _: repl, state.outer_state),
        repl, repl, repl,
        jax.tree.map(lambda _: repl, state.sched),
        jax.tree.map(lambda _: ws, state.resid),
        jax.tree.map(lambda _: ws, state.fault))


def unshard_engine_state(state):
    """Pull the worker-axis leaves of an ``EngineState`` back to host
    as plain single-device arrays (``repro.elastic`` repacks rows
    between mesh layouts; the PRNG keys and scalar carries are left
    untouched — ``device_get`` on typed key arrays would strip the key
    dtype)."""
    pull = lambda t: jax.tree.map(
        lambda x: jnp.asarray(jax.device_get(x)), t)
    return state._replace(
        worker_params=pull(state.worker_params),
        opt_state=pull(state.opt_state),
        resid=pull(state.resid),
        fault=pull(state.fault))


_SIZES = {}


def set_axis_sizes(sizes: dict):
    """Record mesh axis sizes for divisibility checks (set by launch)."""
    _SIZES.clear()
    _SIZES.update(sizes)


def _axes_size(axes) -> int:
    n = 1
    for a in _flat(axes):
        n *= _SIZES.get(a, 1)
    return n

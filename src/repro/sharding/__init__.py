from repro.sharding.specs import (  # noqa: F401
    batch_specs,
    cache_specs,
    leaf_spec,
    param_specs,
    tree_specs,
)

from repro.checkpoint.io import (load_checkpoint, load_engine_state,  # noqa: F401
                                 save_checkpoint, save_engine_state)

"""Pytree checkpointing: flat .npz + json tree metadata.

Saves both the averaged (consensus) model and, optionally, the full
per-worker state so a local-SGD run can resume mid-phase without losing
worker diversity (which one-shot-style resumes would destroy).
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, tree, *, step: int = 0, extra: dict | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(path + ".npz", **arrays)
    meta = {
        "treedef": str(treedef),
        "num_leaves": len(leaves),
        "step": step,
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
        "shapes": [list(np.asarray(x).shape) for x in leaves],
        "extra": extra or {},
    }
    with open(path + ".json", "w") as f:
        json.dump(meta, f)


def load_checkpoint(path: str, like_tree):
    """Restore into the structure of ``like_tree`` (shape/dtype checked)."""
    with open(path + ".json") as f:
        meta = json.load(f)
    data = np.load(path + ".npz")
    leaves = [data[f"leaf_{i}"] for i in range(meta["num_leaves"])]
    like_leaves, treedef = jax.tree_util.tree_flatten(like_tree)
    assert len(leaves) == len(like_leaves), "checkpoint/model mismatch"
    for got, want in zip(leaves, like_leaves):
        assert got.shape == tuple(np.shape(want)), (got.shape, np.shape(want))
    leaves = [np.asarray(g).astype(np.asarray(w).dtype)
              for g, w in zip(leaves, like_leaves)]
    return jax.tree_util.tree_unflatten(treedef, leaves), meta["step"]


# --------------------------------------------------------------------------
# Full EngineState checkpointing (resume mid-run without losing worker
# diversity, optimizer moments, PRNG streams or the step counter)
# --------------------------------------------------------------------------

def save_engine_state(path: str, state, *, extra: dict | None = None):
    """Checkpoint a full ``repro.core.EngineState`` — worker params,
    optimizer state, outer-optimizer state, both PRNG keys, the step
    counter and the schedule state — so ``PhaseEngine.run(...,
    state=loaded)`` continues the run bit-identically to one that was
    never interrupted (static averaging decisions are pure functions of
    (dec_key, step); the adaptive schedules' decisions are pure
    functions of the checkpointed ``SchedState``, which carries the
    dispersion EMA, pacing credit and budget spent forward)."""
    state = jax.device_get(state)
    save_checkpoint(path, state, step=int(state.step), extra=extra)


def load_engine_state(path: str, like_state):
    """Restore an EngineState saved by :func:`save_engine_state` into
    the structure of ``like_state`` (e.g. ``engine.init(params, M)``).
    Returns (state, step).

    Checkpoints written before ``EngineState`` carried the schedule
    state load too: the missing ``SchedState`` leaves are taken fresh
    from ``like_state`` (all-zero bookkeeping — exactly where a run of
    a pre-SchedState build stood)."""
    try:
        state, step = load_checkpoint(path, like_state)
    except AssertionError:
        if getattr(like_state, "sched", ()) == ():
            raise
        bare = like_state._replace(sched=())
        state, step = load_checkpoint(path, bare)
        state = state._replace(sched=like_state.sched)
    return state, step

"""Pytree checkpointing: flat .npz + json tree metadata.

Saves both the averaged (consensus) model and, optionally, the full
per-worker state so a local-SGD run can resume mid-phase without losing
worker diversity (which one-shot-style resumes would destroy).

Saves are crash-safe: both files are written to a temp name and
``os.replace``'d into place, with the json metadata renamed LAST — it is
the commit point loaders read first, so an interrupted save leaves
either the previous checkpoint intact or no (complete) checkpoint at
all, never a torn one that loads garbage. A torn/partial file (killed
mid-rename, disk full, manual truncation) is refused with an actionable
error instead of an opaque zipfile traceback.
"""
from __future__ import annotations

import json
import os
import zipfile

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _atomic_replace(tmp: str, dst: str):
    os.replace(tmp, dst)


def save_checkpoint(path: str, tree, *, step: int = 0, extra: dict | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    # temp-file + atomic rename; np.savez gets an open file object (a
    # bare str path would sprout a second ".npz" suffix)
    npz_tmp = path + ".npz.tmp"
    with open(npz_tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    _atomic_replace(npz_tmp, path + ".npz")
    meta = {
        "treedef": str(treedef),
        "num_leaves": len(leaves),
        "step": step,
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
        "shapes": [list(np.asarray(x).shape) for x in leaves],
        "extra": extra or {},
    }
    # metadata last: loaders open the json first, so its rename is the
    # commit point for the whole checkpoint
    json_tmp = path + ".json.tmp"
    with open(json_tmp, "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    _atomic_replace(json_tmp, path + ".json")


def _read_meta(path: str) -> dict:
    try:
        with open(path + ".json") as f:
            return json.load(f)
    except json.JSONDecodeError as e:
        raise ValueError(
            f"checkpoint {path!r} has torn/partial metadata "
            f"({path}.json: {e}) — the save that wrote it was "
            "interrupted; delete this checkpoint and resume from an "
            "earlier one") from e


def load_checkpoint(path: str, like_tree):
    """Restore into the structure of ``like_tree`` (shape/dtype checked).
    Refuses torn/partial files with an actionable error."""
    meta = _read_meta(path)
    try:
        data = np.load(path + ".npz")
        leaves = [np.array(data[f"leaf_{i}"])
                  for i in range(meta["num_leaves"])]
    except FileNotFoundError as e:
        raise ValueError(
            f"checkpoint {path!r} has metadata but no array file "
            f"({path}.npz missing) — the save that wrote it was "
            "interrupted or the file was removed; delete this "
            "checkpoint and resume from an earlier one") from e
    except (zipfile.BadZipFile, EOFError, KeyError, OSError,
            ValueError) as e:
        raise ValueError(
            f"checkpoint {path!r} has a torn/partial array file "
            f"({path}.npz: {e}) — the save that wrote it was "
            "interrupted; delete this checkpoint and resume from an "
            "earlier one") from e
    like_leaves, treedef = jax.tree_util.tree_flatten(like_tree)
    assert len(leaves) == len(like_leaves), "checkpoint/model mismatch"
    for got, want in zip(leaves, like_leaves):
        assert got.shape == tuple(np.shape(want)), (got.shape, np.shape(want))
    leaves = [np.asarray(g).astype(np.asarray(w).dtype)
              for g, w in zip(leaves, like_leaves)]
    return jax.tree_util.tree_unflatten(treedef, leaves), meta["step"]


# --------------------------------------------------------------------------
# Full EngineState checkpointing (resume mid-run without losing worker
# diversity, optimizer moments, PRNG streams or the step counter)
# --------------------------------------------------------------------------

#: EngineState checkpoint layout versions:
#:   0 — pre-SchedState EngineState (PR 3 and earlier): no ``sched``
#:       leaves in the flattened state
#:   1 — EngineState with the SchedState carry (PR 4); version field
#:       not yet written, so v0-vs-v1 was sniffed by leaf count
#:   2 — same leaf layout as v1, with the version recorded explicitly
#:       in the checkpoint metadata
#:   3 — EngineState with the error-feedback residual plane
#:       (``resid`` — compressed communication, PR 6). Written only
#:       when the state actually carries residual leaves; uncompressed
#:       runs keep writing the v2 (or v0) layout, so their checkpoints
#:       stay loadable by older builds
#:   4 — EngineState with the per-worker fault rows (``fault`` —
#:       alive/staleness, PR 7). Written only when the state carries
#:       fault leaves; the metadata records ``has_resid`` since the
#:       residual plane is independent of the fault rows
#:   5 — elastic-membership saves (``repro.elastic``): the leaf layout
#:       is unchanged, but the metadata declares which optional fields
#:       are present (``has_sched`` / ``has_resid`` / ``has_fault``)
#:       instead of implying them from the version, plus the worker
#:       plane row count ``num_workers`` — a resized run resumes
#:       bit-exactly into the like-state of the segment that saved it.
#:       Fixed-membership runs keep writing the lowest version that
#:       describes their layout, so their checkpoints stay loadable by
#:       older builds
ENGINE_STATE_VERSION = 5
_VERSION_KEY = "engine_state_version"
_HAS_RESID_KEY = "has_resid"
_HAS_FAULT_KEY = "has_fault"
_HAS_SCHED_KEY = "has_sched"
_NUM_WORKERS_KEY = "num_workers"
#: optional EngineState fields, in the order they were added
_OPTIONAL_FIELDS = ("sched", "resid", "fault")


def save_engine_state(path: str, state, *, extra: dict | None = None,
                      elastic: bool = False):
    """Checkpoint a full ``repro.core.EngineState`` — worker params,
    optimizer state, outer-optimizer state, both PRNG keys, the step
    counter, the schedule state and (under a fault plan) the per-worker
    fault rows — so ``PhaseEngine.run(..., state=loaded)`` continues the
    run bit-identically to one that was never interrupted (static
    averaging decisions are pure functions of (dec_key, step); the
    adaptive schedules' decisions are pure functions of the checkpointed
    ``SchedState``; fault streams are pure functions of (dec_key, step,
    row) plus the checkpointed alive/staleness rows). The checkpoint
    metadata records ``engine_state_version`` so loaders dispatch on the
    declared layout instead of sniffing leaf counts.

    ``elastic=True`` marks the save as coming from a resizable-membership
    run (``repro.elastic``): the v5 metadata declares the optional
    fields explicitly and the worker plane row count, so a later resume
    can be matched against the elastic plan's segment for that step."""
    state = jax.device_get(state)
    extra = dict(extra or {})
    # the version describes the LAYOUT the state actually has: no
    # SchedState leaves (sched=()) is exactly the v0 layout, no
    # residual/fault leaves the v2 one, whoever writes it
    has_sched = not _absent(getattr(state, "sched", ()))
    has_resid = not _absent(getattr(state, "resid", ()))
    has_fault = not _absent(getattr(state, "fault", ()))
    wp_leaves = jax.tree_util.tree_leaves(state.worker_params)
    if wp_leaves:
        extra[_NUM_WORKERS_KEY] = int(np.shape(wp_leaves[0])[0])
    if elastic:
        extra[_VERSION_KEY] = ENGINE_STATE_VERSION
        extra[_HAS_SCHED_KEY] = has_sched
        extra[_HAS_RESID_KEY] = has_resid
        extra[_HAS_FAULT_KEY] = has_fault
    elif not has_sched:
        extra[_VERSION_KEY] = 0
    elif has_fault:
        # the fault-row layout is v4; v5 marks elastic saves only
        extra[_VERSION_KEY] = 4
        extra[_HAS_RESID_KEY] = has_resid
    elif has_resid:
        extra[_VERSION_KEY] = 3
    else:
        extra[_VERSION_KEY] = 2
    save_checkpoint(path, state, step=int(state.step), extra=extra)


def _absent(field) -> bool:
    """True when an optional EngineState field is the empty-tuple
    sentinel (``==`` would broadcast against array-valued fields)."""
    return isinstance(field, tuple) and len(field) == 0


def _load_subset(path: str, like_state, present: frozenset | set):
    """Load a checkpoint whose layout carries the optional fields in
    ``present``: fields the target state has but the checkpoint lacks
    are stripped for the structural load and refilled fresh from
    ``like_state``; fields the checkpoint has but the target lacks are
    refused with a field-specific, actionable error."""
    if "resid" in present and _absent(getattr(like_state, "resid", ())):
        raise ValueError(
            f"checkpoint {path!r} carries an error-feedback residual "
            "plane but the target engine has no active compression — "
            "init the engine with the run's Compression before loading")
    if "fault" in present and _absent(getattr(like_state, "fault", ())):
        raise ValueError(
            f"checkpoint {path!r} carries per-worker fault rows "
            "(engine-state v4) but the target engine has no fault "
            "plan — init the engine with the run's FaultPlan before "
            "loading")
    strip = {f: () for f in _OPTIONAL_FIELDS
             if f not in present
             and not _absent(getattr(like_state, f, ()))}
    if not strip:
        return load_checkpoint(path, like_state)
    bare = like_state._replace(**strip)
    state, step = load_checkpoint(path, bare)
    return state._replace(
        **{f: getattr(like_state, f) for f in strip}), step


def _load_v0(path: str, like_state):
    """A v0 state has neither ``sched``, ``resid`` nor ``fault`` leaves:
    load into the bare layout and take all three fresh from
    ``like_state`` (all-zero bookkeeping / all-zero residuals /
    all-alive fault rows — exactly where a run of a pre-SchedState
    build stood)."""
    return _load_subset(path, like_state, set())


def _load_pre_resid(path: str, like_state):
    """v1/v2 states carry SchedState but no residual plane or fault
    rows: both start fresh from ``like_state`` — error feedback begins
    accumulating at the first post-resume event, and every worker
    resumes alive."""
    return _load_subset(path, like_state, {"sched"})


def load_engine_state(path: str, like_state):
    """Restore an EngineState saved by :func:`save_engine_state` into
    the structure of ``like_state`` (e.g. ``engine.init(params, M)``).
    Returns (state, step).

    The checkpoint's declared ``engine_state_version`` picks the
    layout: v5 (elastic saves) declares its optional fields in the
    metadata, v4 carries the per-worker fault rows (and, per its
    ``has_resid`` metadata, possibly the residual plane), v3 the
    residual plane, v1/v2 the SchedState leaves only, v0 predates all
    of them; every field the checkpoint lacks starts fresh from
    ``like_state`` (zero bookkeeping, zero residuals, all-alive fault
    rows). Checkpoints from builds that did not yet write the version
    field load too — the v0-vs-v1 distinction falls back to the
    historical leaf-count sniff.

    A checkpoint whose worker plane has a different row count than
    ``like_state`` is refused eagerly with both Ms named — membership
    changed between save and resume, and the fix is the resize API,
    not a structural load into the wrong-sized plane."""
    meta = _read_meta(path)
    extra = meta.get("extra") or {}
    like_wp = jax.tree_util.tree_leaves(like_state.worker_params)
    got_m = extra.get(_NUM_WORKERS_KEY)
    if got_m is None and meta.get("shapes") and meta["shapes"][0]:
        # pre-v5 saves: the first flattened leaf is a worker-params
        # plane, so its leading dim is the saved M
        got_m = meta["shapes"][0][0]
    if like_wp and got_m is not None:
        want_m = int(np.shape(like_wp[0])[0])
        if int(got_m) != want_m:
            raise ValueError(
                f"checkpoint {path!r} holds a {int(got_m)}-row worker "
                f"plane but the target engine state has {want_m} rows — "
                "membership changed between save and resume. Resume "
                "through repro.elastic instead: replay the run's "
                "--shrink-at/--grow-at plan (run_elastic applies the "
                "resizes), or build the matching like-state with "
                "repro.elastic.segment_engine(engine, plan, step) — "
                "loading into a fixed-M engine of the wrong size would "
                "scramble the worker rows")
    version = extra.get(_VERSION_KEY)
    if version is not None:
        if (isinstance(version, bool) or not isinstance(version, int)
                or version < 0):
            raise ValueError(
                f"checkpoint {path!r} declares an invalid engine-state "
                f"version {version!r} (expected an int in "
                f"[0, {ENGINE_STATE_VERSION}])")
        if version > ENGINE_STATE_VERSION:
            raise ValueError(
                f"checkpoint {path!r} declares engine-state version "
                f"{version}, newer than this build's "
                f"{ENGINE_STATE_VERSION} — load it with the build that "
                "wrote it")
        if version == 0:
            return _load_v0(path, like_state)
        if version in (1, 2):
            return _load_pre_resid(path, like_state)
        if version == 3:
            return _load_subset(path, like_state, {"sched", "resid"})
        if version == 4:
            present = {"sched", "fault"}
            if extra.get(_HAS_RESID_KEY, True):
                present.add("resid")
            return _load_subset(path, like_state, present)
        present = set()
        if extra.get(_HAS_SCHED_KEY, True):
            present.add("sched")
        if extra.get(_HAS_RESID_KEY, False):
            present.add("resid")
        if extra.get(_HAS_FAULT_KEY, False):
            present.add("fault")
        return _load_subset(path, like_state, present)
    try:
        return _load_pre_resid(path, like_state)
    except AssertionError:
        return _load_v0(path, like_state)

"""Pytree checkpointing: flat .npz + json tree metadata.

Saves both the averaged (consensus) model and, optionally, the full
per-worker state so a local-SGD run can resume mid-phase without losing
worker diversity (which one-shot-style resumes would destroy).
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, tree, *, step: int = 0, extra: dict | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(path + ".npz", **arrays)
    meta = {
        "treedef": str(treedef),
        "num_leaves": len(leaves),
        "step": step,
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
        "shapes": [list(np.asarray(x).shape) for x in leaves],
        "extra": extra or {},
    }
    with open(path + ".json", "w") as f:
        json.dump(meta, f)


def load_checkpoint(path: str, like_tree):
    """Restore into the structure of ``like_tree`` (shape/dtype checked)."""
    with open(path + ".json") as f:
        meta = json.load(f)
    data = np.load(path + ".npz")
    leaves = [data[f"leaf_{i}"] for i in range(meta["num_leaves"])]
    like_leaves, treedef = jax.tree_util.tree_flatten(like_tree)
    assert len(leaves) == len(like_leaves), "checkpoint/model mismatch"
    for got, want in zip(leaves, like_leaves):
        assert got.shape == tuple(np.shape(want)), (got.shape, np.shape(want))
    leaves = [np.asarray(g).astype(np.asarray(w).dtype)
              for g, w in zip(leaves, like_leaves)]
    return jax.tree_util.tree_unflatten(treedef, leaves), meta["step"]


# --------------------------------------------------------------------------
# Full EngineState checkpointing (resume mid-run without losing worker
# diversity, optimizer moments, PRNG streams or the step counter)
# --------------------------------------------------------------------------

#: EngineState checkpoint layout versions:
#:   0 — pre-SchedState EngineState (PR 3 and earlier): no ``sched``
#:       leaves in the flattened state
#:   1 — EngineState with the SchedState carry (PR 4); version field
#:       not yet written, so v0-vs-v1 was sniffed by leaf count
#:   2 — same leaf layout as v1, with the version recorded explicitly
#:       in the checkpoint metadata
#:   3 — EngineState with the error-feedback residual plane
#:       (``resid`` — compressed communication, PR 6). Written only
#:       when the state actually carries residual leaves; uncompressed
#:       runs keep writing the v2 (or v0) layout, so their checkpoints
#:       stay loadable by older builds
ENGINE_STATE_VERSION = 3
_VERSION_KEY = "engine_state_version"


def save_engine_state(path: str, state, *, extra: dict | None = None):
    """Checkpoint a full ``repro.core.EngineState`` — worker params,
    optimizer state, outer-optimizer state, both PRNG keys, the step
    counter and the schedule state — so ``PhaseEngine.run(...,
    state=loaded)`` continues the run bit-identically to one that was
    never interrupted (static averaging decisions are pure functions of
    (dec_key, step); the adaptive schedules' decisions are pure
    functions of the checkpointed ``SchedState``, which carries the
    dispersion EMA, pacing credit and budget spent forward). The
    checkpoint metadata records ``engine_state_version`` so loaders
    dispatch on the declared layout instead of sniffing leaf counts."""
    state = jax.device_get(state)
    extra = dict(extra or {})
    # the version describes the LAYOUT the state actually has: no
    # SchedState leaves (sched=()) is exactly the v0 layout, no
    # residual leaves (resid=()) the v2 one, whoever writes it
    if _absent(getattr(state, "sched", ())):
        extra[_VERSION_KEY] = 0
    elif _absent(getattr(state, "resid", ())):
        extra[_VERSION_KEY] = 2
    else:
        extra[_VERSION_KEY] = ENGINE_STATE_VERSION
    save_checkpoint(path, state, step=int(state.step), extra=extra)


def _absent(field) -> bool:
    """True when an optional EngineState field is the empty-tuple
    sentinel (``==`` would broadcast against array-valued fields)."""
    return isinstance(field, tuple) and len(field) == 0


def _load_v0(path: str, like_state):
    """A v0 state has neither ``sched`` nor ``resid`` leaves: load into
    the bare layout and take both fresh from ``like_state`` (all-zero
    bookkeeping / all-zero residuals — exactly where a run of a
    pre-SchedState build stood)."""
    if _absent(getattr(like_state, "sched", ())) and \
            _absent(getattr(like_state, "resid", ())):
        return load_checkpoint(path, like_state)
    bare = like_state._replace(sched=(), resid=())
    state, step = load_checkpoint(path, bare)
    return state._replace(sched=like_state.sched,
                          resid=like_state.resid), step


def _load_pre_resid(path: str, like_state):
    """v1/v2 states carry SchedState but no residual plane: residuals
    start fresh (zero) from ``like_state`` — error feedback begins
    accumulating at the first post-resume event."""
    if _absent(getattr(like_state, "resid", ())):
        return load_checkpoint(path, like_state)
    bare = like_state._replace(resid=())
    state, step = load_checkpoint(path, bare)
    return state._replace(resid=like_state.resid), step


def load_engine_state(path: str, like_state):
    """Restore an EngineState saved by :func:`save_engine_state` into
    the structure of ``like_state`` (e.g. ``engine.init(params, M)``).
    Returns (state, step).

    The checkpoint's declared ``engine_state_version`` picks the
    layout: v3 carries the error-feedback residual plane, v1/v2 carry
    the SchedState leaves but no residuals (they start fresh at zero),
    v0 predates both (SchedState AND residuals come fresh from
    ``like_state``). Checkpoints from builds that did not yet write
    the version field load too — the v0-vs-v1 distinction falls back
    to the historical leaf-count sniff."""
    with open(path + ".json") as f:
        meta = json.load(f)
    version = (meta.get("extra") or {}).get(_VERSION_KEY)
    if version is not None:
        if (isinstance(version, bool) or not isinstance(version, int)
                or version < 0):
            raise ValueError(
                f"checkpoint {path!r} declares an invalid engine-state "
                f"version {version!r} (expected an int in "
                f"[0, {ENGINE_STATE_VERSION}])")
        if version > ENGINE_STATE_VERSION:
            raise ValueError(
                f"checkpoint {path!r} declares engine-state version "
                f"{version}, newer than this build's "
                f"{ENGINE_STATE_VERSION} — load it with the build that "
                "wrote it")
        if version == 0:
            return _load_v0(path, like_state)
        if version < ENGINE_STATE_VERSION:
            return _load_pre_resid(path, like_state)
        if _absent(getattr(like_state, "resid", ())):
            raise ValueError(
                f"checkpoint {path!r} carries an error-feedback "
                "residual plane (engine-state v3) but the target "
                "engine has no active compression — init the engine "
                "with the run's Compression before loading")
        return load_checkpoint(path, like_state)
    try:
        return _load_pre_resid(path, like_state)
    except AssertionError:
        return _load_v0(path, like_state)

"""Roofline-term derivation from compiled dry-run artifacts.

  compute term    = HLO_FLOPs_per_device / peak_FLOPs
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw

HLO_FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device SPMD
module, so the spec's global/(chips×peak) equals per-device/peak).
Collective bytes are parsed from the SPMD HLO text: the result-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. Ring-algorithm traffic multipliers (~2(n-1)/n) are
deliberately NOT applied — reported numbers are payload bytes per chip;
methodology noted in EXPERIMENTS.md.
"""
from __future__ import annotations

import re
from dataclasses import dataclass


@dataclass(frozen=True)
class HW:
    """TPU v5e-class chip (target hardware; see task spec)."""
    peak_flops: float = 197e12   # bf16 FLOP/s
    hbm_bw: float = 819e9        # B/s
    ici_bw: float = 50e9         # B/s per link


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.  %all-reduce.5 = bf16[16,2560]{1,0} all-reduce(...)
_INSTR_RE = re.compile(
    r"=\s*(\(?)([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_TUPLE_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def wire_scale(wire: str = "f32") -> float:
    """Asymptotic bytes-on-the-wire fraction of an f32 payload shipped
    in ``wire`` format (``repro.core.compress.WIRE_BITS``; the per-row
    f32 scale of the scaled formats vanishes at roofline widths):
    1.0 / 0.5 / 0.25 / 0.03125 for f32 / bf16 / int8 / one_bit."""
    from repro.core.compress import WIRE_BITS
    if wire not in WIRE_BITS:
        raise ValueError(f"unknown wire format {wire!r}; "
                         f"pick one of {tuple(WIRE_BITS)}")
    return WIRE_BITS[wire] / 32.0


def collective_bytes(hlo_text: str, *, wire: str = "f32") -> dict:
    """Sum result-shape bytes per collective kind from HLO text.

    ``wire`` rescales the f32 collective payloads to the given wire
    format (``repro.core.compress``): the compiled HLO moves f32
    planes, but a compressed-communication deployment ships them
    encoded, so the roofline's collective term shrinks by
    :func:`wire_scale`. Non-f32 collectives (already-reduced
    precisions, integer index exchanges) are left untouched."""
    scale = wire_scale(wire)
    out = {k: 0 for k in _COLL_KINDS}
    counts = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        kind = m.group(4)
        if "-done(" in line:
            continue  # avoid double counting start/done pairs
        if m.group(1) == "(":
            # tuple result: sum all component shapes up to the op name
            head = line.split(kind)[0]
            total = sum(int(_shape_bytes(d, s) * (scale if d == "f32"
                                                  else 1.0))
                        for d, s in _TUPLE_SHAPE_RE.findall(head))
        else:
            total = _shape_bytes(m.group(2), m.group(3))
            if m.group(2) == "f32":
                total = int(total * scale)
        out[kind] += total
        counts[kind] += 1
    out["total"] = sum(out[k] for k in _COLL_KINDS)
    out["counts"] = counts
    return out


def roofline_report(compiled, *, hw: HW = HW(), model_flops: float = 0.0,
                    chips: int = 1, hlo_text: str | None = None,
                    wire: str = "f32") -> dict:
    """Derive the three terms + bottleneck from a compiled executable.
    ``wire`` prices the f32 collective payloads at that wire format
    (compressed communication shrinks the collective term only — HBM
    traffic is unchanged, the planes stay f32 in memory)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_ = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text, wire=wire)

    compute_s = flops / hw.peak_flops
    memory_s = bytes_ / hw.hbm_bw
    collective_s = coll["total"] / hw.ici_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    rep = {
        "flops_per_device": flops,
        "bytes_per_device": bytes_,
        "wire": wire,
        "collective_bytes_per_device": coll["total"],
        "collective_breakdown": {k: coll[k] for k in _COLL_KINDS},
        "collective_counts": coll["counts"],
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "step_time_lower_bound_s": max(terms.values()),
    }
    if model_flops:
        rep["model_flops_global"] = model_flops
        hlo_global = flops * chips
        rep["useful_flop_fraction"] = model_flops / hlo_global if hlo_global else 0.0
    try:
        ma = compiled.memory_analysis()
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                rep[f"mem_{attr}"] = int(v)
    except Exception:
        pass
    return rep


def model_flops(cfg, shape, *, training: bool) -> float:
    """6·N_active·D for train, 2·N_active·D for inference (global)."""
    n = cfg.num_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence

"""Model zoo: composable JAX definitions for every assigned architecture
plus the paper's own experimental models (CNN, convex)."""
from repro.models.transformer import (  # noqa: F401
    init_params,
    init_cache,
    forward,
    decode_step,
    lm_loss,
)

"""Model assembly: heterogeneous block stacks (dense / local / recurrent /
rwkv / moe / cross-attn), encoder-decoder support (whisper), VLM
cross-attention, full-sequence forward (train & prefill) and single-token
decode with per-layer caches.

Layers are applied with an unrolled python loop (no lax.scan) so XLA's
cost analysis sees the full FLOP count (DESIGN.md §5); per-block remat is
available for the training path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs import LayerSpec, ModelConfig
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import recurrent as rec_mod
from repro.models import rwkv as rwkv_mod


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------

def _init_block(cfg: ModelConfig, spec: LayerSpec, key):
    ks = jax.random.split(key, 4)
    p = {"norm1": L.init_norm(cfg)}
    if spec.mixer in ("attn", "attn_local"):
        p["mixer"] = attn_mod.init_attn(cfg, ks[0])
    elif spec.mixer == "rglru":
        p["mixer"] = rec_mod.init_rglru(cfg, ks[0])
    elif spec.mixer == "rwkv":
        p["mixer"] = rwkv_mod.init_rwkv(cfg, ks[0])
    if spec.cross_attn:
        p["norm_cross"] = L.init_norm(cfg)
        p["cross"] = attn_mod.init_attn(cfg, ks[1], cross=True)
    p["norm2"] = L.init_norm(cfg)
    if spec.ffn == "dense":
        p["ffn"] = L.init_mlp(cfg, ks[2])
    elif spec.ffn == "moe":
        p["ffn"] = moe_mod.init_moe(cfg, ks[2])
    elif spec.ffn == "rwkv_cmix":
        p["ffn"] = rwkv_mod.init_rwkv_cmix(cfg, ks[2])
    return p


def init_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, cfg.num_layers + cfg.encoder_layers + 2)
    params = {
        "embed": L.init_embed(cfg, ks[0]),
        "final_norm": L.init_norm(cfg),
        "layers": [
            _init_block(cfg, spec, ks[1 + i])
            for i, spec in enumerate(cfg.layers)
        ],
    }
    if cfg.encoder_layers:
        enc_spec = LayerSpec(mixer="attn", causal=False)
        params["encoder"] = {
            "layers": [
                _init_block(cfg, enc_spec, ks[1 + cfg.num_layers + i])
                for i in range(cfg.encoder_layers)
            ],
            "final_norm": L.init_norm(cfg),
        }
    return params


# --------------------------------------------------------------------------
# Full-sequence block / forward (train & prefill)
# --------------------------------------------------------------------------

def _apply_block(cfg: ModelConfig, spec: LayerSpec, p, x, memory, impl,
                 capture: int = 0):
    """capture > 0: also return the decode cache for this block, with
    attention K/V padded to ``capture`` positions (prefill)."""
    aux = {}
    cache = {}
    if spec.mixer != "none":
        h = L.apply_norm(cfg, p["norm1"], x)
        if spec.mixer in ("attn", "attn_local"):
            if capture:
                h, (k, v) = attn_mod.attention(cfg, p["mixer"], h,
                                               layer=spec, impl=impl,
                                               return_kv=True)
                pad = ((0, 0), (0, capture - k.shape[1]), (0, 0), (0, 0))
                cache["attn"] = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
            else:
                h = attn_mod.attention(cfg, p["mixer"], h, layer=spec,
                                       impl=impl)
        elif spec.mixer == "rglru":
            if capture:
                h, cache["rglru"] = rec_mod.apply_rglru(
                    cfg, p["mixer"], h, impl=impl, return_state=True)
            else:
                h = rec_mod.apply_rglru(cfg, p["mixer"], h, impl=impl)
        elif spec.mixer == "rwkv":
            if capture:
                h, cache["rwkv"] = rwkv_mod.apply_rwkv(
                    cfg, p["mixer"], h, impl=impl, return_state=True)
            else:
                h = rwkv_mod.apply_rwkv(cfg, p["mixer"], h, impl=impl)
        x = x + h
    if spec.cross_attn:
        h = L.apply_norm(cfg, p["norm_cross"], x)
        h = attn_mod.attention(cfg, p["cross"], h, layer=spec,
                               kv_x=memory, impl=impl)
        if capture:
            cache["cross"] = attn_mod.cross_cache_from_memory(
                cfg, p["cross"], memory)
        x = x + h
    h = L.apply_norm(cfg, p["norm2"], x)
    if spec.ffn == "dense":
        h = L.apply_mlp(cfg, p["ffn"], h)
    elif spec.ffn == "moe":
        h, aux = moe_mod.apply_moe(cfg, p["ffn"], h)
    elif spec.ffn == "rwkv_cmix":
        h2 = rwkv_mod.apply_rwkv_cmix(cfg, p["ffn"], h)
        if capture:
            cache.setdefault("rwkv", {})["shift_c"] = h[:, -1:]
        h = h2
    else:
        h = jnp.zeros_like(x)
    if capture:
        return x + h, aux, cache
    return x + h, aux


def encode(cfg: ModelConfig, params, memory_embed, impl="xla"):
    """Run the (whisper) encoder over stubbed frame embeddings."""
    x = memory_embed.astype(L.cdtype(cfg))
    enc_spec = LayerSpec(mixer="attn", causal=False)
    for p in params["encoder"]["layers"]:
        x, _ = _apply_block(cfg, enc_spec, p, x, None, impl)
    return L.apply_norm(cfg, params["encoder"]["final_norm"], x)


def _get_memory(cfg: ModelConfig, params, batch, impl):
    if cfg.family == "audio":
        return encode(cfg, params, batch["audio"], impl)
    if cfg.family == "vlm":
        return batch["media"].astype(L.cdtype(cfg))
    return None


def forward(cfg: ModelConfig, params, batch, *, impl="xla", remat=False,
            return_cache=False, cache_len=0):
    """batch: {"tokens": (B,S) int32, ["audio"|"media"]: (B,T,d)}.
    Returns (logits fp32 (B,S,V), aux dict of scalar metrics); with
    ``return_cache`` (true prefill) additionally a decode cache sized
    ``cache_len`` (>= S), ready for repro.models.decode_step."""
    memory = _get_memory(cfg, params, batch, impl)
    tokens = batch["tokens"]
    x = L.embed(cfg, params["embed"], tokens)
    aux_sum = {"load_balance": 0.0, "router_z": 0.0}
    capture = 0
    if return_cache:
        assert not remat, "prefill cache capture is a no-remat path"
        capture = max(cache_len, tokens.shape[1])

    caches = []
    for spec, p in zip(cfg.layers, params["layers"]):
        fn = functools.partial(_apply_block, cfg, spec)
        if remat:
            fn = jax.checkpoint(
                lambda p_, x_, m_, fn=fn: fn(p_, x_, m_, impl))
            x, aux = fn(p, x, memory)
        elif capture:
            x, aux, c = fn(p, x, memory, impl, capture)
            caches.append(c)
        else:
            x, aux = fn(p, x, memory, impl)
        for k_ in aux:
            aux_sum[k_] = aux_sum[k_] + aux[k_]
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], x)
    if return_cache:
        cache = {"pos": jnp.asarray(tokens.shape[1], jnp.int32),
                 "layers": caches}
        return logits, aux_sum, cache
    return logits, aux_sum


def lm_loss(cfg: ModelConfig, params, batch, *, impl="xla", remat=False):
    """Next-token cross-entropy (+ MoE aux). labels default to shifted
    tokens; positions where label < 0 are masked."""
    logits, aux = forward(cfg, params, batch, impl=impl, remat=remat)
    if "labels" in batch:
        labels = batch["labels"]
    else:
        labels = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)),
                         constant_values=-1)
    mask = (labels >= 0).astype(jnp.float32)
    labels_c = jnp.clip(labels, 0, cfg.padded_vocab - 1)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels_c[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    moe_layers = max(1, sum(1 for s in cfg.layers if s.ffn == "moe"))
    aux_loss = cfg.router_aux_coef * aux["load_balance"] / moe_layers \
        + 1e-3 * aux["router_z"] / moe_layers
    if cfg.num_experts:
        loss = loss + aux_loss
    metrics = {"ce": loss, **{k: v for k, v in aux.items()}}
    return loss, metrics


# --------------------------------------------------------------------------
# Decode (single token, per-layer caches)
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int, *,
               memory=None, params=None):
    """Build the per-layer decode cache pytree.

    memory: encoder/vision embeddings (B, T, d) — cross K/V are
    precomputed here (as a real serving runtime does at prefill)."""
    dt = L.cdtype(cfg)
    layers = []
    for spec, p in zip(cfg.layers, params["layers"] if params else [None] * cfg.num_layers):
        c = {}
        if spec.mixer in ("attn", "attn_local"):
            c["attn"] = attn_mod.init_attn_cache(cfg, batch, seq_len, dt)
        elif spec.mixer == "rglru":
            c["rglru"] = rec_mod.init_rglru_cache(cfg, batch, dt)
        elif spec.mixer == "rwkv":
            c["rwkv"] = rwkv_mod.init_rwkv_cache(cfg, batch, dt)
        if spec.cross_attn:
            assert memory is not None and p is not None
            c["cross"] = attn_mod.cross_cache_from_memory(cfg, p["cross"], memory)
        if spec.ffn == "rwkv_cmix":
            c.setdefault("rwkv", rwkv_mod.init_rwkv_cache(cfg, batch, dt))
        layers.append(c)
    return {"pos": jnp.zeros((), jnp.int32), "layers": layers}


def _decode_block(cfg, spec, p, x, cache, pos):
    if spec.mixer != "none":
        h = L.apply_norm(cfg, p["norm1"], x)
        if spec.mixer in ("attn", "attn_local"):
            h, cache["attn"] = attn_mod.decode_attention(
                cfg, p["mixer"], h, cache["attn"], pos, layer=spec)
        elif spec.mixer == "rglru":
            h, cache["rglru"] = rec_mod.decode_rglru(cfg, p["mixer"], h, cache["rglru"])
        elif spec.mixer == "rwkv":
            h, cache["rwkv"] = rwkv_mod.decode_rwkv(cfg, p["mixer"], h, cache["rwkv"])
        x = x + h
    if spec.cross_attn:
        h = L.apply_norm(cfg, p["norm_cross"], x)
        h = attn_mod.decode_cross_attention(cfg, p["cross"], h, cache["cross"])
        x = x + h
    h = L.apply_norm(cfg, p["norm2"], x)
    if spec.ffn == "dense":
        h = L.apply_mlp(cfg, p["ffn"], h)
    elif spec.ffn == "moe":
        h, _ = moe_mod.apply_moe(cfg, p["ffn"], h)
    elif spec.ffn == "rwkv_cmix":
        h, cache["rwkv"] = rwkv_mod.decode_rwkv_cmix(cfg, p["ffn"], h, cache["rwkv"])
    else:
        h = jnp.zeros_like(x)
    return x + h, cache


def decode_step(cfg: ModelConfig, params, tokens, cache):
    """tokens: (B,1) int32. Returns (logits (B,1,V) fp32, new cache)."""
    pos = cache["pos"]
    x = L.embed(cfg, params["embed"], tokens, pos_offset=pos)
    new_layers = []
    for spec, p, c in zip(cfg.layers, params["layers"], cache["layers"]):
        x, c = _decode_block(cfg, spec, p, x, dict(c), pos)
        new_layers.append(c)
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], x)
    return logits, {"pos": pos + 1, "layers": new_layers}

"""Convex models from the paper's §3.1: least squares and logistic
regression, in component form f(w) = (1/m) sum_j f_j(w) so that per-sample
SGD (paper Eq. 2) and gradient-variance measurement (Definition 1) are
exact, not minibatch approximations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---- least squares: f_j(w) = 0.5 (x_j.w - y_j)^2 --------------------------

def ls_objective(w, X, y):
    r = X @ w - y
    return 0.5 * jnp.mean(r * r)


def ls_grad_sample(w, x_j, y_j):
    return x_j * (x_j @ w - y_j)


# ---- logistic regression: f_j(w) = log(1 + exp(-y_j x_j.w)), y in {-1,1} --

def lr_objective(w, X, y):
    z = y * (X @ w)
    return jnp.mean(jax.nn.softplus(-z))


def lr_grad_sample(w, x_j, y_j):
    z = y_j * (x_j @ w)
    return -y_j * jax.nn.sigmoid(-z) * x_j


def make_problem(kind: str):
    if kind == "ls":
        return ls_objective, ls_grad_sample
    if kind == "lr":
        return lr_objective, lr_grad_sample
    raise ValueError(kind)


def solve_optimum(kind, X, y, *, iters: int = 400, lr: float = 0.5):
    """w* — closed form for LS, full-gradient descent for logistic."""
    if kind == "ls":
        return jnp.linalg.solve(X.T @ X + 1e-6 * jnp.eye(X.shape[1]),
                                X.T @ y)
    obj = jax.jit(jax.value_and_grad(lambda w: lr_objective(w, X, y)))
    w = jnp.zeros(X.shape[1])
    meansq = float(jnp.mean(jnp.sum(X * X, axis=1)))
    step = lr / max(meansq / X.shape[1], 1e-9)  # ~ 1/avg feature scale
    for _ in range(iters):
        _, g = obj(w)
        w = w - step * g
    return w


def full_gradient(kind, w, X, y):
    obj, _ = make_problem(kind)
    return jax.grad(obj)(w, X, y)


def gradient_variance(kind, w, X, y):
    """Definition 1: (1/m) sum_j ||grad f_j(w) - grad f(w)||^2."""
    _, gs = make_problem(kind)
    per = jax.vmap(lambda xj, yj: gs(w, xj, yj))(X, y)
    if kind == "ls":
        # ls_objective has the 1/m inside; per-sample grads are the f_j grads
        pass
    g = jnp.mean(per, axis=0)
    return jnp.mean(jnp.sum((per - g) ** 2, axis=1))

"""GQA attention: training/prefill (full or sliding-window causal),
single-token decode against a KV cache, and cross-attention.

Two interchangeable compute paths:
  - "xla":    plain jnp einsums (used for dry-run/cost-analysis & CPU)
  - "pallas": repro.kernels flash attention (TPU target, interpret on CPU)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.models.layers import cdtype, dense_init, rope_freqs, apply_rope


def init_attn(cfg: ModelConfig, key, cross: bool = False):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, cfg.q_dim), 0, cdtype(cfg)),
        "wk": dense_init(ks[1], (d, cfg.kv_dim), 0, cdtype(cfg)),
        "wv": dense_init(ks[2], (d, cfg.kv_dim), 0, cdtype(cfg)),
        "wo": dense_init(ks[3], (cfg.q_dim, d), 0, cdtype(cfg)),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _sdpa_xla(q, k, v, mask, scale, score_dtype=jnp.float32):
    """q: (B,Sq,H,hd)  k/v: (B,Sk,Hkv,hd)  mask: broadcastable (B,1,Sq,Sk).

    score_dtype: dtype of the materialized (Sq,Sk) score/prob traffic —
    the softmax statistics themselves are always fp32."""
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(score_dtype)
    scores = scores * jnp.asarray(scale, score_dtype)
    scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask,
                       scores, jnp.asarray(-1e30, score_dtype))
    m = jnp.max(scores.astype(jnp.float32), axis=-1, keepdims=True)
    p = jnp.exp(scores.astype(jnp.float32) - m).astype(score_dtype)
    denom = jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
    p = (p.astype(jnp.float32) / jnp.maximum(denom, 1e-30)).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return out.reshape(b, sq, h, hd)


def _banded_attention(cfg, q, k, v, *, window, scale, score_dtype,
                      pos_offset=0):
    """Sliding-window attention computed band-wise: each q chunk of size
    c = window attends to a static k slice of 2c keys — score traffic is
    O(S·2w) instead of O(S²) (FLOPs likewise). Chunks are a static
    (unrolled) python loop so XLA cost analysis sees true FLOPs."""
    b, s, h, hd = q.shape
    c = min(window, s)
    s_pad = -(-s // c) * c
    qp = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    # pad keys by one chunk on the left so slice [i*c, i*c+2c) is static
    kp = jnp.pad(k, ((0, 0), (c, s_pad - s), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (c, s_pad - s), (0, 0), (0, 0)))
    outs = []
    for i in range(s_pad // c):
        qi = qp[:, i * c:(i + 1) * c]
        ki = kp[:, i * c:i * c + 2 * c]
        vi = vp[:, i * c:i * c + 2 * c]
        qpos = i * c + jnp.arange(c)[:, None]            # absolute q pos
        kpos = (i - 1) * c + jnp.arange(2 * c)[None, :]  # absolute k pos
        msk = (kpos <= qpos) & (kpos > qpos - window) & (kpos >= 0) & \
              (qpos < s)
        outs.append(_sdpa_xla(qi, ki, vi, msk[None, None], scale,
                              score_dtype))
    return jnp.concatenate(outs, axis=1)[:, :s]


def make_mask(sq: int, sk: int, *, causal: bool, window: int = 0,
              q_offset: int = 0):
    """Boolean mask (sq, sk), True = attend. q position i maps to absolute
    position q_offset + i; k position j is absolute j."""
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m


def attention(cfg: ModelConfig, p, x, *, layer, kv_x=None, impl="xla",
              pos_offset=0, return_kv=False):
    """Full-sequence attention (training / prefill).

    kv_x: source for k/v (cross-attention memory); None => self-attention.
    Returns (B, S, d_model), or (out, (k, v)) with post-RoPE k/v when
    ``return_kv`` (prefill cache capture).
    """
    b, sq, _ = x.shape
    src = x if kv_x is None else kv_x
    sk = src.shape[1]
    q = _split_heads(x @ p["wq"], cfg.num_heads, cfg.head_dim)
    k = _split_heads(src @ p["wk"], cfg.num_kv_heads, cfg.head_dim)
    v = _split_heads(src @ p["wv"], cfg.num_kv_heads, cfg.head_dim)

    self_attn = kv_x is None
    if self_attn and cfg.pos_emb == "rope":
        cos_q, sin_q = rope_freqs(cfg, pos_offset + jnp.arange(sq))
        q = apply_rope(q, cos_q, sin_q)
        cos_k, sin_k = rope_freqs(cfg, jnp.arange(sk))
        k = apply_rope(k, cos_k, sin_k)

    causal = layer.causal and self_attn
    window = cfg.sliding_window if (layer.mixer == "attn_local" and self_attn) else 0
    scale = 1.0 / np.sqrt(cfg.head_dim)

    score_dt = jnp.dtype(cfg.score_dtype)
    if impl == "pallas" and self_attn and sq == sk:
        from repro.kernels import ops
        out = ops.flash_attention(q, k, v, causal=causal, window=window,
                                  scale=scale)
    elif (cfg.attn_banded and window > 0 and causal and self_attn
          and sq == sk and pos_offset == 0):
        out = _banded_attention(cfg, q, k, v, window=window, scale=scale,
                                score_dtype=score_dt)
    else:
        mask = make_mask(sq, sk, causal=causal, window=window,
                         q_offset=pos_offset)[None, None]
        out = _sdpa_xla(q, k, v, mask, scale, score_dt)
    out = out.reshape(b, sq, cfg.q_dim) @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


# --------------------------------------------------------------------------
# Decode path (single token, KV cache)
# --------------------------------------------------------------------------

def init_attn_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype):
    shape = (batch, seq_len, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attention(cfg: ModelConfig, p, x, cache, pos, *, layer):
    """x: (B, 1, d). cache: {"k","v"} (B, S, Hkv, hd). pos: scalar int32 —
    index at which the new token is written; attends to [0, pos].

    Sliding-window layers attend only to the last ``window`` positions via
    a static-size dynamic slice (O(window) instead of O(S))."""
    b = x.shape[0]
    s_cache = cache["k"].shape[1]
    q = _split_heads(x @ p["wq"], cfg.num_heads, cfg.head_dim)
    k = _split_heads(x @ p["wk"], cfg.num_kv_heads, cfg.head_dim)
    v = _split_heads(x @ p["wv"], cfg.num_kv_heads, cfg.head_dim)

    if cfg.pos_emb == "rope":
        cos, sin = rope_freqs(cfg, pos[None] if pos.ndim == 0 else pos)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos, 1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos, 1)
    new_cache = {"k": ck, "v": cv}

    window = cfg.sliding_window if layer.mixer == "attn_local" else 0
    scale = 1.0 / np.sqrt(cfg.head_dim)
    if window and window < s_cache:
        start = jnp.clip(pos - window + 1, 0, s_cache - window)
        ks = jax.lax.dynamic_slice_in_dim(ck, start, window, 1)
        vs = jax.lax.dynamic_slice_in_dim(cv, start, window, 1)
        kpos = start + jnp.arange(window)
    else:
        ks, vs = ck, cv
        kpos = jnp.arange(s_cache)
    mask = (kpos <= pos)[None, None, None, :]  # (1,1,1,Sk)
    out = _sdpa_xla(q, ks, vs, mask, scale)
    return out.reshape(b, 1, cfg.q_dim) @ p["wo"], new_cache


def decode_cross_attention(cfg: ModelConfig, p, x, cache):
    """Cross-attn at decode time: the memory K/V are precomputed at
    prefill and stored in ``cache`` as {"k","v"}: (B, Sm, Hkv, hd)."""
    b = x.shape[0]
    q = _split_heads(x @ p["wq"], cfg.num_heads, cfg.head_dim)
    sm = cache["k"].shape[1]
    mask = jnp.ones((1, 1, 1, sm), bool)
    out = _sdpa_xla(q, cache["k"], cache["v"], mask, 1.0 / np.sqrt(cfg.head_dim))
    return out.reshape(b, 1, cfg.q_dim) @ p["wo"]


def cross_cache_from_memory(cfg: ModelConfig, p, memory):
    """Precompute cross-attention K/V from encoder/vision memory."""
    k = _split_heads(memory @ p["wk"], cfg.num_kv_heads, cfg.head_dim)
    v = _split_heads(memory @ p["wv"], cfg.num_kv_heads, cfg.head_dim)
    return {"k": k, "v": v}

"""Shared building blocks: norms, activations, MLPs, embeddings, RoPE."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init (what llama/gemma use in practice)."""
    fan_in = shape[in_axis] if in_axis is not None else 1
    std = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: int | None = None):
    d = d or cfg.d_model
    p = {"scale": jnp.zeros((d,), cdtype(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), cdtype(cfg))
    return p


def apply_norm(cfg: ModelConfig, p, x):
    """RMSNorm / LayerNorm with fp32 statistics, (1+scale) gemma-style."""
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        xf = xf - jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + 1e-6)
    out = xf * (1.0 + p["scale"].astype(jnp.float32))
    if cfg.norm == "layernorm":
        out = out + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Activations / MLP
# --------------------------------------------------------------------------

def activate(cfg: ModelConfig, x):
    if cfg.act == "silu":
        return jax.nn.silu(x)
    if cfg.act == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if cfg.act == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(cfg.act)


def init_mlp(cfg: ModelConfig, key, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(ks[0], (d, f), 0, cdtype(cfg)),
        "w_out": dense_init(ks[1], (f, d), 0, cdtype(cfg)),
    }
    if cfg.gated_mlp:
        p["w_gate"] = dense_init(ks[2], (d, f), 0, cdtype(cfg))
    return p


def apply_mlp(cfg: ModelConfig, p, x):
    h = x @ p["w_in"]
    if cfg.gated_mlp:
        h = activate(cfg, x @ p["w_gate"]) * h
    else:
        h = activate(cfg, h)
    return h @ p["w_out"]


# --------------------------------------------------------------------------
# Embedding / unembedding (padded vocab, see ModelConfig.padded_vocab)
# --------------------------------------------------------------------------

def init_embed(cfg: ModelConfig, key):
    ks = jax.random.split(key, 3)
    p = {"tok": dense_init(ks[0], (cfg.padded_vocab, cfg.d_model), 1, cdtype(cfg))}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], (cfg.d_model, cfg.padded_vocab), 0, cdtype(cfg))
    if cfg.pos_emb == "learned":
        p["pos"] = dense_init(ks[2], (cfg.max_seq_len, cfg.d_model), 1, cdtype(cfg))
    return p


def embed(cfg: ModelConfig, p, tokens, pos_offset=0):
    x = jnp.take(p["tok"], tokens, axis=0)
    if cfg.family != "ssm":  # gemma-style sqrt(d) scaling for attn models
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if cfg.pos_emb == "learned":
        s = tokens.shape[-1]
        idx = pos_offset + jnp.arange(s)
        x = x + jnp.take(p["pos"], idx, axis=0)
    return x


def unembed(cfg: ModelConfig, p, x):
    if cfg.tie_embeddings:
        logits = x @ p["tok"].T
    else:
        logits = x @ p["unembed"]
    logits = logits.astype(jnp.float32)
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    # mask padded vocab rows so they can never win a softmax/argmax
    pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
    return jnp.where(pad_mask, -1e9, logits)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(cfg: ModelConfig, positions):
    """positions: (...,) int32 -> cos/sin of shape (..., head_dim//2)."""
    half = cfg.head_dim // 2
    inv = 1.0 / (cfg.rope_theta ** (np.arange(0, half) / half))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (B?, S, hd//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    # broadcast (..., S, 1, half) over heads
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)

"""LeNet5-like CNN — the paper's §3.2 non-convex experiment model.

conv 32@5x5 -> relu -> maxpool/2 -> conv 64@5x5 -> relu -> maxpool/2
-> fc(hidden) -> relu -> fc(classes) -> softmax cross-entropy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper import CNNConfig


def init_cnn(cfg: CNNConfig, key):
    ks = jax.random.split(key, 4)
    c1, c2 = cfg.conv_channels
    k = cfg.kernel_size
    # 'SAME' convs + two stride-2 pools
    feat = (cfg.image_size // 4) ** 2 * c2

    def glorot(key, shape, fan_in):
        return jax.random.normal(key, shape) * np.sqrt(2.0 / fan_in)

    return {
        "conv1": {"w": glorot(ks[0], (k, k, cfg.in_channels, c1), k * k * cfg.in_channels),
                  "b": jnp.zeros((c1,))},
        "conv2": {"w": glorot(ks[1], (k, k, c1, c2), k * k * c1),
                  "b": jnp.zeros((c2,))},
        "fc1": {"w": glorot(ks[2], (feat, cfg.fc_hidden), feat),
                "b": jnp.zeros((cfg.fc_hidden,))},
        "fc2": {"w": glorot(ks[3], (cfg.fc_hidden, cfg.num_classes), cfg.fc_hidden),
                "b": jnp.zeros((cfg.num_classes,))},
    }


def _conv(x, p):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def cnn_forward(cfg: CNNConfig, params, images):
    """images: (B, H, W, C) float32 -> logits (B, classes)."""
    x = _maxpool2(jax.nn.relu(_conv(images, params["conv1"])))
    x = _maxpool2(jax.nn.relu(_conv(x, params["conv2"])))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


def cnn_loss(cfg: CNNConfig, params, batch):
    logits = cnn_forward(cfg, params, batch["images"])
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)
    return jnp.mean(nll)


def cnn_error(cfg: CNNConfig, params, batch):
    logits = cnn_forward(cfg, params, batch["images"])
    return jnp.mean((jnp.argmax(logits, -1) != batch["labels"]).astype(jnp.float32))

"""Mixture-of-Experts FFN: top-k router with capacity-bounded GShard-style
einsum dispatch (TPU-native — dispatch/combine are MXU matmuls and the
expert dimension shards cleanly for expert parallelism; see DESIGN.md).

Includes the standard load-balance auxiliary loss (Shazeer/GShard) and
router z-loss; both are returned so the training loop can add them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.models.layers import activate, cdtype, dense_init


def init_moe(cfg: ModelConfig, key):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), 0, jnp.float32),
        "w_in": dense_init(ks[1], (e, d, f), 1, cdtype(cfg)),
        "w_out": dense_init(ks[2], (e, f, d), 1, cdtype(cfg)),
    }
    if cfg.gated_mlp:
        p["w_gate"] = dense_init(ks[3], (e, d, f), 1, cdtype(cfg))
    if cfg.shared_expert:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(cfg, ks[4], d_ff=cfg.moe_d_ff)
    return p


def _capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(np.ceil(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts))
    return max(4, -(-c // 4) * 4)  # pad to multiple of 4


def _dispatch_combine(cfg: ModelConfig, probs, cap: int):
    """Top-k combine weights with per-expert capacity over the leading
    token axis. probs: (T,E) fp32 -> combine (T,E,C) fp32."""
    t, e = probs.shape
    k = cfg.top_k
    gate_vals, gate_idx = jax.lax.top_k(probs, k)           # (T,k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)
    combine = jnp.zeros((t, e, cap), jnp.float32)
    offset = jnp.zeros((e,), jnp.float32)  # slots used by earlier k-slots
    for slot in range(k):
        onehot = jax.nn.one_hot(gate_idx[:, slot], e, dtype=jnp.float32)
        # position of each token within its expert's buffer
        pos = jnp.cumsum(onehot, axis=0) - 1.0 + offset[None, :]
        offset = offset + jnp.sum(onehot, axis=0)
        keep = (pos < cap) & (onehot > 0)                   # drop over-capacity
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
        pos_oh = pos_oh * keep[..., None]                   # (T,E,C)
        combine = combine + gate_vals[:, slot, None, None] * pos_oh
    return combine


def _expert_ffn(cfg: ModelConfig, p, combine, xt):
    """combine: (T,E,C); xt: (T,d). GShard dispatch/compute/combine."""
    dispatch = (combine > 0).astype(xt.dtype)               # (T,E,C)
    xe = jnp.einsum("tec,td->ecd", dispatch, xt)            # (E,C,d)
    h = jnp.einsum("ecd,edf->ecf", xe, p["w_in"])
    if cfg.gated_mlp:
        g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
        h = activate(cfg, g) * h
    else:
        h = activate(cfg, h)
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_out"])          # (E,C,d)
    return jnp.einsum("tec,ecd->td", combine.astype(xt.dtype), ye)


def apply_moe(cfg: ModelConfig, p, x):
    """x: (B,S,d) -> (y, aux) with aux = {load_balance, router_z}.

    With ``cfg.moe_group_size`` = 0 (baseline) the capacity buffer spans
    all T tokens and the dispatch einsums cost O(T²·k·cf·d/E·E) — fine at
    small T, catastrophic at prefill scale (EXPERIMENTS.md §Perf HC1).
    With group_size G > 0 tokens are routed in independent groups of G
    (GShard's design): dispatch cost becomes O(T·G·k·cf·d), linear in T."""
    b, s, d = x.shape
    e = cfg.num_experts
    t = b * s
    xt = x.reshape(t, d)

    logits = xt.astype(jnp.float32) @ p["router"]          # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)

    # --- aux losses (computed on full probs)
    density = jnp.mean(probs, axis=0)                       # (E,)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, e, dtype=jnp.float32), axis=0)
    load_balance = e * jnp.sum(density * frac)
    router_z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    g = cfg.moe_group_size
    if g and g < t:
        t_pad = -(-t // g) * g
        if t_pad != t:  # pad with zero tokens (router sends them anywhere;
            xt_p = jnp.pad(xt, ((0, t_pad - t), (0, 0)))    # zero x -> zero y)
            probs_p = jnp.pad(probs, ((0, t_pad - t), (0, 0)))
        else:
            xt_p, probs_p = xt, probs
        cap = _capacity(cfg, g)
        xg = xt_p.reshape(t_pad // g, g, d)
        pg = probs_p.reshape(t_pad // g, g, e)

        def per_group(pp, xx):
            return _expert_ffn(cfg, p, _dispatch_combine(cfg, pp, cap), xx)

        y = jax.vmap(per_group)(pg, xg).reshape(t_pad, d)[:t]
    else:
        cap = _capacity(cfg, t)
        y = _expert_ffn(cfg, p, _dispatch_combine(cfg, probs, cap), xt)

    if cfg.shared_expert:
        from repro.models.layers import apply_mlp
        y = y + apply_mlp(cfg, p["shared"], xt)

    aux = {"load_balance": load_balance, "router_z": router_z}
    return y.reshape(b, s, d), aux

"""RWKV6 "Finch" time-mix + channel-mix (arXiv:2404.05892).

Per head (head_dim n), with data-dependent per-channel decay w_t:
  S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] * v_t[j]
  y_t[j]   = sum_i r_t[i] * (S_{t-1}[i,j] + u[i] * k_t[i] * v_t[j])

Training path is CHUNKED (TPU adaptation, see DESIGN.md): the sequence is
split into chunks of size C; within a chunk the output is computed in
quadratic "decay attention" form with *relative* decays (numerically
bounded); chunk boundary states are combined with a log-depth
jax.lax.associative_scan (no while loop => correct XLA cost analysis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models.layers import cdtype, dense_init

# Chunk size / decay floor are coupled: every intra-chunk exponent is
# bounded by (CHUNK-1) * |log_w|_max = 15 * 5 = 75 < log(fp32 max) ~ 88,
# so the quadratic decay-attention form never overflows in fp32.
CHUNK = 16
LOG_W_MIN = -5.0


def init_rwkv(cfg: ModelConfig, key):
    d = cfg.d_model
    ks = jax.random.split(key, 10)
    lora = max(32, d // 64)
    return {
        # token-shift mix coefficients (static lerp part of ddlerp)
        "mu_r": jnp.full((d,), 0.5, cdtype(cfg)),
        "mu_k": jnp.full((d,), 0.5, cdtype(cfg)),
        "mu_v": jnp.full((d,), 0.5, cdtype(cfg)),
        "mu_w": jnp.full((d,), 0.5, cdtype(cfg)),
        "mu_g": jnp.full((d,), 0.5, cdtype(cfg)),
        "wr": dense_init(ks[0], (d, d), 0, cdtype(cfg)),
        "wk": dense_init(ks[1], (d, d), 0, cdtype(cfg)),
        "wv": dense_init(ks[2], (d, d), 0, cdtype(cfg)),
        "wg": dense_init(ks[3], (d, d), 0, cdtype(cfg)),
        "wo": dense_init(ks[4], (d, d), 0, cdtype(cfg)),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((d,), -6.0, jnp.float32) +
              8.0 * (jnp.arange(d) / max(d - 1, 1)).astype(jnp.float32) ** 3,
        "wA": dense_init(ks[5], (d, lora), 0, cdtype(cfg)),
        "wB": dense_init(ks[6], (lora, d), 0, cdtype(cfg)),
        "u": dense_init(ks[7], (d,), None, jnp.float32),  # per-channel bonus
        "ln_out": jnp.ones((d,), jnp.float32),            # group-norm scale
    }


def _token_shift(x, mu, prev=None):
    """lerp(x_t, x_{t-1}, mu); prev: (B,1,d) last token of previous step."""
    if prev is None:
        prev_x = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev_x = jnp.concatenate([prev.astype(x.dtype), x[:, :-1]], axis=1)
    return x + (prev_x - x) * mu


def _project(cfg, p, x, prev=None):
    """Returns r,k,v,g: (B,S,H,n); log_w: (B,S,H,n) fp32 (<0)."""
    n = cfg.rwkv_head_dim
    b, s, d = x.shape
    h = d // n
    r = _token_shift(x, p["mu_r"], prev) @ p["wr"]
    k = _token_shift(x, p["mu_k"], prev) @ p["wk"]
    v = _token_shift(x, p["mu_v"], prev) @ p["wv"]
    g = jax.nn.silu(_token_shift(x, p["mu_g"], prev) @ p["wg"])
    xw = _token_shift(x, p["mu_w"], prev)
    dw = jnp.tanh(xw @ p["wA"]) @ p["wB"]
    log_w = -jnp.exp(jnp.clip(p["w0"] + dw.astype(jnp.float32), -20.0, 8.0))
    log_w = jnp.clip(log_w, LOG_W_MIN, -1e-5)
    hsplit = lambda t: t.reshape(b, s, h, n)
    return hsplit(r), hsplit(k), hsplit(v), g, hsplit(log_w)


def _chunk_scan(A, S):
    """Combine per-chunk (decay, state) across chunks.
    A: (B,H,N,n) total per-channel decay of each chunk (key dim)
    S: (B,H,N,n,n) chunk-local state contribution.
    Returns prefix states BEFORE each chunk (exclusive scan)."""
    def combine(x, y):
        a1, s1 = x
        a2, s2 = y
        return a1 * a2, a2[..., None] * s1 + s2
    a, s = jax.lax.associative_scan(combine, (A, S), axis=2)
    # exclusive: state entering chunk c = scanned state of chunk c-1
    zero = jnp.zeros_like(s[:, :, :1])
    return jnp.concatenate([zero, s[:, :, :-1]], axis=2)


def rwkv_attention(cfg: ModelConfig, r, k, v, log_w, u, *,
                   return_state=False):
    """Chunked WKV6. r,k,v,log_w: (B,S,H,n) (log_w fp32). u: (n,) or (d,)->
    reshaped per head. Returns (B,S,H,n) fp32."""
    b, s_orig, h, n = r.shape
    c = min(CHUNK, s_orig)
    if s_orig % c:  # pad to a chunk multiple: k=0 adds no state and
        pad = c - s_orig % c  # log_w=0 (decay 1) leaves the state intact
        z = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, z), jnp.pad(k, z), jnp.pad(v, z)
        log_w = jnp.pad(log_w, z, constant_values=0.0)
    s = r.shape[1]
    nchunk = s // c
    u = u.reshape(h, n)

    # (B,H,N,c,n) layout
    def to_chunks(t):
        return t.transpose(0, 2, 1, 3).reshape(b, h, nchunk, c, n)

    r_, k_, v_ = map(to_chunks, (r, k, v))
    lw = to_chunks(log_w.astype(jnp.float32))
    r_, k_, v_ = r_.astype(jnp.float32), k_.astype(jnp.float32), v_.astype(jnp.float32)

    # cumulative decay within chunk: L[t] = sum_{u<=t} log_w[u]
    L = jnp.cumsum(lw, axis=3)                       # (B,H,N,c,n)
    Ltot = L[:, :, :, -1]                            # (B,H,N,n)

    # ---- intra-chunk: y_t += sum_{s<t} r_t ⊙ exp(L_{t-1}-L_s) k_s · v_s
    # scores[t,s] = sum_i r_t[i] exp(L[t-1,i] - L[s,i]) k_s[i]
    rd = r_ * jnp.exp(L - lw)                        # r_t e^{L_{t-1}}
    kd = k_ * jnp.exp(-L)                            # k_s e^{-L_s}
    scores = jnp.einsum("bhnti,bhnsi->bhnts", rd, kd)
    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
    scores = jnp.where(tri, scores, 0.0)
    # diagonal bonus: u ⊙ k_t
    diag = jnp.einsum("bhnti,bhnti->bhnt", r_ * u[None, :, None, None], k_)
    y = jnp.einsum("bhnts,bhnsj->bhntj", scores, v_) + diag[..., None] * v_

    # ---- inter-chunk: contribution of the state entering the chunk
    # chunk-local state: S_c[i,j] = sum_t exp(Ltot - L_t)[i] k_t[i] v_t[j]
    kS = k_ * jnp.exp(Ltot[:, :, :, None] - L)
    S_local = jnp.einsum("bhnti,bhntj->bhnij", kS, v_)
    S_in = _chunk_scan(jnp.exp(Ltot), S_local)       # (B,H,N,n,n)
    y = y + jnp.einsum("bhnti,bhnij->bhntj", rd, S_in)

    out = y.reshape(b, h, s, n).transpose(0, 2, 1, 3)[:, :s_orig]
    if return_state:
        S_final = (jnp.exp(Ltot[:, :, -1])[..., None] * S_in[:, :, -1]
                   + S_local[:, :, -1])              # (B,H,n,n)
        return out, S_final
    return out


def _group_norm(y, scale, h, n, eps=64e-5):
    """RWKV's per-head group norm on the wkv output."""
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    yn = (y - mu) * jax.lax.rsqrt(var + eps)
    return yn.reshape(y.shape[:2] + (h * n,)) * scale


def apply_rwkv(cfg: ModelConfig, p, x, *, impl="xla", return_state=False):
    """Time-mix layer. x: (B,S,d) -> (B,S,d) (+ decode state)."""
    b, s, d = x.shape
    n = cfg.rwkv_head_dim
    h = d // n
    r, k, v, g, log_w = _project(cfg, p, x)
    state = None
    if impl == "pallas" and not return_state:
        from repro.kernels import ops
        y = ops.rwkv6_scan(r, k, v, log_w, p["u"])
    elif return_state:
        y, state = rwkv_attention(cfg, r, k, v, log_w, p["u"],
                                  return_state=True)
    else:
        y = rwkv_attention(cfg, r, k, v, log_w, p["u"])
    y = _group_norm(y, p["ln_out"], h, n).astype(x.dtype)
    out = (y * g) @ p["wo"]
    if return_state:
        return out, {"wkv": state, "shift_t": x[:, -1:]}
    return out


# ---- channel mix ----------------------------------------------------------

def init_rwkv_cmix(cfg: ModelConfig, key):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 2)
    return {
        "mu_k": jnp.full((d,), 0.5, cdtype(cfg)),
        "wk": dense_init(ks[0], (d, f), 0, cdtype(cfg)),
        "wv": dense_init(ks[1], (f, d), 0, cdtype(cfg)),
    }


def apply_rwkv_cmix(cfg: ModelConfig, p, x, prev=None):
    xk = _token_shift(x, p["mu_k"], prev)
    hdn = jax.nn.relu(xk @ p["wk"])
    return (hdn * hdn) @ p["wv"]


# ---- decode (single token) ------------------------------------------------

def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    n = cfg.rwkv_head_dim
    h = d // n
    return {
        "wkv": jnp.zeros((batch, h, n, n), jnp.float32),
        "shift_t": jnp.zeros((batch, 1, d), dtype),
        "shift_c": jnp.zeros((batch, 1, d), dtype),
    }


def decode_rwkv(cfg: ModelConfig, p, x, cache):
    """x: (B,1,d). One recurrence step."""
    b, _, d = x.shape
    n = cfg.rwkv_head_dim
    h = d // n
    r, k, v, g, log_w = _project(cfg, p, x, prev=cache["shift_t"])
    r, k, v = (t[:, 0].astype(jnp.float32) for t in (r, k, v))  # (B,H,n)
    w = jnp.exp(log_w[:, 0])
    u = p["u"].reshape(h, n)
    S = cache["wkv"]
    kv = k[..., None] * v[..., None, :]              # (B,H,n,n)
    y = jnp.einsum("bhi,bhij->bhj", r, S + u[None, :, :, None] * kv)
    S = w[..., None] * S + kv
    y = _group_norm(y[:, None], p["ln_out"], h, n).astype(x.dtype)
    out = (y * g) @ p["wo"]
    return out, {"wkv": S, "shift_t": x, "shift_c": cache["shift_c"]}


def decode_rwkv_cmix(cfg: ModelConfig, p, x, cache):
    out = apply_rwkv_cmix(cfg, p, x, prev=cache["shift_c"])
    cache = dict(cache, shift_c=x)
    return out, cache

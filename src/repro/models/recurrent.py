"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block structure (TPU-adapted, see DESIGN.md):
  x -> [gate branch: linear -> GeLU] ----------------\
  x -> [linear -> causal conv1d(width 4) -> RG-LRU] --⊙--> linear -> out

RG-LRU recurrence (all elementwise over rnn_width channels):
  r_t = sigmoid(block_diag(W_a) u_t)          recurrence gate
  i_t = sigmoid(block_diag(W_i) u_t)          input gate
  a_t = exp(-c * softplus(Lambda) * r_t)      c = 8
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Training uses jax.lax.associative_scan (log-depth, fully visible to XLA
cost analysis — no while loop); decode carries (h, conv buffer) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models.layers import cdtype, dense_init

_C = 8.0


def init_rglru(cfg: ModelConfig, key):
    d, w, h = cfg.d_model, cfg.rnn_width or cfg.d_model, cfg.num_heads
    bw = w // h  # block size for block-diagonal gates
    ks = jax.random.split(key, 7)
    # Lambda init so that a ~ Uniform(0.9, 0.999) at r=1 (Griffin appendix)
    u = jax.random.uniform(ks[5], (w,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log u / c)
    return {
        "w_gate": dense_init(ks[0], (d, w), 0, cdtype(cfg)),
        "w_x": dense_init(ks[1], (d, w), 0, cdtype(cfg)),
        "conv": dense_init(ks[2], (cfg.conv_width, w), 0, cdtype(cfg)),
        "conv_b": jnp.zeros((w,), cdtype(cfg)),
        "wa": dense_init(ks[3], (h, bw, bw), 1, cdtype(cfg)),
        "wi": dense_init(ks[4], (h, bw, bw), 1, cdtype(cfg)),
        "lam": lam.astype(jnp.float32),
        "w_out": dense_init(ks[6], (w, d), 0, cdtype(cfg)),
    }


def _block_gate(p_w, u, h):
    """Block-diagonal projection: u (B,S,W) -> (B,S,W) with H blocks."""
    b, s, w = u.shape
    ub = u.reshape(b, s, h, w // h)
    return jnp.einsum("bshi,hij->bshj", ub, p_w).reshape(b, s, w)


def _causal_conv(p, u, prev=None):
    """Per-channel causal conv1d, width cw. u: (B,S,W).
    prev: (B, cw-1, W) history for decode; None => zero left-pad."""
    cw = p["conv"].shape[0]
    if prev is None:
        prev = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    up = jnp.concatenate([prev.astype(u.dtype), u], axis=1)
    out = sum(up[:, i:i + u.shape[1]] * p["conv"][i] for i in range(cw))
    return out + p["conv_b"], up[:, -(cw - 1):]


def _gates(cfg, p, u):
    h = cfg.num_heads
    r = jax.nn.sigmoid(_block_gate(p["wa"], u, h).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_gate(p["wi"], u, h).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r  # (B,S,W), <= 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0))
    b = b * i * u.astype(jnp.float32)
    return a, b


def rglru_scan(a, b):
    """h_t = a_t h_{t-1} + b_t via associative scan over axis 1."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def apply_rglru(cfg: ModelConfig, p, x, *, impl="xla", return_state=False):
    """x: (B,S,d) -> (B,S,d) (+ decode state when ``return_state``)."""
    gate = jax.nn.gelu(x @ p["w_gate"], approximate=True)
    u = x @ p["w_x"]
    u, conv_tail = _causal_conv(p, u)
    a, b = _gates(cfg, p, u)
    if impl == "pallas":
        from repro.kernels import ops
        h = ops.rglru_scan(a, b)
    else:
        h = rglru_scan(a, b)
    out = (h.astype(x.dtype) * gate) @ p["w_out"]
    if return_state:
        return out, {"h": h[:, -1].astype(jnp.float32), "conv": conv_tail}
    return out


# ---- decode (single token, carried state) --------------------------------

def init_rglru_cache(cfg: ModelConfig, batch: int, dtype):
    w = cfg.rnn_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }


def decode_rglru(cfg: ModelConfig, p, x, cache):
    """x: (B,1,d); cache {"h": (B,W) fp32, "conv": (B,cw-1,W)}."""
    gate = jax.nn.gelu(x @ p["w_gate"], approximate=True)
    u = x @ p["w_x"]
    u, conv_state = _causal_conv(p, u, prev=cache["conv"])
    a, b = _gates(cfg, p, u)          # (B,1,W)
    h = a[:, 0] * cache["h"] + b[:, 0]
    out = (h[:, None].astype(x.dtype) * gate) @ p["w_out"]
    return out, {"h": h, "conv": conv_state}

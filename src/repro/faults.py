"""Deterministic fault injection: worker failure as a scenario axis.

The paper's claim — averaging helps in proportion to the gradient-
variance envelope — is most interesting exactly where distributed
training is ugliest: workers die, straggle, and rejoin mid-run. This
module makes those faults a first-class, bit-reproducible scenario axis
instead of an ops accident:

* a :class:`FaultPlan` scripts crash / rejoin events (and membership
  changes M -> M': :meth:`FaultPlan.shrink` / :meth:`FaultPlan.grow`,
  which are simultaneous crashes / rejoins — ``repro.elastic`` applies
  the same change to a live ``EngineState`` by actually repacking the
  plane), an optional stochastic per-step straggle probability, and
  **solo windows**: steps during which a row trains (its local update
  applies) but is masked out of every averaging / mixing event, the
  loss and the dispersion. ``rejoin_curriculum=c`` derives a c-step
  solo window after every scripted rejoin, so a warm-started worker
  re-converges alone before its iterate re-enters the mix;
* the plan compiles to a pure per-step transition on a small
  :class:`FaultState` ``(alive, staleness)`` carry riding the engine
  scan exactly like ``SchedState`` — scripted liveness is a pure
  function of ``step``, stochastic straggles are a pure function of
  ``fold_in(dec_key, salt, step, row)`` — so every engine path, phase
  blocking, shard layout and checkpoint-resume replays the identical
  fault stream;
* degradation is graceful by construction: dead rows are masked out of
  every averaging / mixing event (:func:`degraded_matrix` renormalizes
  a doubly-stochastic ``W`` over the alive workers, Metropolis-style),
  stragglers skip their local update but still receive the mix, and
  rejoining workers warm-start from the current alive average with
  optimizer planes and error-feedback residuals zeroed.

A trivial plan (no events, zero straggle probability) is lowered away
by the engine entirely, so an all-alive ``FaultPlan`` is bit-identical
to the no-fault engine by construction.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

#: fold_in salt for the straggle uniforms ("str"), keeping the stream
#: independent of the gossip-partner (0x676F73) and stochastic-rounding
#: (0x656E63) streams that hang off the same dec_key
_STRAGGLE_SALT = 0x737472

EVENT_KINDS = ("crash", "rejoin")

_EVENT_RE = re.compile(r"^\s*(\w+)\s*:\s*m\s*=\s*(\d+)\s*@\s*t\s*=\s*(\d+)\s*$")


class FaultEvent(NamedTuple):
    """One scripted liveness change: ``worker`` crashes or rejoins at
    the local step ``step`` (1-based, matching ``EngineState.step``).
    The event takes effect DURING step ``step``: a worker crashed at
    ``t`` contributes no update and no averaging weight from step ``t``
    on; a worker rejoined at ``t`` is warm-started and participates
    from step ``t`` on."""
    kind: str
    worker: int
    step: int


class FaultState(NamedTuple):
    """Per-worker fault carry riding the engine scan (like SchedState).

    alive:     (M,) float32 — 1.0 for rows participating in averaging.
               Scripted liveness is a pure function of the step, but the
               carried copy is what rejoin detection diffs against, so
               checkpoint-resume replays warm-starts exactly once.
    staleness: (M,) int32 — steps since the row last applied a local
               update (dead and straggling rows age; diagnostics and
               schedules can consume it).
    """
    alive: Any
    staleness: Any


def init_fault_state(num_workers: int) -> FaultState:
    return FaultState(jnp.ones((num_workers,), jnp.float32),
                      jnp.zeros((num_workers,), jnp.int32))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic fault script for an ``num_workers``-row plane.

    events:        scripted :class:`FaultEvent` crashes / rejoins,
                   validated (rows in range, steps >= 1, per-worker
                   crash/rejoin alternation, at least one worker alive
                   at every point).
    straggle_prob: per-step probability that an alive worker skips its
                   local update (it still receives the averaging /
                   mixing event). Drawn per (step, row) from the salted
                   ``dec_key`` stream — identical across engine paths,
                   shards and resume.
    solo:          ``(worker, start, stop)`` windows — during steps
                   ``start <= t < stop`` the row keeps updating but is
                   excluded from averaging / mixing events, the loss
                   and the dispersion (a curriculum: train alone, then
                   re-enter the mix). ``repro.elastic`` uses these for
                   grown rows.
    rejoin_curriculum: c > 0 derives a ``(worker, t, t + c)`` solo
                   window after every scripted rejoin at ``t``, so the
                   warm-started worker runs c solo steps before its
                   iterate re-enters the mix.
    """
    num_workers: int
    events: tuple = ()
    straggle_prob: float = 0.0
    solo: tuple = ()
    rejoin_curriculum: int = 0

    def __post_init__(self):
        if self.num_workers < 1:
            raise ValueError(f"num_workers must be >= 1, got {self.num_workers}")
        if not 0.0 <= self.straggle_prob <= 1.0:
            raise ValueError(
                f"straggle_prob must be in [0, 1], got {self.straggle_prob}")
        events = tuple(FaultEvent(*e) for e in self.events)
        for ev in events:
            if ev.kind not in EVENT_KINDS:
                raise ValueError(
                    f"unknown fault kind {ev.kind!r} (expected one of "
                    f"{EVENT_KINDS})")
            if not 0 <= ev.worker < self.num_workers:
                raise ValueError(
                    f"fault event row m={ev.worker} out of range for "
                    f"{self.num_workers} workers")
            if ev.step < 1:
                raise ValueError(
                    f"fault event step t={ev.step} must be >= 1")
        events = tuple(sorted(events, key=lambda e: (e.step, e.worker)))
        seen = set()
        for ev in events:
            if (ev.worker, ev.step) in seen:
                raise ValueError(
                    f"multiple fault events for worker {ev.worker} at "
                    f"step {ev.step} are ambiguous")
            seen.add((ev.worker, ev.step))
        # per-worker crash/rejoin alternation + never-all-dead
        alive = [True] * self.num_workers
        for ev in events:
            if ev.kind == "crash":
                if not alive[ev.worker]:
                    raise ValueError(
                        f"worker {ev.worker} crashes at step {ev.step} "
                        "but is already dead (crash requires an alive "
                        "worker)")
                alive[ev.worker] = False
            else:
                if alive[ev.worker]:
                    raise ValueError(
                        f"worker {ev.worker} rejoins at step {ev.step} "
                        "without a prior crash (rejoin requires a dead "
                        "worker)")
                alive[ev.worker] = True
            if not any(alive):
                raise ValueError(
                    f"all {self.num_workers} workers are dead from step "
                    f"{ev.step} — at least one must stay alive")
        object.__setattr__(self, "events", events)
        if self.rejoin_curriculum < 0:
            raise ValueError(
                f"rejoin_curriculum must be >= 0, got "
                f"{self.rejoin_curriculum}")
        solo = tuple(tuple(int(v) for v in w) for w in self.solo)
        for w in solo:
            if len(w) != 3:
                raise ValueError(
                    f"solo window {w!r} must be (worker, start, stop)")
            worker, start, stop = w
            if not 0 <= worker < self.num_workers:
                raise ValueError(
                    f"solo window row m={worker} out of range for "
                    f"{self.num_workers} workers")
            if not 1 <= start < stop:
                raise ValueError(
                    f"solo window {w!r} needs 1 <= start < stop")
        object.__setattr__(self, "solo", solo)
        # curriculum windows derive from the scripted rejoins; explicit
        # solo windows come from the caller (repro.elastic adds them
        # for grown rows). _solo_windows is what the streams consume.
        derived = tuple((ev.worker, ev.step, ev.step + self.rejoin_curriculum)
                        for ev in events
                        if ev.kind == "rejoin" and self.rejoin_curriculum > 0)
        windows = solo + tuple(w for w in derived if w not in solo)
        object.__setattr__(self, "_solo_windows", windows)
        if windows:
            # at every liveness/solo breakpoint, some row must remain in
            # the mix (alive and not solo) — events and dispersion are
            # normalized by the mix count
            breaks = sorted({1} | {ev.step for ev in events}
                            | {t for _, a, b in windows for t in (a, b)})
            for t in breaks:
                alive = [True] * self.num_workers
                for ev in events:
                    if ev.step <= t:
                        alive[ev.worker] = ev.kind == "rejoin"
                in_solo = [any(w == i and a <= t < b
                               for i, a, b in windows)
                           for w in range(self.num_workers)]
                if not any(a and not s for a, s in zip(alive, in_solo)):
                    raise ValueError(
                        f"no worker left in the mix at step {t}: every "
                        "alive row is inside a solo window — at least "
                        "one must keep averaging")

    # -- static structure ------------------------------------------------

    @property
    def is_trivial(self) -> bool:
        """True when the plan can be lowered away entirely (the engine
        then runs its unmodified no-fault paths, bit-identically)."""
        return (not self.events and self.straggle_prob == 0.0
                and not self._solo_windows)

    @property
    def has_rejoin(self) -> bool:
        return any(ev.kind == "rejoin" for ev in self.events)

    @classmethod
    def parse(cls, text: str, num_workers: int, *,
              straggle_prob: float = 0.0, rejoin_after: int = 0,
              rejoin_curriculum: int = 0) -> "FaultPlan":
        """Parse a CLI fault script: comma-separated
        ``kind:m=<row>@t=<step>`` terms, e.g.
        ``"crash:m=3@t=100,rejoin:m=3@t=200"``. ``rejoin_after > 0``
        auto-appends a rejoin N steps after every crash that has no
        later scripted event for the same worker; ``rejoin_curriculum``
        passes through to the plan (c solo steps after every rejoin)."""
        events = []
        for part in text.split(","):
            if not part.strip():
                continue
            match = _EVENT_RE.match(part)
            if not match:
                raise ValueError(
                    f"cannot parse fault event {part.strip()!r} "
                    "(expected kind:m=<row>@t=<step>, e.g. "
                    "crash:m=3@t=100)")
            kind, worker, step = match.groups()
            if kind not in EVENT_KINDS:
                raise ValueError(
                    f"unknown fault kind {kind!r} in {part.strip()!r} "
                    f"(expected one of {EVENT_KINDS})")
            events.append(FaultEvent(kind, int(worker), int(step)))
        if rejoin_after > 0:
            for ev in list(events):
                if ev.kind != "crash":
                    continue
                later = [e for e in events
                         if e.worker == ev.worker and e.step > ev.step]
                if not later:
                    events.append(FaultEvent("rejoin", ev.worker,
                                             ev.step + rejoin_after))
        return cls(num_workers, tuple(events), straggle_prob,
                   rejoin_curriculum=rejoin_curriculum)

    @classmethod
    def shrink(cls, num_workers: int, new_num_workers: int, step: int,
               **kw) -> "FaultPlan":
        """Scripted membership change M -> M' at ``step``: rows
        ``new_num_workers..num_workers-1`` crash simultaneously."""
        if not 1 <= new_num_workers <= num_workers:
            raise ValueError(
                f"cannot shrink {num_workers} workers to {new_num_workers}")
        events = tuple(FaultEvent("crash", m, step)
                       for m in range(new_num_workers, num_workers))
        return cls(num_workers, events, **kw)

    @classmethod
    def grow(cls, num_workers: int, new_num_workers: int, step: int,
             **kw) -> "FaultPlan":
        """Scripted membership change M -> M' (M' >= M) at ``step``: a
        plan for the GROWN M'-row plane whose rows
        ``num_workers..new_num_workers-1`` are dead from step 1 and
        rejoin (warm-started from the alive consensus) at ``step``.
        Pass ``rejoin_curriculum=c`` for c solo steps before the new
        rows re-enter the mix. ``repro.elastic`` applies the same
        change to a LIVE ``EngineState`` without padding the plane."""
        if not 1 <= num_workers <= new_num_workers:
            raise ValueError(
                f"cannot grow {num_workers} workers to {new_num_workers}")
        if step < 2:
            raise ValueError(
                f"grow step t={step} must be >= 2 (the joining rows "
                "crash at t=1 and rejoin at t)")
        events = tuple(ev for m in range(num_workers, new_num_workers)
                       for ev in (FaultEvent("crash", m, 1),
                                  FaultEvent("rejoin", m, step)))
        return cls(new_num_workers, events, **kw)

    def events_in(self, t0: int, t1: int) -> tuple:
        """Scripted events with ``t0 < step <= t1``, in script order —
        the host-side enumeration the telemetry ``fault_event``
        records ride (one record per scripted crash/rejoin in the
        phase the driver just consumed)."""
        return tuple(ev for ev in self.events if t0 < ev.step <= t1)

    # -- pure per-step streams -------------------------------------------

    def alive_at(self, step):
        """(M,) f32 liveness at local step ``step`` — a pure function of
        the scripted events, safe under trace and across resume."""
        alive = jnp.ones((self.num_workers,), jnp.float32)
        for ev in self.events:  # sorted by step: later events override
            val = jnp.float32(0.0 if ev.kind == "crash" else 1.0)
            alive = alive.at[ev.worker].set(
                jnp.where(step >= ev.step, val, alive[ev.worker]))
        return alive

    def straggle_mask(self, dec_key, step, rows):
        """(len(rows),) f32 — 1.0 where the row straggles this step.
        Pure function of ``(dec_key, step, row)`` via the salted
        fold_in chain, so every path and shard draws identical masks."""
        rows = jnp.asarray(rows, jnp.int32)
        if self.straggle_prob <= 0.0:
            return jnp.zeros(rows.shape, jnp.float32)
        base = jax.random.fold_in(
            jax.random.fold_in(dec_key, _STRAGGLE_SALT), step)
        u = jax.vmap(lambda r: jax.random.uniform(
            jax.random.fold_in(base, r), (), jnp.float32))(rows)
        return (u < self.straggle_prob).astype(jnp.float32)

    def solo_at(self, step):
        """(M,) f32 — 1.0 where the row is inside a solo window at local
        step ``step`` (explicit windows plus the rejoin-curriculum
        derived ones). Pure function of ``step``, like :meth:`alive_at`."""
        out = jnp.zeros((self.num_workers,), jnp.float32)
        for worker, start, stop in self._solo_windows:
            out = out.at[worker].set(
                jnp.where((step >= start) & (step < stop), 1.0,
                          out[worker]))
        return out

    def mix_at(self, alive, step, *, row0=0, num_rows: int | None = None):
        """Mask ``alive`` down to the mixing cohort at ``step`` —
        alive rows not inside a solo window. With no solo windows this
        returns ``alive`` unchanged (the same array: bit-exact no-op).
        ``alive`` spans the full plane by default; shards pass their
        slice via ``row0``/``num_rows``."""
        if not self._solo_windows:
            return alive
        solo = self.solo_at(step)
        if num_rows is not None and num_rows != self.num_workers:
            solo = jax.lax.dynamic_slice_in_dim(solo, row0, num_rows, 0)
        return alive * (1.0 - solo)

    def disp_scale(self, mix_full, dec_key, step):
        """Fraction of the mixing cohort that applied its local update
        this step — the discount ``straggle_aware`` adaptive schedules
        multiply into the measured dispersion before it feeds their
        EMA/budget (a straggler's frozen iterate lags the mean and
        widens dispersion without carrying gradient-variance signal).
        Pure function of ``(dec_key, step)`` plus the scripted masks,
        so every engine path and every shard computes the identical
        scalar with no collective."""
        rows = jnp.arange(self.num_workers, dtype=jnp.int32)
        straggle = self.straggle_mask(dec_key, step, rows)
        updated = jnp.sum(mix_full * (1.0 - straggle))
        return updated / jnp.maximum(jnp.sum(mix_full), 1.0)

    def transition(self, state: FaultState, step, dec_key, *,
                   row0=0, num_rows: int | None = None):
        """One pure fault-state step for rows ``[row0, row0+num_rows)``
        (the full plane by default; shards pass their slice).

        Returns ``(new_state, mix_full, mix, umask, rejoined)``:
        ``mix_full`` the global (M,) mixing cohort — alive rows not in
        a solo window (every shard computes it locally — mixing
        matrices need all rows), ``mix`` / ``umask`` / ``rejoined`` the
        local-row masks. ``umask`` = alive and not straggling = rows
        that apply their local update this step (solo rows DO update —
        that is the curriculum). Without solo windows the mix masks are
        exactly the alive masks, bitwise. The carried ``new_state``
        keeps the *scripted* liveness, so rejoin detection (and its
        one-time warm start) is independent of curricula.
        """
        m = self.num_workers
        if num_rows is None:
            num_rows = m
        alive_prev = state.alive
        alive_full = self.alive_at(step)
        mix_full = self.mix_at(alive_full, step)
        if num_rows == m and isinstance(row0, int) and row0 == 0:
            alive = alive_full
            mix = mix_full
            rows = jnp.arange(m, dtype=jnp.int32)
        else:
            alive = jax.lax.dynamic_slice_in_dim(alive_full, row0,
                                                 num_rows, 0)
            mix = (alive if mix_full is alive_full else
                   jax.lax.dynamic_slice_in_dim(mix_full, row0,
                                                num_rows, 0))
            rows = jnp.asarray(row0, jnp.int32) + jnp.arange(
                num_rows, dtype=jnp.int32)
        straggle = self.straggle_mask(dec_key, step, rows)
        umask = alive * (1.0 - straggle)
        rejoined = alive * (1.0 - alive_prev)
        staleness = jnp.where(umask > 0, jnp.int32(0), state.staleness + 1)
        return (FaultState(alive, staleness), mix_full, mix, umask,
                rejoined)


# --------------------------------------------------------------------------
# Masked plane primitives (jnp; shared by the kernel refs, the Pallas
# wrappers and the engine's sharded collectives)
# --------------------------------------------------------------------------

def masked_mean(plane, alive):
    """Exact mean over alive rows: (M, P), (M,) -> (P,)."""
    return (jnp.sum(plane * alive[:, None], axis=0) / jnp.sum(alive))


def masked_dispersion(plane, alive):
    """Eq. 4 dispersion restricted to alive rows:
    sum_i alive_i ||w_i - w̄_alive||^2 / n_alive."""
    glob = masked_mean(plane, alive)
    return (jnp.sum(jnp.square(plane - glob[None]) * alive[:, None])
            / jnp.sum(alive))


def masked_group_mean(plane, alive, groups: int):
    """Per-group alive means broadcast back to (M, P); dead groups
    (no alive member) broadcast zeros — callers keep dead rows via
    :func:`select_rows` so those never land in the plane."""
    m, p = plane.shape
    mg = m // groups
    a = alive.reshape(groups, mg)
    sums = jnp.sum(plane.reshape(groups, mg, p) * a[..., None], axis=1)
    cnt = jnp.sum(a, axis=1)
    gm = sums / jnp.maximum(cnt, 1.0)[:, None]
    out = jnp.broadcast_to(gm[:, None], (groups, mg, p))
    return out.reshape(m, p)


def masked_event_matrix(alive, groups: int = 1):
    """The masked (group-)mean event as a doubly-stochastic (M, M)
    matrix: alive rows average the alive members of their group
    (``A[i, j] = a_i a_j / n_g``), dead rows are identity. Lets the
    fused Pallas ``mix`` kernels execute masked mean events as the same
    single ``A @ plane`` pass they already run for gossip mixing
    (equal to the exact-sum refs up to matmul rounding)."""
    a = alive.astype(jnp.float32)
    m = a.shape[0]
    gid = jnp.arange(m) // (m // groups)
    same = (gid[:, None] == gid[None, :]).astype(jnp.float32)
    cnt = jnp.sum(same * a[None, :], axis=1)  # alive count of my group
    A = same * a[:, None] * a[None, :] / jnp.maximum(cnt, 1.0)[:, None]
    return A + jnp.diag(1.0 - a)


def degraded_matrix(W, alive):
    """Renormalize a doubly-stochastic mixing matrix over the alive
    workers: off-diagonal mass to/from dead rows is dropped and folded
    back onto the diagonal (the Metropolis self-weight refill), giving
    identity rows/columns for dead workers and a matrix that is again
    doubly stochastic whenever ``W`` is symmetric (every built-in
    topology is). All-alive returns ``W`` itself, bitwise."""
    a = alive.astype(W.dtype)
    eye = jnp.eye(W.shape[0], dtype=W.dtype)
    off = W * (1.0 - eye) * a[:, None] * a[None, :]
    Wm = off + jnp.diag(1.0 - jnp.sum(off, axis=1))
    return jnp.where(jnp.all(a > 0), W, Wm)


def select_rows(new, old, mask):
    """Row-mask merge: rows with ``mask > 0`` from ``new``, others kept
    from ``old``. Works on (M, ...) arrays."""
    m = mask.reshape((mask.shape[0],) + (1,) * (new.ndim - 1))
    return jnp.where(m > 0, new, old)


def zero_rows(x, mask):
    """Zero the rows with ``mask > 0`` (rejoin resets for optimizer
    planes and error-feedback residuals)."""
    m = mask.reshape((mask.shape[0],) + (1,) * (x.ndim - 1))
    return jnp.where(m > 0, jnp.zeros_like(x), x)


# --------------------------------------------------------------------------
# Pytree twins (the engine's tree path and run_host; per-leaf math and
# reduction order match the plane primitives, so a single-leaf f32 model
# is bitwise identical across paths)
# --------------------------------------------------------------------------

def _row(mask, x):
    return mask.reshape((mask.shape[0],) + (1,) * (jnp.ndim(x) - 1))


def select_rows_tree(new_tree, old_tree, mask):
    return jax.tree.map(
        lambda n, o: jnp.where(_row(mask, n) > 0, n, o), new_tree, old_tree)


def zero_rows_tree(tree, mask):
    return jax.tree.map(
        lambda x: jnp.where(_row(mask, x) > 0, jnp.zeros_like(x), x), tree)


def masked_mean_tree(tree, alive):
    """Per-leaf alive mean (f32 accumulate, cast back): the tree twin of
    :func:`masked_mean` / ``consensus`` over the alive rows."""
    def leaf(x):
        xf = x.astype(jnp.float32)
        glob = (jnp.sum(xf * _row(alive, x), axis=0) / jnp.sum(alive))
        return glob.astype(x.dtype)
    return jax.tree.map(leaf, tree)


def masked_dispersion_tree(tree, alive):
    """Tree twin of :func:`masked_dispersion` (per-leaf f32 sums)."""
    total = jnp.float32(0.0)
    for x in jax.tree.leaves(tree):
        xf = x.astype(jnp.float32)
        glob = jnp.sum(xf * _row(alive, x), axis=0) / jnp.sum(alive)
        total = total + jnp.sum(
            jnp.square(xf - glob[None]) * _row(alive, x))
    return total / jnp.sum(alive)


def warm_start_tree(tree, alive_prev, rejoined):
    """Rejoining rows take the current alive average (measured over the
    PREVIOUS step's alive set — the rejoiner itself excluded)."""
    mean = masked_mean_tree(tree, alive_prev)
    return jax.tree.map(
        lambda x, g: jnp.where(_row(rejoined, x) > 0,
                               jnp.broadcast_to(g[None], x.shape), x),
        tree, mean)


def masked_average_all_tree(tree, alive, *, groups: int = 1):
    """Masked averaging event on a pytree: alive rows get the (group)
    alive mean, dead rows keep their stale params."""
    def leaf(x):
        xf = x.astype(jnp.float32)
        m = x.shape[0]
        if groups > 1:
            mg = m // groups
            a = alive.reshape(groups, mg)
            rest = xf.shape[1:]
            sums = jnp.sum(xf.reshape((groups, mg) + rest)
                           * a.reshape((groups, mg) + (1,) * len(rest)),
                           axis=1)
            cnt = jnp.maximum(jnp.sum(a, axis=1), 1.0)
            gm = sums / cnt.reshape((groups,) + (1,) * len(rest))
            out = jnp.broadcast_to(gm[:, None], (groups, mg) + rest)
            out = out.reshape(x.shape)
        else:
            glob = jnp.sum(xf * _row(alive, x), axis=0) / jnp.sum(alive)
            out = jnp.broadcast_to(glob[None], x.shape)
        out = out.astype(x.dtype)
        return jnp.where(_row(alive, x) > 0, out, x)
    return jax.tree.map(leaf, tree)


def masked_mix_tree(tree, W, alive):
    """Masked gossip mix on a pytree: the degraded (alive-renormalized)
    ``W`` mixes alive rows; dead rows keep their stale params."""
    Wm = degraded_matrix(W.astype(jnp.float32), alive)

    def leaf(x):
        m = x.shape[0]
        flat = x.astype(jnp.float32).reshape(m, -1)
        out = jnp.dot(Wm, flat, preferred_element_type=jnp.float32)
        out = out.reshape(x.shape).astype(x.dtype)
        return jnp.where(_row(alive, x) > 0, out, x)
    return jax.tree.map(leaf, tree)

"""Elastic membership: live plane resize on a running engine.

PR 7's fault engine masks dead rows but never frees them — a worker
that leaves for good still costs memory, compute and collective
bandwidth on every step. This module makes membership a first-class,
*resizable* runtime axis: an :class:`ElasticPlan` scripts M -> M'
changes at step boundaries, and :func:`run_elastic` executes them by
actually repacking the ``EngineState`` planes — params, every
``FlatOptSpec`` optimizer plane, the error-feedback residual, the
``FaultState`` rows — and rebuilding the :class:`~repro.topology.Topology`
and the worker mesh for the new M. Between resizes the unmodified
``PhaseEngine.run`` drives each segment, so a no-op plan (M' = M, no
curriculum) lowers to the fault engine bit-exactly: phase blocking
never affects results, and a resize is just a phase cut plus a row
repack.

Semantics:

* ``shrink`` at step t: rows ``M'..M-1`` are dropped before step t
  runs; the surviving rows continue bit-identically (their iterates,
  optimizer planes and residual rows are untouched — row repack is a
  pure ``take``).
* ``grow`` at step t: rows ``M..M'-1`` are appended before step t
  runs, warm-started from the mixing-cohort consensus of step t-1
  (optimizer planes and residual rows zeroed, exactly like a fault
  rejoin). With ``curriculum=c > 0`` each grown row runs c solo steps
  — it trains but is masked out of every averaging / mixing event,
  the loss and the dispersion via ``FaultPlan`` solo windows — before
  its iterate re-enters the mix.
* a base :class:`~repro.faults.FaultPlan` (scripted crashes / rejoins /
  straggle on the original rows) composes with the resize plan: each
  segment keeps the base events of the rows that exist in it. Worker
  row indices are stable identities across resizes.

``core/variance_model.predict_post_resize_dispersion`` predicts what a
membership change should cost: the K-weighted drift budget of Parallel
Restarted SGD (arXiv 1807.06629) calibrated against the measured
post-resize dispersion (see ``benchmarks/bench_engine.py`` ``elastic``
arm).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import faults as faults_mod
from repro.faults import FaultPlan, FaultState
from repro.telemetry.events import init_history, make_record
from repro.topology import Topology


class ResizeEvent(NamedTuple):
    """One scripted membership change: the plane is resized to
    ``num_workers`` rows immediately BEFORE local step ``step`` runs
    (1-based, matching ``FaultEvent``: steps >= ``step`` run at the
    new size)."""
    step: int
    num_workers: int


class Segment(NamedTuple):
    """A maximal fixed-membership run of steps ``start <= t < stop``."""
    start: int
    stop: int
    num_workers: int


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """A deterministic resize script for a run starting at
    ``num_workers`` rows.

    resizes:    :class:`ResizeEvent` tuples, strictly increasing steps
                >= 2 (a resize at t=1 would precede every step — start
                the run at that size instead). ``num_workers`` equal to
                the current size is allowed: a no-op resize is a pure
                phase cut, bit-identical to the unresized run.
    curriculum: c > 0 gives every GROWN row c solo steps (train alone,
                out of the mix) before its iterate re-enters averaging.
    """
    num_workers: int
    resizes: tuple = ()
    curriculum: int = 0

    def __post_init__(self):
        if self.num_workers < 1:
            raise ValueError(
                f"num_workers must be >= 1, got {self.num_workers}")
        if self.curriculum < 0:
            raise ValueError(
                f"curriculum must be >= 0, got {self.curriculum}")
        resizes = tuple(ResizeEvent(int(s), int(m)) for s, m in self.resizes)
        prev_step = 1
        for ev in resizes:
            if ev.step <= prev_step:
                raise ValueError(
                    f"resize steps must be strictly increasing and >= 2, "
                    f"got t={ev.step} after t={prev_step}")
            if ev.num_workers < 1:
                raise ValueError(
                    f"resize target M'={ev.num_workers} at t={ev.step} "
                    "must be >= 1")
            prev_step = ev.step
        object.__setattr__(self, "resizes", resizes)

    @classmethod
    def parse(cls, num_workers: int, *, shrink_at=(), grow_at=(),
              curriculum: int = 0) -> "ElasticPlan":
        """Build a plan from CLI ``step:M'`` terms. Each term is
        validated against the membership it would apply to: shrinks
        must shrink, grows must grow (equal M' is allowed on either —
        a scripted no-op)."""
        events = []
        for kind, terms in (("shrink", shrink_at), ("grow", grow_at)):
            for term in terms:
                try:
                    step_s, m_s = str(term).split(":")
                    step, m = int(step_s), int(m_s)
                except ValueError:
                    raise ValueError(
                        f"cannot parse --{kind}-at {term!r} (expected "
                        "step:M', e.g. 128:12)") from None
                events.append((step, m, kind))
        events.sort()
        cur = num_workers
        resizes = []
        for step, m, kind in events:
            if kind == "shrink" and m > cur:
                raise ValueError(
                    f"--shrink-at {step}:{m} would grow the plane "
                    f"({cur} -> {m} workers) — use --grow-at")
            if kind == "grow" and m < cur:
                raise ValueError(
                    f"--grow-at {step}:{m} would shrink the plane "
                    f"({cur} -> {m} workers) — use --shrink-at")
            resizes.append((step, m))
            cur = m
        return cls(num_workers, tuple(resizes), curriculum)

    @property
    def is_trivial(self) -> bool:
        """True when no resize ever changes the plane and no curriculum
        window exists — the plan is pure phase cuts."""
        cur = self.num_workers
        for ev in self.resizes:
            if ev.num_workers != cur:
                return False
            cur = ev.num_workers
        return True

    def sizes(self) -> tuple:
        """Every membership the run passes through, in order."""
        out = [self.num_workers]
        for ev in self.resizes:
            if ev.num_workers != out[-1]:
                out.append(ev.num_workers)
        return tuple(out)

    def segments(self, total_steps: int) -> list:
        """Maximal fixed-membership :class:`Segment` list covering
        local steps ``1..total_steps``."""
        if total_steps < 1:
            raise ValueError(f"total_steps must be >= 1, got {total_steps}")
        bounds = [1]
        ms = [self.num_workers]
        for ev in self.resizes:
            if ev.step > total_steps:
                break
            bounds.append(ev.step)
            ms.append(ev.num_workers)
        bounds.append(total_steps + 1)
        return [Segment(bounds[i], bounds[i + 1], ms[i])
                for i in range(len(ms))]

    def solo_windows(self) -> tuple:
        """Global ``(row, start, stop)`` curriculum windows: every
        grown row trains ``curriculum`` solo steps from its grow step.
        Rows re-grown after a later shrink get a fresh window."""
        if self.curriculum <= 0:
            return ()
        out = []
        cur = self.num_workers
        for ev in self.resizes:
            for row in range(cur, ev.num_workers):
                out.append((row, ev.step, ev.step + self.curriculum))
            cur = ev.num_workers
        return tuple(out)

    def segment_faults(self, base: FaultPlan | None, m: int,
                       start: int = 1, stop: int | None = None):
        """The fault plan a ``m``-row segment engine runs: the base
        plan's events / straggle / rejoin curriculum restricted to the
        rows that exist, plus the grow-curriculum solo windows for
        those rows overlapping steps ``[start, stop)`` (a window from
        another segment's grow would needlessly engage the fault
        machinery here). Returns None when the restriction is trivial
        (the segment lowers to the no-fault engine)."""
        if base is not None and base.num_workers != self.num_workers:
            raise ValueError(
                f"base fault plan has {base.num_workers} workers but the "
                f"elastic plan starts at {self.num_workers}")
        events = tuple(ev for ev in (base.events if base else ())
                       if ev.worker < m)
        solo = tuple(w for w in self.solo_windows()
                     if w[0] < m and w[2] > start
                     and (stop is None or w[1] < stop))
        plan = FaultPlan(
            m, events,
            base.straggle_prob if base else 0.0,
            solo=solo,
            rejoin_curriculum=base.rejoin_curriculum if base else 0)
        return None if plan.is_trivial else plan


# --------------------------------------------------------------------------
# Row repack: EngineState M -> M'
# --------------------------------------------------------------------------

def _state_m(state) -> int:
    """The worker-plane row count of an ``EngineState``."""
    return int(jax.tree.leaves(state.worker_params)[0].shape[0])


def _map_planes(state, fn):
    """Apply ``fn`` to every worker-axis leaf of the state (params,
    optimizer planes, EF residual, fault rows); scalar carries — keys,
    step, ``SchedState`` — ride along untouched."""
    return state._replace(
        worker_params=jax.tree.map(fn, state.worker_params),
        opt_state=jax.tree.map(fn, state.opt_state),
        resid=jax.tree.map(fn, state.resid),
        fault=jax.tree.map(fn, state.fault))


def shrink_state(state, new_m: int):
    """Repack an ``EngineState`` from M to ``new_m`` <= M rows by
    dropping rows ``new_m..M-1``. The kept rows are untouched (a pure
    ``take`` on every plane), so the surviving workers continue
    bit-identically."""
    old_m = _state_m(state)
    if not 1 <= new_m <= old_m:
        raise ValueError(
            f"cannot shrink a {old_m}-row plane to {new_m} rows")
    if isinstance(state.fault, FaultState):
        alive = np.asarray(jax.device_get(state.fault.alive))[:new_m]
        if not np.any(alive > 0):
            raise ValueError(
                f"shrinking to {new_m} rows would keep no alive worker "
                "— every kept row is dead under the fault plan")
    return _map_planes(state, lambda x: x[:new_m])


def grow_state(state, new_m: int, *, optimizer, faults=None):
    """Repack an ``EngineState`` from M to ``new_m`` >= M rows. The
    appended rows warm-start from the current consensus — the mean
    over the mixing cohort (alive, non-solo) of the last completed
    step under ``faults``, the plain worker mean otherwise — with
    optimizer planes, error-feedback residual rows and staleness
    zeroed, exactly like a fault-plan rejoin."""
    old_m = _state_m(state)
    if not old_m <= new_m:
        raise ValueError(
            f"cannot grow a {old_m}-row plane to {new_m} rows")
    if new_m == old_m:
        return state
    k = new_m - old_m
    if isinstance(state.fault, FaultState):
        mask = state.fault.alive
    else:
        mask = jnp.ones((old_m,), jnp.float32)
    if faults is not None:
        mask = faults.mix_at(mask, int(state.step))
    glob = faults_mod.masked_mean_tree(state.worker_params, mask)
    new_rows = jax.tree.map(
        lambda g: jnp.broadcast_to(g[None], (k,) + g.shape), glob)
    new_opt = jax.vmap(optimizer.init)(new_rows)

    def cat(a, b):
        return jnp.concatenate([a, jnp.asarray(b, a.dtype)], axis=0)

    out = state._replace(
        worker_params=jax.tree.map(cat, state.worker_params, new_rows),
        opt_state=jax.tree.map(cat, state.opt_state, new_opt))
    if not (isinstance(state.resid, tuple) and len(state.resid) == 0):
        width = state.resid.shape[1]
        out = out._replace(resid=cat(
            state.resid, jnp.zeros((k, width), state.resid.dtype)))
    if isinstance(state.fault, FaultState):
        out = out._replace(fault=FaultState(
            cat(state.fault.alive, jnp.ones((k,), jnp.float32)),
            cat(state.fault.staleness, jnp.zeros((k,), jnp.int32))))
    return out


def resize_state(state, new_m: int, *, optimizer, faults=None):
    """Dispatch :func:`shrink_state` / :func:`grow_state` (a no-op
    when the plane is already ``new_m`` rows). ``faults`` is the fault
    plan of the segment that just ENDED — it defines the consensus
    cohort grown rows warm-start from."""
    old_m = _state_m(state)
    if new_m < old_m:
        return shrink_state(state, new_m)
    if new_m > old_m:
        return grow_state(state, new_m, optimizer=optimizer,
                          faults=faults)
    return state


def resize_engine(engine, new_m: int, *, faults=None):
    """A segment engine for ``new_m`` rows: the topology re-validated
    and rebuilt at the new size (``full`` stays bit-exact to the mean
    path by construction), the worker mesh rebuilt over the devices
    dividing ``new_m``, and the segment fault plan swapped in."""
    from repro.launch.mesh import make_worker_mesh
    kw = {"faults": faults}
    t = engine.topology
    if t is not None:
        kw["topology"] = Topology.build(
            t.kind, new_m,
            groups=t.groups if t.kind == "groups" else None)
    if engine.mesh is not None:
        kw["mesh"] = make_worker_mesh(new_m)
    return dataclasses.replace(engine, **kw)


def segment_engine(engine, plan: ElasticPlan, step: int,
                   total_steps: int | None = None):
    """The ``(engine, num_workers)`` in effect at local step ``step``
    (the resized engine whose segment contains it). ``step`` may be 0
    (before the first step). Used by ``train.py`` to build the
    like-state a mid-resize checkpoint resumes into."""
    m, start, stop = plan.num_workers, 1, None
    for ev in plan.resizes:
        if total_steps is not None and ev.step > total_steps:
            break
        if ev.step <= max(step, 1):
            m, start = ev.num_workers, ev.step
        elif stop is None:
            stop = ev.step
    if total_steps is not None and stop is None:
        stop = total_steps + 1
    fp = plan.segment_faults(engine.faults, m, start, stop)
    return resize_engine(engine, m, faults=fp), m


def _validate(engine, plan: ElasticPlan):
    if engine.outer is not None:
        raise ValueError(
            "elastic membership is incompatible with the outer "
            "optimizer (its consensus step assumes a fixed membership) "
            "— drop --outer or the resize plan")
    base = engine.faults
    if base is not None and base.num_workers != plan.num_workers:
        raise ValueError(
            f"fault plan covers {base.num_workers} workers but the "
            f"elastic plan starts at {plan.num_workers}")
    g = engine.schedule.inner_groups
    for m in plan.sizes():
        if engine.schedule.kind == "hierarchical" and m % g:
            raise ValueError(
                f"resize target M'={m} is not divisible by "
                f"inner_groups={g} — hierarchical averaging needs every "
                "membership the run passes through to split evenly")
        t = engine.topology
        if t is not None:
            Topology.build(t.kind, m,
                           groups=t.groups if t.kind == "groups" else None)
        plan.segment_faults(base, m)  # eager solo/event validation


def run_elastic(engine, params, data_factory, plan: ElasticPlan, *,
                steps: int, seed: int = 0, record_every: int = 0,
                eval_fn=None, worker_eval_fn=None, state=None,
                return_state: bool = False, sink=None):
    """Drive ``engine`` through ``plan`` for ``steps`` local steps.

    ``data_factory(m, t0, k)`` returns the data argument (e.g. a
    ``DeviceDataset`` slice) for ``k`` steps starting at local step
    ``t0`` under an ``m``-row plane — it must be a pure function of
    its arguments so resume replays identical batches.

    Resumes from ``state`` (a checkpointed ``EngineState``; its plane
    row count disambiguates whether a resize at exactly
    ``state.step + 1`` was already applied before the save). Returns
    ``(final consensus params, history)`` like ``PhaseEngine.run``;
    the history additionally records ``resizes`` as
    ``(step, old_m, new_m)``. ``return_state`` appends the final
    state. A plan with no effective resizes and no curriculum lowers
    to the plain (fault) engine bit-exactly: segment boundaries are
    phase cuts, which never affect results.

    ``sink`` (requires ``PhaseEngine(telemetry=True)``) is forwarded to
    every segment's run; each applied resize additionally emits one
    ``resize_event`` record.
    """
    _validate(engine, plan)
    segs = plan.segments(steps)
    done = 0 if state is None else int(state.step)
    if done >= steps:
        raise ValueError(
            f"state has already completed {done} of {steps} steps")
    hist = init_history(resizes=True)
    prev_faults = None
    for seg in segs:
        fp = plan.segment_faults(engine.faults, seg.num_workers,
                                 seg.start, seg.stop)
        if seg.stop - 1 <= done:  # segment fully completed before resume
            prev_faults = fp
            continue
        eng = resize_engine(engine, seg.num_workers, faults=fp)
        if state is not None:
            old_m = _state_m(state)
            if old_m != seg.num_workers:
                if done + 1 != seg.start:
                    raise ValueError(
                        f"resumed state has {old_m} worker rows but the "
                        f"segment covering step {done + 1} runs "
                        f"{seg.num_workers} — the checkpoint does not "
                        "match the elastic plan")
                if engine.mesh is not None:
                    from repro.sharding.specs import unshard_engine_state
                    state = unshard_engine_state(state)
                state = resize_state(state, seg.num_workers,
                                     optimizer=engine.optimizer,
                                     faults=prev_faults)
                hist["resizes"].append(
                    (seg.start, old_m, seg.num_workers))
                if sink is not None:
                    sink.emit(make_record(
                        "resize_event", step=seg.start, old_m=old_m,
                        new_m=seg.num_workers))
        t0 = max(done + 1, seg.start)
        k = seg.stop - t0
        data = data_factory(seg.num_workers, t0, k)
        out = eng.run(params, data, num_workers=seg.num_workers,
                      seed=seed, record_every=record_every,
                      eval_fn=eval_fn, worker_eval_fn=worker_eval_fn,
                      steps=k, state=state, return_state=True,
                      sink=sink)
        params_final, h, state = out
        for key in ("loss", "dispersion", "disp_trace", "eval",
                    "worker_eval"):
            hist[key].extend(h[key])
        hist["averages"] += h["averages"]
        done = seg.stop - 1
        prev_faults = fp
    if return_state:
        return params_final, hist, state
    return params_final, hist

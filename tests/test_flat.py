"""Flat parameter plane + fused avg_disp kernel + device data plane.

Three layers of guarantees:
  1. FlatSpec pack→unpack is bit-exact for nested trees with mixed
     (float32 / bfloat16 / float16) dtypes — deterministic sweeps plus a
     hypothesis property when available.
  2. The Pallas avg_disp kernels (interpret mode on CPU) match the
     kernels/ref.py jnp twins, and both match the tree-path operators
     (consensus / worker_dispersion / average_inner / OuterOptimizer).
  3. The flat-plane engine (default), the tree-path engine, and the
     indexed on-device data plane all produce the host loop's trajectory
     for all 5 schedules.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AveragingSchedule, FlatSpec, OuterOptimizer,
                        PhaseEngine, consensus)
from repro.core.averaging import (average_inner, worker_dispersion)
from repro.data.pipeline import DeviceDataset, Prefetcher, WorkerSharder, \
    worker_batches
from repro.kernels.avg_disp import avg_disp, avg_disp_outer
from repro.kernels.ref import avg_disp_outer_ref, avg_disp_ref
from repro.optim import SGD

KEY = jax.random.PRNGKey(0)
WORKERS, STEPS, DIM, SAMPLES = 4, 65, 12, 256


# --------------------------------------------------------------------------
# 1. FlatSpec roundtrip
# --------------------------------------------------------------------------

def _mixed_tree(m, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return {
        "dense": {"w": jax.random.normal(ks[0], (m, 3, 5)),
                  "b": jax.random.normal(ks[1], (m, 5)).astype(jnp.bfloat16)},
        "head": (jax.random.normal(ks[2], (m, 7)).astype(jnp.float16),
                 jax.random.normal(ks[3], (m,))),  # scalar-per-worker leaf
    }


@pytest.mark.parametrize("m", [1, 2, 4, 8])
def test_pack_unpack_bit_exact_mixed_dtypes(m):
    tree = _mixed_tree(m, seed=m)
    spec = FlatSpec.of(tree)
    plane = spec.pack(tree)
    assert plane.shape == (m, 15 + 5 + 7 + 1) and plane.dtype == jnp.float32
    back = spec.unpack(plane)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_pack1_unpack1_roundtrip_and_dtype_override():
    tree = jax.tree.map(lambda x: x[0], _mixed_tree(2))
    spec = FlatSpec.of(tree, worker_axis=False)
    vec = spec.pack1(tree)
    assert vec.shape == (spec.width,)
    back = spec.unpack1(vec)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    f32 = spec.unpack1(vec, dtypes=jnp.float32)
    assert all(x.dtype == jnp.float32 for x in jax.tree.leaves(f32))


def test_flatspec_rejects_unembeddable_dtypes():
    assert not FlatSpec.supports({"i": jnp.zeros((2, 3), jnp.int32)})
    with pytest.raises(TypeError):
        FlatSpec.of({"i": jnp.zeros((2, 3), jnp.int32)})
    assert FlatSpec.supports(_mixed_tree(2))


def test_pack_unpack_property():
    """Hypothesis property: arbitrary nested shapes/dtypes roundtrip."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    dtypes = st.sampled_from([jnp.float32, jnp.bfloat16, jnp.float16])
    shapes = st.lists(st.sampled_from([(3,), (2, 4), (1, 1, 5), ()]),
                      min_size=1, max_size=4)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000), m=st.sampled_from([1, 2, 4]),
           shapes=shapes, data=st.data())
    def prop(seed, m, shapes, data):
        rng = np.random.default_rng(seed)
        tree = {}
        for i, s in enumerate(shapes):
            dt = data.draw(dtypes)
            tree[f"l{i}"] = jnp.asarray(
                rng.standard_normal((m,) + s), jnp.float32).astype(dt)
        spec = FlatSpec.of(tree)
        back = spec.unpack(spec.pack(tree))
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    prop()


# --------------------------------------------------------------------------
# 2. avg_disp kernel == ref == tree operators
# --------------------------------------------------------------------------

class TestAvgDispKernel:
    @pytest.mark.parametrize("m,p,groups,bp", [
        (4, 300, 1, 128),   # padding path
        (8, 1024, 1, 256),
        (8, 1024, 2, 512),
        (8, 96, 4, 96),
        (16, 33, 1, 1024),  # single partial block
    ])
    def test_matches_ref(self, m, p, groups, bp):
        x = jax.random.normal(jax.random.PRNGKey(p), (m, p))
        out, disp = avg_disp(x, groups=groups, block_p=bp)
        oref, dref = avg_disp_ref(x, groups=groups)
        np.testing.assert_allclose(np.asarray(out), np.asarray(oref),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(float(disp), float(dref), rtol=1e-5)

    @pytest.mark.parametrize("nesterov", [True, False])
    @pytest.mark.parametrize("bp", [128, 1024])
    def test_outer_matches_ref(self, nesterov, bp):
        ks = jax.random.split(KEY, 3)
        x = jax.random.normal(ks[0], (8, 300))
        prev = jax.random.normal(ks[1], (300,))
        vel = jax.random.normal(ks[2], (300,)) * 0.1
        got = avg_disp_outer(x, prev, vel, lr=0.8, momentum=0.5,
                             nesterov=nesterov, block_p=bp)
        ref = avg_disp_outer_ref(x, prev, vel, lr=0.8, momentum=0.5,
                                 nesterov=nesterov)
        for a, b in zip(got, ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    def test_ref_matches_tree_operators(self):
        """The fused flat op == consensus/average_inner + Eq. 4
        dispersion on the equivalent pytree."""
        tree = {"a": jax.random.normal(KEY, (8, 11)),
                "b": {"c": jax.random.normal(KEY, (8, 2, 3))}}
        spec = FlatSpec.of(tree)
        plane = spec.pack(tree)
        for groups in (1, 2, 4):
            out, disp = avg_disp_ref(plane, groups=groups)
            want = average_inner(tree, groups) if groups > 1 else \
                jax.tree.map(lambda x: jnp.broadcast_to(
                    jnp.mean(x, axis=0, keepdims=True), x.shape), tree)
            for a, b in zip(jax.tree.leaves(spec.unpack(out)),
                            jax.tree.leaves(want)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-6, atol=1e-7)
            np.testing.assert_allclose(float(disp),
                                       float(worker_dispersion(tree)),
                                       rtol=1e-5)

    def test_outer_ref_matches_outer_optimizer(self):
        tree = {"a": jax.random.normal(KEY, (8, 11))}
        spec = FlatSpec.of(tree)
        plane = spec.pack(tree)
        prev = {"a": jax.random.normal(jax.random.PRNGKey(7), (11,))}
        outer = OuterOptimizer(lr=0.7, momentum=0.4, nesterov=True)
        vel = outer.init(prev)
        _, upd_vec, vel_vec, _ = avg_disp_outer_ref(
            plane, spec.pack1(prev), spec.pack1(vel), lr=0.7, momentum=0.4,
            nesterov=True)
        want_upd, want_vel = outer.apply(prev, consensus(tree), vel)
        np.testing.assert_allclose(np.asarray(upd_vec),
                                   np.asarray(want_upd["a"]), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(vel_vec),
                                   np.asarray(want_vel["a"]), rtol=1e-6)


# --------------------------------------------------------------------------
# 3. flat engine == tree engine == host loop, all 5 schedules
# --------------------------------------------------------------------------

SCHEDULES = {
    "oneshot": AveragingSchedule("oneshot"),
    "minibatch": AveragingSchedule("minibatch"),
    "periodic": AveragingSchedule("periodic", 8),
    "stochastic": AveragingSchedule("stochastic", zeta=0.2),
    "hierarchical": AveragingSchedule("hierarchical", inner_phase_len=5,
                                      outer_phase_len=20, inner_groups=2),
    "adaptive_threshold": AveragingSchedule("adaptive_threshold",
                                            disp_threshold=0.05,
                                            disp_ema_beta=0.5),
    "adaptive_budget": AveragingSchedule("adaptive_budget", comm_budget=6,
                                         budget_horizon=STEPS),
}


def _convex_problem(seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((SAMPLES, DIM))
    y = X @ rng.standard_normal(DIM) + 0.1 * rng.standard_normal(SAMPLES)
    return X, y


def _loss_fn(params, batch, rng):
    r = batch["x"] @ params["w"]["inner"] - batch["y"]
    return 0.5 * jnp.mean(r * r), {}


def _params():
    return {"w": {"inner": jnp.zeros(DIM)}}


def _index_draws(seed=1, steps=STEPS):
    rng = np.random.default_rng(seed)
    return rng.integers(0, SAMPLES, (steps, WORKERS, 8))


def _batches(X, y, idx):
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    for t in range(len(idx)):
        yield {"x": Xj[idx[t]], "y": yj[idx[t]]}


@pytest.mark.parametrize("name", list(SCHEDULES))
def test_flat_tree_indexed_all_match_host(name):
    """Default (flat) engine, tree-path engine, and the on-device indexed
    data plane reproduce the host loop for every schedule."""
    X, y = _convex_problem()
    idx = _index_draws()
    kw = dict(num_workers=WORKERS, seed=3, record_every=1)

    def final(engine, data, **extra):
        f, h = engine.run(_params(), data, **kw, **extra)
        return np.asarray(f["w"]["inner"]), h

    flat_eng = PhaseEngine(_loss_fn, SGD(lr=0.05), SCHEDULES[name])
    tree_eng = PhaseEngine(_loss_fn, SGD(lr=0.05), SCHEDULES[name],
                           flat=False)
    assert flat_eng.flat and not tree_eng.flat
    f_flat, h_flat = final(flat_eng, _batches(X, y, idx))
    f_tree, h_tree = final(tree_eng, _batches(X, y, idx))
    ds = DeviceDataset({"x": X, "y": y}, WORKERS, indices=idx)
    f_idx, h_idx = final(flat_eng, ds)
    f_host, h_host = flat_eng.run_host(_params(), _batches(X, y, idx),
                                       num_workers=WORKERS, seed=3,
                                       record_every=1)
    f_host = np.asarray(f_host["w"]["inner"])

    np.testing.assert_array_equal(f_flat, f_idx)  # same program modulo gather
    assert h_flat == h_idx
    for got in (f_flat, f_tree):
        np.testing.assert_allclose(got, f_host, rtol=1e-6, atol=1e-7)
    for h in (h_flat, h_tree):
        assert h["averages"] == h_host["averages"]
        assert [t for t, _ in h["dispersion"]] == \
            [t for t, _ in h_host["dispersion"]]
        np.testing.assert_allclose([v for _, v in h["dispersion"]],
                                   [v for _, v in h_host["dispersion"]],
                                   rtol=1e-5, atol=1e-8)
        np.testing.assert_allclose([v for _, v in h["loss"]],
                                   [v for _, v in h_host["loss"]],
                                   rtol=1e-6, atol=1e-7)


def test_flat_engine_with_outer_matches_tree_engine():
    X, y = _convex_problem()
    idx = _index_draws(seed=5)
    mk = lambda flat: PhaseEngine(
        _loss_fn, SGD(lr=0.05), AveragingSchedule("periodic", 8),
        outer=OuterOptimizer(lr=0.8, momentum=0.5), flat=flat)
    f_a, h_a = mk(True).run(_params(), _batches(X, y, idx),
                            num_workers=WORKERS, seed=5, record_every=1)
    f_b, h_b = mk(False).run(_params(), _batches(X, y, idx),
                             num_workers=WORKERS, seed=5, record_every=1)
    np.testing.assert_allclose(np.asarray(f_a["w"]["inner"]),
                               np.asarray(f_b["w"]["inner"]),
                               rtol=1e-6, atol=1e-7)
    assert h_a["averages"] == h_b["averages"]


@pytest.mark.filterwarnings(
    "ignore:Casting from complex:DeprecationWarning")
def test_flat_falls_back_for_unembeddable_leaves():
    """Trees FlatSpec cannot embed (here: a complex64 leaf) still run
    under flat=True — the engine silently takes the tree path."""
    X, y = _convex_problem()
    idx = _index_draws()

    def loss(params, batch, rng):
        r = batch["x"] @ params["w"] - batch["y"]
        return 0.5 * jnp.mean(r * r) + 0.0 * jnp.real(jnp.sum(params["c"])), {}

    p0 = {"w": jnp.zeros(DIM), "c": jnp.zeros(3, jnp.complex64)}
    assert not FlatSpec.supports(p0)
    eng = PhaseEngine(loss, SGD(lr=0.05), AveragingSchedule("periodic", 8))
    f, hist = eng.run(p0, _batches(X, y, idx), num_workers=WORKERS, seed=0)
    assert hist["averages"] == STEPS // 8
    assert np.isfinite(np.asarray(f["w"])).all()


def test_device_dataset_sampler_and_steps():
    """Sampler-backed DeviceDataset: steps= bounds the run; replacement
    draws come from the stacked single-stream generator."""
    X, y = _convex_problem()
    ds = DeviceDataset({"x": X, "y": y}, WORKERS, batch_size=8, seed=4,
                       mode="replacement")
    eng = PhaseEngine(_loss_fn, SGD(lr=0.05), AveragingSchedule("periodic", 8))
    _, hist = eng.run(_params(), ds, num_workers=WORKERS, seed=0,
                      record_every=8, steps=32)
    assert hist["averages"] == 4
    assert [t for t, _ in hist["loss"]] == [8, 16, 24, 32]


def test_prefetch_matches_sync_staging():
    X, y = _convex_problem()
    idx = _index_draws(seed=9)
    eng = PhaseEngine(_loss_fn, SGD(lr=0.05),
                      AveragingSchedule("stochastic", zeta=0.3))
    f_a, h_a = eng.run(_params(), _batches(X, y, idx), num_workers=WORKERS,
                       seed=1, record_every=1, prefetch=True)
    f_b, h_b = eng.run(_params(), _batches(X, y, idx), num_workers=WORKERS,
                       seed=1, record_every=1, prefetch=False)
    np.testing.assert_array_equal(np.asarray(f_a["w"]["inner"]),
                                  np.asarray(f_b["w"]["inner"]))
    assert h_a == h_b


def test_indexed_run_clamps_to_available_indices():
    """steps= beyond the precomputed index list ends like a streaming
    source (partial history), not mid-run assertion."""
    X, y = _convex_problem()
    idx = _index_draws(steps=24)
    ds = DeviceDataset({"x": X, "y": y}, WORKERS, indices=idx)
    eng = PhaseEngine(_loss_fn, SGD(lr=0.05), AveragingSchedule("periodic", 8))
    _, hist = eng.run(_params(), ds, num_workers=WORKERS, seed=0,
                      record_every=8, steps=1000)
    assert [t for t, _ in hist["loss"]] == [8, 16, 24]
    assert ds.num_steps == 0  # cursor exhausted, not overrun


def test_prefetcher_close_unblocks_producer():
    produced = []

    def src():
        for i in range(100):
            produced.append(i)
            yield i

    pf = Prefetcher(src(), depth=1)
    assert next(pf) == 0
    pf.close()  # consumer abandons: producer must exit, not block
    assert not pf._thread.is_alive()
    assert len(produced) < 100
    with pytest.raises(StopIteration):
        next(pf)


def test_prefetcher_propagates_producer_errors():
    def bad():
        yield 1
        raise ValueError("boom")

    pf = Prefetcher(bad())
    assert next(pf) == 1
    with pytest.raises(ValueError, match="boom"):
        for _ in pf:
            pass


# --------------------------------------------------------------------------
# Satellites: finite streams, sharder vectorization, run_host worker_eval
# --------------------------------------------------------------------------

def test_worker_batches_finite_stream_ends_cleanly():
    """PEP 479 regression: an exhausted stream must END the generator,
    not raise RuntimeError; a partial final worker group is dropped."""
    stream = iter([np.full(3, i) for i in range(7)])
    got = list(worker_batches(stream, 2))  # 7 = 3 full groups + partial
    assert len(got) == 3
    assert all(b.shape == (2, 3) for b in got)
    np.testing.assert_array_equal(got[2][1], np.full(3, 5))


def test_sharder_replacement_block_equals_successive_draws():
    a = WorkerSharder(100, 4, seed=5, mode="replacement")
    b = WorkerSharder(100, 4, seed=5, mode="replacement")
    blk = a.next_index_block(6, 8)
    assert blk.shape == (6, 4, 8) and blk.min() >= 0 and blk.max() < 100
    np.testing.assert_array_equal(
        blk, np.stack([b.next_indices(8) for _ in range(6)]))


def test_sharder_permute_block_walks_epoch_cursors():
    a = WorkerSharder(32, 2, seed=1, mode="permute")
    blk = a.next_index_block(4, 8)  # exactly one epoch per worker
    assert blk.shape == (4, 2, 8)
    for w in range(2):
        assert sorted(blk[:, w].ravel()) == list(range(32))


def test_run_host_records_worker_eval():
    X, y = _convex_problem()
    idx = _index_draws()
    eng = PhaseEngine(_loss_fn, SGD(lr=0.05), AveragingSchedule("periodic", 8))

    def worker_eval(wp):
        assert jax.tree.leaves(wp)[0].shape[0] == WORKERS
        return 2.0

    _, h_eng = eng.run(_params(), _batches(X, y, idx), num_workers=WORKERS,
                       seed=0, record_every=20, worker_eval_fn=worker_eval)
    _, h_host = eng.run_host(_params(), _batches(X, y, idx),
                             num_workers=WORKERS, seed=0, record_every=20,
                             worker_eval_fn=worker_eval)
    assert set(h_eng) == set(h_host)  # identical history dict keys
    assert h_eng["worker_eval"] == h_host["worker_eval"] == \
        [(20, 2.0), (40, 2.0), (60, 2.0)]

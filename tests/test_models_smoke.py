"""Per-arch smoke tests (required deliverable): reduced variant of each
assigned architecture runs one forward/train step on CPU with correct
shapes and no NaNs; decode agrees with the full-sequence forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.core.averaging import average_all
from repro.models import (decode_step, forward, init_cache, init_params,
                          lm_loss)
from repro.optim import Momentum

KEY = jax.random.PRNGKey(0)


def make(arch, **kw):
    cfg = dataclasses.replace(get_config(arch, reduced=True),
                              dtype="float32", **kw)
    params = init_params(cfg, KEY)
    return cfg, params


def make_batch(cfg, b=2, s=32, lead=()):
    ks = jax.random.split(KEY, 3)
    batch = {"tokens": jax.random.randint(ks[0], lead + (b, s), 0,
                                          cfg.vocab_size)}
    if cfg.family == "audio":
        batch["audio"] = jax.random.normal(
            ks[1], lead + (b, cfg.encoder_seq, cfg.d_model)) * 0.3
    if cfg.family == "vlm":
        batch["media"] = jax.random.normal(
            ks[2], lead + (b, cfg.num_media_tokens, cfg.d_model)) * 0.3
    return batch


class TestSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg, params = make(arch)
        assert cfg.num_layers <= 2 and cfg.d_model <= 512
        assert cfg.num_experts <= 4
        batch = make_batch(cfg)
        logits, _ = forward(cfg, params, batch)
        assert logits.shape == (2, 32, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits).all())

    def test_one_train_step(self, arch):
        """One local-SGD step per worker + one averaging step: loss is
        finite, params move, and averaging collapses worker dispersion."""
        from repro.core.averaging import worker_dispersion
        cfg, params = make(arch)
        opt = Momentum(lr=0.01, mu=0.9)
        W = 2
        wp = jax.tree.map(lambda x: jnp.stack([x] * W), params)
        os_ = jax.vmap(opt.init)(wp)
        batch = make_batch(cfg, lead=(W,))

        def one(p, s, b):
            (loss, _), g = jax.value_and_grad(
                lambda pp: lm_loss(cfg, pp, b), has_aux=True)(p)
            p2, s2 = opt.apply(p, g, s, jnp.zeros((), jnp.int32))
            return p2, s2, loss

        wp2, os2, loss = jax.vmap(one)(wp, os_, batch)
        assert bool(jnp.isfinite(loss).all()), arch
        moved = any(
            float(jnp.max(jnp.abs(a - b))) > 0
            for a, b in zip(jax.tree.leaves(wp), jax.tree.leaves(wp2)))
        assert moved
        # distinct per-worker batches -> workers diverge; averaging fixes
        assert float(worker_dispersion(wp2)) > 0
        avg = average_all(wp2)
        assert float(worker_dispersion(avg)) < 1e-10
        for leaf in jax.tree.leaves(avg):
            assert bool(jnp.isfinite(leaf).all())


class TestDecodeConsistency:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_decode_matches_forward(self, arch):
        """Teacher-forced decode, token by token, must reproduce the
        full-sequence forward logits — exercises KV caches, RG-LRU/RWKV
        state carrying, sliding windows, cross-attn caches and RoPE
        offsets in one go."""
        cfg, params = make(arch, capacity_factor=8.0)
        b, s = 2, 24
        batch = make_batch(cfg, b=b, s=s)
        ref_logits, _ = forward(cfg, params, batch)

        mem = None
        if cfg.family == "audio":
            from repro.models.transformer import encode
            mem = encode(cfg, params, batch["audio"])
        if cfg.family == "vlm":
            mem = batch["media"]
        cache = init_cache(cfg, b, s, memory=mem, params=params)
        outs = []
        for t in range(s):
            logits, cache = decode_step(cfg, params,
                                        batch["tokens"][:, t:t + 1], cache)
            outs.append(logits[:, 0])
        got = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref_logits),
                                   rtol=2e-3, atol=2e-3, err_msg=arch)


class TestPrefillContinuity:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_prefill_cache_then_decode(self, arch):
        """True prefill (forward with return_cache) followed by decode
        must equal the full-sequence forward — the production serving
        path for every family."""
        cfg, params = make(arch, capacity_factor=8.0)
        b, s, gen = 2, 16, 5
        batch = make_batch(cfg, b=b, s=s + gen)
        ref, _ = forward(cfg, params, batch)
        pre = {k: (v[:, :s] if k == "tokens" else v)
               for k, v in batch.items()}
        logits, _, cache = forward(cfg, params, pre, return_cache=True,
                                   cache_len=s + gen)
        outs = [logits[:, -1]]
        for t in range(s, s + gen - 1):
            lg, cache = decode_step(cfg, params,
                                    batch["tokens"][:, t:t + 1], cache)
            outs.append(lg[:, 0])
        got = jnp.stack(outs, 1)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref[:, s - 1:s + gen - 1]),
                                   rtol=2e-3, atol=2e-3, err_msg=arch)


class TestVocabPadding:
    def test_padded_vocab_never_wins(self):
        # odd vocab (like whisper's real 51865) -> padded internally
        cfg, params = make("whisper-small", vocab_size=493)
        assert cfg.padded_vocab > cfg.vocab_size
        batch = make_batch(cfg)
        logits, _ = forward(cfg, params, batch)
        assert int(jnp.argmax(logits, -1).max()) < cfg.vocab_size

    def test_loss_label_masking(self):
        cfg, params = make("smollm-360m")
        batch = make_batch(cfg)
        labels = jnp.where(jnp.arange(32)[None, :] < 16,
                           batch["tokens"], -1)
        loss_masked, _ = lm_loss(cfg, params, {**batch, "labels": labels})
        assert bool(jnp.isfinite(loss_masked))

"""Adaptive dispersion-driven schedules: the stateful subsystem.

The adaptive kinds decide WHEN to average from the measured Eq. 4
dispersion, carried as an explicit ``SchedState`` in the phase scan and
in ``EngineState``. These tests pin the system-level guarantees:

  1. engine == host on the FULL per-step trajectory — decision sequence,
     dispersion trace, loss trace, final params — for both adaptive
     kinds (the host loop replays the identical pure transition from its
     own per-step dispersion).
  2. Decisions are independent of phase blocking (the state rides the
     scan carry across run_phase boundaries) and of prefetch staging.
  3. Checkpoint/resume is bit-identical, INCLUDING the schedule state
     (dispersion EMA, pacing credit, budget spent): a resumed run replays
     the decisions of the uninterrupted one.
  4. The dispersion trace is the true Eq. 4 value on EVERY step (it used
     to read 0.0 between averaging events), in the engine and host paths.
  5. ``PhaseEngine`` rejects a worker count the hierarchical inner
     grouping cannot split — eagerly, not as a mid-trace reshape error.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_engine_state, save_engine_state
from repro.core import (AveragingSchedule, OuterOptimizer, PhaseEngine,
                        SchedState)
from repro.data.pipeline import DeviceDataset
from repro.optim import SGD, Momentum

WORKERS, STEPS, DIM, SAMPLES = 4, 65, 12, 256


def _convex_problem(seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((SAMPLES, DIM))
    y = X @ rng.standard_normal(DIM) + 0.1 * rng.standard_normal(SAMPLES)
    return X, y


def _loss_fn(params, batch, rng):
    r = batch["x"] @ params["w"] - batch["y"]
    return 0.5 * jnp.mean(r * r), {}


def _params():
    return {"w": jnp.zeros(DIM)}


def _index_draws(seed=1, steps=STEPS):
    rng = np.random.default_rng(seed)
    return rng.integers(0, SAMPLES, (steps, WORKERS, 8))


def _batches(X, y, idx):
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    return [{"x": Xj[idx[t]], "y": yj[idx[t]]} for t in range(len(idx))]


# tuned so both kinds produce a non-trivial, non-degenerate decision
# sequence on this workload (some events, not every step)
ADAPTIVE = {
    "adaptive_threshold": AveragingSchedule("adaptive_threshold",
                                            disp_threshold=0.05,
                                            disp_ema_beta=0.5),
    "adaptive_budget": AveragingSchedule("adaptive_budget", comm_budget=8,
                                         budget_horizon=STEPS),
}


@pytest.mark.parametrize("name", list(ADAPTIVE))
def test_adaptive_engine_matches_host_full_trace(name):
    """Engine and host replay the identical decision sequence from
    their independently measured dispersion, and agree on the FULL
    per-step dispersion/loss traces — not just at averaging events."""
    X, y = _convex_problem()
    idx = _index_draws()
    engine = PhaseEngine(_loss_fn, SGD(lr=0.05), ADAPTIVE[name])
    kw = dict(num_workers=WORKERS, seed=3, record_every=1)
    f_eng, h_eng = engine.run(_params(), _batches(X, y, idx), **kw)
    f_host, h_host = engine.run_host(_params(), _batches(X, y, idx), **kw)

    # decision sequences are exactly equal (discrete — no tolerance)
    assert h_eng["averages"] == h_host["averages"] > 0
    assert [t for t, _ in h_eng["dispersion"]] == \
        [t for t, _ in h_host["dispersion"]]
    # non-degenerate: the schedule must skip some steps too
    assert h_eng["averages"] < STEPS
    np.testing.assert_allclose(np.asarray(f_eng["w"]),
                               np.asarray(f_host["w"]),
                               rtol=1e-6, atol=1e-7)
    # FULL per-step traces agree (65 points each)
    assert len(h_eng["disp_trace"]) == STEPS
    np.testing.assert_allclose([v for _, v in h_eng["disp_trace"]],
                               [v for _, v in h_host["disp_trace"]],
                               rtol=1e-5, atol=1e-8)
    np.testing.assert_allclose([v for _, v in h_eng["loss"]],
                               [v for _, v in h_host["loss"]],
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("name", list(ADAPTIVE))
def test_adaptive_flat_tree_indexed_paths_agree(name):
    """flat-native (default), PR 2 flat, tree carry and the indexed
    on-device data plane all take the same averaging decisions and land
    on the same params."""
    X, y = _convex_problem()
    idx = _index_draws()
    kw = dict(num_workers=WORKERS, seed=3, record_every=1)
    mk = lambda **e: PhaseEngine(_loss_fn, SGD(lr=0.05), ADAPTIVE[name],
                                 **e)
    f_nat, h_nat = mk().run(_params(), _batches(X, y, idx), **kw)
    f_pr2, h_pr2 = mk(fused_opt=False).run(_params(), _batches(X, y, idx),
                                           **kw)
    f_tree, h_tree = mk(flat=False).run(_params(), _batches(X, y, idx),
                                        **kw)
    ds = DeviceDataset({"x": X, "y": y}, WORKERS, indices=idx)
    f_idx, h_idx = mk().run(_params(), ds, **kw)

    np.testing.assert_array_equal(np.asarray(f_nat["w"]),
                                  np.asarray(f_idx["w"]))
    assert h_nat == h_idx
    for f, h in ((f_pr2, h_pr2), (f_tree, h_tree)):
        assert h_nat["averages"] == h["averages"] > 0
        assert [t for t, _ in h_nat["dispersion"]] == \
            [t for t, _ in h["dispersion"]]
        np.testing.assert_allclose(np.asarray(f_nat["w"]),
                                   np.asarray(f["w"]),
                                   rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("block", [1, 7, 32, 100])
def test_adaptive_decisions_invariant_to_phase_blocking(block):
    """SchedState rides the scan carry across run_phase boundaries, so
    phase blocking stays a pure perf knob for adaptive schedules too."""
    X, y = _convex_problem()
    idx = _index_draws()
    engine = PhaseEngine(_loss_fn, SGD(lr=0.05),
                         ADAPTIVE["adaptive_threshold"])
    kw = dict(num_workers=WORKERS, seed=0, record_every=1)
    ref, h_ref = engine.run(_params(), _batches(X, y, idx), phase_len=8,
                            **kw)
    got, h_got = engine.run(_params(), _batches(X, y, idx),
                            phase_len=block, **kw)
    np.testing.assert_array_equal(np.asarray(ref["w"]),
                                  np.asarray(got["w"]))
    assert h_ref == h_got


@pytest.mark.parametrize("name", list(ADAPTIVE))
def test_adaptive_checkpoint_resume_bit_identical(tmp_path, name):
    """Interrupt -> save_engine_state -> load -> resume == uninterrupted,
    bit for bit: the SchedState fields (EMA, credit, budget spent) are
    checkpointed, so the resumed run replays the adaptive decisions."""
    X, y = _convex_problem()
    idx = _index_draws(seed=7)
    mk = lambda: PhaseEngine(_loss_fn, Momentum(lr=0.05, mu=0.9),
                             ADAPTIVE[name],
                             outer=OuterOptimizer(lr=0.9, momentum=0.5))
    batches = _batches(X, y, idx)
    kw = dict(num_workers=WORKERS, record_every=8)

    f_full, h_full = mk().run(_params(), batches, seed=7, **kw)

    cut = 32
    _, h1, st = mk().run(_params(), batches[:cut], seed=7,
                         return_state=True, **kw)
    # mid-run: the stateful schedule has accumulated real state
    assert isinstance(st.sched, SchedState)
    assert int(st.sched.comm_spent) == h1["averages"]
    path = os.path.join(tmp_path, "ck")
    save_engine_state(path, st)

    loaded, at = load_engine_state(path, mk().init(_params(), WORKERS, 7))
    assert at == cut
    # every field — including each SchedState scalar — restored bit-exact
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    f_res, h2 = mk().run(None, batches[cut:], state=loaded, **kw)
    np.testing.assert_array_equal(np.asarray(f_full["w"]),
                                  np.asarray(f_res["w"]))
    assert h_full["dispersion"] == h1["dispersion"] + h2["dispersion"]
    assert h_full["disp_trace"] == h1["disp_trace"] + h2["disp_trace"]
    assert h_full["averages"] == h1["averages"] + h2["averages"] > 0


def test_pre_schedstate_checkpoint_still_loads(tmp_path):
    """Checkpoints written before EngineState carried SchedState (PR 3
    and earlier) must still load: the missing sched leaves are taken
    fresh (all-zero) from the like-state instead of tripping the
    leaf-count assert."""
    X, y = _convex_problem()
    idx = _index_draws()
    engine = PhaseEngine(_loss_fn, SGD(lr=0.05),
                         AveragingSchedule("periodic", 8))
    _, _, st = engine.run(_params(), _batches(X, y, idx)[:16],
                          num_workers=WORKERS, seed=1, return_state=True)
    path = os.path.join(tmp_path, "old")
    save_engine_state(path, st._replace(sched=()))  # PR 3 layout

    like = engine.init(_params(), WORKERS, 1)
    loaded, step = load_engine_state(path, like)
    assert step == 16 and isinstance(loaded.sched, SchedState)
    assert int(loaded.sched.comm_spent) == 0  # fresh bookkeeping
    np.testing.assert_array_equal(
        np.asarray(st.worker_params["w"]),
        np.asarray(loaded.worker_params["w"]))
    # and the resumed run proceeds normally
    f, h = engine.run(None, _batches(X, y, idx)[16:32], state=loaded,
                      num_workers=WORKERS, record_every=8)
    assert h["averages"] == 2 and np.isfinite(np.asarray(f["w"])).all()


def test_dispersion_trace_true_on_non_averaging_steps():
    """The Eq. 4 trace regression: between periodic events the recorded
    dispersion must be the true (growing) diagnostic, not 0.0 — and the
    engine's per-step values must match the host loop's."""
    X, y = _convex_problem()
    idx = _index_draws()
    engine = PhaseEngine(_loss_fn, SGD(lr=0.05),
                         AveragingSchedule("periodic", 8))
    kw = dict(num_workers=WORKERS, seed=3, record_every=1)
    _, h_eng = engine.run(_params(), _batches(X, y, idx), **kw)
    _, h_host = engine.run_host(_params(), _batches(X, y, idx), **kw)
    trace = dict(h_eng["disp_trace"])
    assert len(trace) == STEPS
    # every step from 2 on has genuinely dispersed workers (step 1 may
    # round to ~0 from identical init); non-averaging steps especially
    non_avg = [t for t in range(2, STEPS + 1) if t % 8]
    assert all(trace[t] > 0 for t in non_avg)
    # within a phase the dispersion grows from the post-average collapse
    assert trace[9] < trace[15]
    np.testing.assert_allclose([v for _, v in h_eng["disp_trace"]],
                               [v for _, v in h_host["disp_trace"]],
                               rtol=1e-5, atol=1e-8)
    # at event steps the trace equals the event diagnostic (pre-average)
    for t, v in h_eng["dispersion"]:
        assert trace[t] == v


def test_run_phase_trace_matches_host_per_step():
    """The raw run_phase trace (the engine's only host transfer) carries
    the true per-step dispersion for a rare-averaging schedule."""
    from repro.core import tree_stack
    X, y = _convex_problem()
    idx = _index_draws()
    engine = PhaseEngine(_loss_fn, SGD(lr=0.05),
                         AveragingSchedule("periodic", 16))
    state = engine.init(_params(), WORKERS, seed=3)
    _, trace = engine.run_phase(state, tree_stack(_batches(X, y, idx)))
    disp = np.asarray(trace["dispersion"])
    codes = np.asarray(trace["avg_code"])
    assert disp.shape == (STEPS,)
    assert (disp[1:] > 0).all()          # true value on EVERY step
    assert (codes[15::16] == 2).all()    # periodic-16 events intact
    _, h_host = engine.run_host(_params(), _batches(X, y, idx),
                                num_workers=WORKERS, seed=3,
                                record_every=1)
    np.testing.assert_allclose(disp, [v for _, v in h_host["disp_trace"]],
                               rtol=1e-5, atol=1e-8)


def test_inner_groups_must_divide_workers_eagerly():
    """M % inner_groups != 0 must fail with a clear eager error in
    init/run/run_host — not an opaque reshape error mid-trace."""
    X, y = _convex_problem()
    idx = _index_draws()
    sch = AveragingSchedule("hierarchical", inner_phase_len=5,
                            outer_phase_len=20, inner_groups=3)
    engine = PhaseEngine(_loss_fn, SGD(lr=0.05), sch)
    with pytest.raises(ValueError, match="inner_groups"):
        engine.init(_params(), WORKERS)  # 4 % 3 != 0
    with pytest.raises(ValueError, match="inner_groups"):
        engine.run(_params(), _batches(X, y, idx), num_workers=WORKERS)
    with pytest.raises(ValueError, match="inner_groups"):
        engine.run_host(_params(), _batches(X, y, idx),
                        num_workers=WORKERS)
    # a dividing count passes through
    PhaseEngine(_loss_fn, SGD(lr=0.05), AveragingSchedule(
        "hierarchical", inner_phase_len=5, outer_phase_len=20,
        inner_groups=2)).init(_params(), WORKERS)


class TestTrainCliValidation:
    """train.py schedule-arg validation fails at parse time (argparse
    error, exit code 2) instead of deep inside a trace — the
    hierarchical inner>=outer case used to silently never inner-average
    and an invalid stochastic zeta surfaced as a raw ValueError."""

    def _error(self, argv):
        from repro.launch.train import main
        with pytest.raises(SystemExit) as e:
            main(argv)
        assert e.value.code == 2

    def test_hierarchical_inner_ge_outer_rejected(self):
        self._error(["--avg", "hierarchical", "--phase-len", "10",
                     "--outer-phase-len", "5"])
        self._error(["--avg", "hierarchical", "--phase-len", "10",
                     "--outer-phase-len", "10"])

    def test_stochastic_needs_nonzero_zeta(self):
        self._error(["--avg", "stochastic", "--zeta", "0.0"])
        self._error(["--avg", "stochastic", "--zeta", "1.5"])

    def test_adaptive_threshold_needs_threshold(self):
        self._error(["--avg", "adaptive_threshold"])

    def test_adaptive_budget_needs_feasible_budget(self):
        self._error(["--avg", "adaptive_budget"])
        self._error(["--avg", "adaptive_budget", "--comm-budget", "200",
                     "--steps", "100"])


def test_adaptive_with_outer_optimizer_matches_host():
    """Adaptive events drive the DiLoCo-style outer momentum step too."""
    X, y = _convex_problem()
    idx = _index_draws(seed=5)
    engine = PhaseEngine(_loss_fn, Momentum(lr=0.05, mu=0.9),
                         ADAPTIVE["adaptive_threshold"],
                         outer=OuterOptimizer(lr=0.8, momentum=0.5))
    kw = dict(num_workers=WORKERS, seed=5, record_every=1)
    f_eng, h_eng = engine.run(_params(), _batches(X, y, idx), **kw)
    f_host, h_host = engine.run_host(_params(), _batches(X, y, idx), **kw)
    assert h_eng["averages"] == h_host["averages"] > 0
    assert [t for t, _ in h_eng["dispersion"]] == \
        [t for t, _ in h_host["dispersion"]]
    np.testing.assert_allclose(np.asarray(f_eng["w"]),
                               np.asarray(f_host["w"]),
                               rtol=1e-6, atol=1e-7)

"""Elastic membership: live plane resize, rejoin curricula,
straggle-aware scheduling.

Covers the repro.elastic subsystem end to end:

  - ElasticPlan validation / parsing (eager, actionable errors);
  - row repacking is a permutation-exact pack/unpack (property test,
    hypothesis-optional with an always-on numpy fallback);
  - a no-op resize plan (M' = M, no curriculum) lowers to the PR 7
    fault engine bit-exactly across all 7 schedules;
  - a shrink + grow mid-run is bitwise identical across the scan
    triple (flat-native / flat / tree carries);
  - resume-across-resize (through a v5 checkpoint) == uninterrupted;
  - a shrink-then-grow round trip restores a bit-identical layout;
  - grow curricula: grown rows train solo, out of the consensus, until
    their window closes;
  - straggle-aware adaptive scheduling discounts straggler-widened
    dispersion (fires <= unaware; bit-exact no-op without stragglers;
    refused for non-adaptive kinds);
  - checkpoint v0-v5 ladder round-trip for the resized case, plane-M
    mismatch refused with both Ms named and the resize API pointed at;
  - the calibrated post-resize dispersion prediction
    (variance_model.predict_post_resize_dispersion) against a
    simulated K-step window;
  - sharded resize under shard_map with both psum and gather
    collectives (subprocess with 8 host devices, like test_faults).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_engine_state, save_engine_state
from repro.checkpoint.io import ENGINE_STATE_VERSION
from repro.core import PhaseEngine
from repro.core.averaging import AveragingSchedule
from repro.core.compress import Compression
from repro.core.variance_model import (predict_averaging_benefit,
                                       predict_post_resize_dispersion)
from repro.elastic import (ElasticPlan, ResizeEvent, grow_state,
                           resize_engine, run_elastic, segment_engine,
                           shrink_state)
from repro.faults import FaultPlan, FaultState
from repro.optim import SGD, Momentum
from repro.topology import Topology

DIM, WORKERS, STEPS = 8, 4, 24

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _loss_fn(params, batch, rng):
    x, y = batch
    r = x @ params["w"] - y
    return jnp.mean(r * r), {}


def _params():
    return {"w": jnp.zeros((DIM,), jnp.float32)}


def _block(steps=STEPS, m=WORKERS, seed=0):
    """One fixed (steps, m, batch, ...) data block; every engine and
    every segment slices the same arrays, so comparisons are over
    identical batches."""
    rng = np.random.default_rng(seed)
    w_true = rng.standard_normal(DIM)
    x = rng.standard_normal((steps, m, 16, DIM)).astype(np.float32)
    y = (x @ w_true + 0.1 * rng.standard_normal(
        (steps, m, 16))).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y)


def _factory(block):
    x, y = block

    def data(m, t0, k):
        return [(x[t, :m], y[t, :m]) for t in range(t0 - 1, t0 - 1 + k)]
    return data


def _batches(block, m=WORKERS):
    return _factory(block)(m, 1, block[0].shape[0])


_PLAN = "crash:m=1@t=6,rejoin:m=1@t=14"

SCHEDS = {
    "oneshot": AveragingSchedule("oneshot"),
    "minibatch": AveragingSchedule("minibatch"),
    "periodic": AveragingSchedule("periodic", 8),
    "stochastic": AveragingSchedule("stochastic", zeta=0.2),
    "hierarchical": AveragingSchedule("hierarchical", inner_phase_len=4,
                                      outer_phase_len=8, inner_groups=2),
    "adaptive_threshold": AveragingSchedule("adaptive_threshold",
                                            disp_threshold=0.05),
    "adaptive_budget": AveragingSchedule("adaptive_budget", comm_budget=4,
                                         budget_horizon=STEPS),
}


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --------------------------------------------------------------------------
# ElasticPlan validation / parsing
# --------------------------------------------------------------------------

class TestElasticPlan:
    def test_parse_roundtrip(self):
        plan = ElasticPlan.parse(4, shrink_at=["8:3"], grow_at=["16:4"],
                                 curriculum=2)
        assert plan.resizes == (ResizeEvent(8, 3), ResizeEvent(16, 4))
        assert plan.curriculum == 2
        assert not plan.is_trivial
        assert plan.sizes() == (4, 3, 4)

    def test_noop_plan_is_trivial(self):
        plan = ElasticPlan(4, ((10, 4),))
        assert plan.is_trivial
        assert plan.sizes() == (4,)

    @pytest.mark.parametrize("kw,match", [
        (dict(shrink_at=["8:6"]), "would grow"),
        (dict(grow_at=["8:2"]), "would shrink"),
        (dict(shrink_at=["bogus"]), "cannot parse"),
        (dict(shrink_at=["8:3"], grow_at=["8:4"]), "strictly increasing"),
        (dict(shrink_at=["1:3"]), "strictly increasing|>= 2"),
        (dict(shrink_at=["8:0"]), "must be >= 1"),
        (dict(shrink_at=["8:3"], curriculum=-1), "curriculum"),
    ])
    def test_invalid_plans_refused(self, kw, match):
        with pytest.raises(ValueError, match=match):
            ElasticPlan.parse(4, **kw)

    def test_segments(self):
        plan = ElasticPlan(4, ((8, 3), (16, 4)))
        segs = plan.segments(24)
        assert [(s.start, s.stop, s.num_workers) for s in segs] == \
            [(1, 8, 4), (8, 16, 3), (16, 25, 4)]
        # resizes beyond the horizon are ignored
        assert len(plan.segments(7)) == 1

    def test_solo_windows(self):
        plan = ElasticPlan(4, ((8, 3), (16, 4)), curriculum=3)
        assert plan.solo_windows() == ((3, 16, 19),)
        assert ElasticPlan(4, ((8, 3), (16, 4))).solo_windows() == ()

    def test_segment_faults_compose_with_base(self):
        base = FaultPlan.parse(_PLAN, 4, straggle_prob=0.1)
        plan = ElasticPlan(4, ((8, 3), (16, 4)), curriculum=2)
        fp3 = plan.segment_faults(base, 3, 8, 16)
        assert fp3.num_workers == 3
        assert all(ev.worker < 3 for ev in fp3.events)
        assert fp3.straggle_prob == 0.1
        fp4 = plan.segment_faults(base, 4, 16, 25)
        assert (3, 16, 18) in fp4.solo
        # a window from another segment's grow is not dragged along
        fp_pre = plan.segment_faults(base, 4, 1, 8)
        assert fp_pre.solo == ()

    def test_segment_faults_trivial_lowering(self):
        plan = ElasticPlan(4, ((8, 3),))
        assert plan.segment_faults(None, 3, 8, 25) is None

    def test_base_plan_m_mismatch_refused(self):
        plan = ElasticPlan(4, ((8, 3),))
        with pytest.raises(ValueError, match="elastic plan starts at"):
            plan.segment_faults(FaultPlan(8), 3)


# --------------------------------------------------------------------------
# Row repacking: permutation-exact pack/unpack
# --------------------------------------------------------------------------

def _rand_state(rng, m):
    """A fake EngineState-shaped carrier with random bit patterns."""
    eng = PhaseEngine(_loss_fn, Momentum(0.05, 0.9),
                      AveragingSchedule("periodic", 8),
                      compression=Compression("int8"),
                      faults=FaultPlan.parse(_PLAN, m))
    state = eng.init(_params(), m, 0)
    noise = lambda x: jnp.asarray(
        rng.standard_normal(x.shape).astype(np.asarray(x).dtype))
    return state._replace(
        worker_params=jax.tree.map(noise, state.worker_params),
        opt_state=jax.tree.map(noise, state.opt_state),
        resid=noise(state.resid))


def _check_repack(state, new_m, old_m):
    small = shrink_state(state, new_m)
    for a, b in zip(jax.tree.leaves(small.worker_params)
                    + jax.tree.leaves(small.opt_state)
                    + [small.resid],
                    jax.tree.leaves(state.worker_params)
                    + jax.tree.leaves(state.opt_state)
                    + [state.resid]):
        assert np.asarray(a).shape[0] == new_m
        np.testing.assert_array_equal(np.asarray(a),
                                      np.asarray(b)[:new_m])
    big = grow_state(small, old_m, optimizer=Momentum(0.05, 0.9))
    for a, b in zip(jax.tree.leaves(big.worker_params),
                    jax.tree.leaves(small.worker_params)):
        a = np.asarray(a)
        assert a.shape[0] == old_m
        np.testing.assert_array_equal(a[:new_m], np.asarray(b))
        # every appended row is the same consensus vector
        for r in range(new_m, old_m):
            np.testing.assert_array_equal(a[r], a[new_m] if new_m < old_m
                                          else a[r])
    for s in jax.tree.leaves(big.opt_state) + [big.resid]:
        assert not np.asarray(s)[new_m:].any()  # zeroed for new rows


class TestRepack:
    def test_repack_numpy_cases(self):
        rng = np.random.default_rng(0)
        for new_m in (1, 2, 3, 4):
            _check_repack(_rand_state(rng, 4), new_m, 4)

    @pytest.mark.skipif(not HAVE_HYPOTHESIS,
                        reason="hypothesis not installed")
    def test_repack_property(self):
        @settings(max_examples=20, deadline=None)
        @given(st.integers(2, 6), st.data())
        def prop(old_m, data):
            new_m = data.draw(st.integers(1, old_m))
            rng = np.random.default_rng(data.draw(st.integers(0, 2**16)))
            _check_repack(_rand_state(rng, old_m), new_m, old_m)
        prop()

    def test_shrink_refuses_all_dead(self):
        eng = PhaseEngine(_loss_fn, SGD(0.05),
                          AveragingSchedule("periodic", 8),
                          faults=FaultPlan.parse(_PLAN, 4))
        state = eng.init(_params(), 4, 0)
        dead = state._replace(fault=FaultState(
            jnp.asarray([0.0, 0.0, 1.0, 1.0]),
            state.fault.staleness))
        with pytest.raises(ValueError, match="no alive worker"):
            shrink_state(dead, 2)

    def test_shrink_grow_bounds(self):
        state = PhaseEngine(_loss_fn, SGD(0.05),
                            AveragingSchedule("periodic", 8)).init(
                                _params(), 4, 0)
        with pytest.raises(ValueError, match="cannot shrink"):
            shrink_state(state, 5)
        with pytest.raises(ValueError, match="cannot grow"):
            grow_state(state, 3, optimizer=SGD(0.05))


# --------------------------------------------------------------------------
# Engine integration
# --------------------------------------------------------------------------

class TestElasticEngine:
    @pytest.mark.parametrize("sname", list(SCHEDS))
    def test_noop_resize_bitwise_equals_fault_engine(self, sname):
        """A no-op resize (M' = M, no curriculum) lowers to the PR 7
        fault engine bit-exactly: segment boundaries are phase cuts."""
        block = _block()
        plan = FaultPlan.parse(_PLAN, WORKERS, straggle_prob=0.1)
        eng = PhaseEngine(_loss_fn, SGD(0.05), SCHEDS[sname],
                          faults=plan)
        f0, h0 = eng.run(_params(), _batches(block), num_workers=WORKERS,
                         seed=0, record_every=1)
        f1, h1 = run_elastic(eng, _params(), _factory(block),
                             ElasticPlan(WORKERS, ((10, WORKERS),)),
                             steps=STEPS, seed=0, record_every=1)
        _leaves_equal(f0, f1)
        assert h1["resizes"] == []
        assert h0["loss"] == h1["loss"]
        assert h0["dispersion"] == h1["dispersion"]
        assert h0["averages"] == h1["averages"]

    def test_resize_bitwise_across_scan_triple(self):
        """shrink 4->3 @8 then grow ->4 @16 (curriculum 2, straggle,
        base faults) is bitwise identical across the flat-native, flat
        and tree carries."""
        block = _block()
        base = FaultPlan.parse(_PLAN, WORKERS, straggle_prob=0.1)
        plan = ElasticPlan(WORKERS, ((8, 3), (16, 4)), curriculum=2)
        outs = []
        for kw in ({}, dict(fused_opt=False), dict(flat=False)):
            eng = PhaseEngine(_loss_fn, Momentum(0.05, 0.9),
                              AveragingSchedule("periodic", 8),
                              faults=base, **kw)
            outs.append(run_elastic(eng, _params(), _factory(block),
                                    plan, steps=STEPS, seed=0,
                                    record_every=1, return_state=True))
        for f, h, st_ in outs[1:]:
            _leaves_equal(outs[0][0], f)
            _leaves_equal(outs[0][2].worker_params, st_.worker_params)
            assert h["loss"] == outs[0][1]["loss"]
            assert h["resizes"] == [(8, 4, 3), (16, 3, 4)]

    def test_hierarchical_resize(self):
        """Hierarchical inner groups keep dividing every segment M."""
        block = _block()
        plan = ElasticPlan(WORKERS, ((8, 2), (16, 4)), curriculum=2)
        eng = PhaseEngine(_loss_fn, SGD(0.05), SCHEDS["hierarchical"])
        f, h = run_elastic(eng, _params(), _factory(block), plan,
                           steps=STEPS, seed=0, record_every=4)
        assert h["resizes"] == [(8, 4, 2), (16, 2, 4)]
        assert np.isfinite(h["loss"][-1][1])
        bad = ElasticPlan(WORKERS, ((8, 3),))
        with pytest.raises(ValueError, match="inner_groups"):
            run_elastic(eng, _params(), _factory(block), bad,
                        steps=STEPS)

    def test_resume_across_resize_bitwise(self, tmp_path):
        """Checkpoint mid-segment (after a resize), resume through a
        v5 save: bitwise == uninterrupted, including the grow-back."""
        block = _block()
        base = FaultPlan.parse(_PLAN, WORKERS, straggle_prob=0.1)
        plan = ElasticPlan(WORKERS, ((8, 3), (16, 4)), curriculum=2)
        eng = PhaseEngine(_loss_fn, Momentum(0.05, 0.9),
                          AveragingSchedule("periodic", 8), faults=base)
        fac = _factory(block)
        f_full, h_full, st_full = run_elastic(
            eng, _params(), fac, plan, steps=STEPS, seed=0,
            record_every=1, return_state=True)
        for cut in (8, 12, 16):  # boundary, mid-segment, boundary
            _, _, st_mid = run_elastic(eng, _params(), fac, plan,
                                       steps=cut, seed=0,
                                       return_state=True)
            path = str(tmp_path / f"ck{cut}")
            save_engine_state(path, st_mid, elastic=True)
            seg_eng, m = segment_engine(eng, plan, cut, STEPS)
            loaded, at = load_engine_state(
                path, seg_eng.init(_params(), m, 0))
            assert at == cut
            f_res, _, st_res = run_elastic(
                eng, _params(), fac, plan, steps=STEPS, seed=0,
                record_every=1, state=loaded, return_state=True)
            _leaves_equal(f_full, f_res)
            _leaves_equal(st_full.worker_params, st_res.worker_params)
            _leaves_equal(st_full.opt_state, st_res.opt_state)

    def test_shrink_grow_round_trip_restores_layout(self):
        """A shrink-then-grow round trip restores a bit-identical
        layout: same treedef, shapes, dtypes as the never-resized
        state, kept rows bitwise preserved through the trip."""
        rng = np.random.default_rng(1)
        state = _rand_state(rng, WORKERS)
        trip = grow_state(shrink_state(state, 3), WORKERS,
                          optimizer=Momentum(0.05, 0.9))
        assert (jax.tree.structure(trip._asdict())
                == jax.tree.structure(state._asdict()))
        for a, b in zip(jax.tree.leaves(trip), jax.tree.leaves(state)):
            assert np.asarray(a).shape == np.asarray(b).shape
            assert np.asarray(a).dtype == np.asarray(b).dtype
        for a, b in zip(jax.tree.leaves(trip.worker_params),
                        jax.tree.leaves(state.worker_params)):
            np.testing.assert_array_equal(np.asarray(a)[:3],
                                          np.asarray(b)[:3])

    def test_grow_curriculum_masks_consensus(self):
        """During its curriculum window a grown row trains (its iterate
        moves) but stays out of the consensus."""
        block = _block()
        plan = ElasticPlan(WORKERS, ((8, 3), (16, 4)), curriculum=6)
        eng = PhaseEngine(_loss_fn, SGD(0.05),
                          AveragingSchedule("periodic", 4))
        # stop inside the window: steps 16..18 done, window is [16, 22)
        f, h, st_ = run_elastic(eng, _params(), _factory(block), plan,
                                steps=18, seed=0, return_state=True)
        wp = np.asarray(st_.worker_params["w"])
        grown_at_16 = np.asarray(  # row 3's warm-start == consensus @15
            grow_state(run_elastic(eng, _params(), _factory(block),
                                   plan, steps=15, seed=0,
                                   return_state=True)[2],
                       WORKERS, optimizer=SGD(0.05)).worker_params["w"])[3]
        assert not np.array_equal(wp[3], grown_at_16)  # it trained
        np.testing.assert_array_equal(np.asarray(f["w"]),
                                      wp[:3].mean(axis=0))  # excluded

    def test_straggle_aware_discounts_dispersion(self):
        block = _block()
        base = FaultPlan(WORKERS, (), 0.4)
        naive = AveragingSchedule("adaptive_threshold",
                                  disp_threshold=0.05)
        aware = AveragingSchedule("adaptive_threshold",
                                  disp_threshold=0.05,
                                  straggle_aware=True)
        runs = {}
        for name, sched in (("naive", naive), ("aware", aware)):
            eng = PhaseEngine(_loss_fn, SGD(0.05), sched, faults=base)
            runs[name] = eng.run(_params(), _batches(block),
                                 num_workers=WORKERS, seed=0,
                                 record_every=1)
        assert runs["aware"][1]["averages"] <= \
            runs["naive"][1]["averages"]
        # the recorded dispersion trace is the TRUE diagnostic, not the
        # discounted one — identical wherever both runs took the same
        # averaging decisions
        t_aware = dict(runs["aware"][1]["disp_trace"])
        t_naive = dict(runs["naive"][1]["disp_trace"])
        assert t_aware[1] == t_naive[1]

    def test_straggle_aware_without_stragglers_is_noop(self):
        """No straggle probability -> disp_scale is exactly 1, and the
        aware run is bit-identical to the unaware one."""
        block = _block()
        base = FaultPlan.parse(_PLAN, WORKERS)  # events, no straggle
        outs = []
        for flag in (False, True):
            sched = AveragingSchedule("adaptive_threshold",
                                      disp_threshold=0.05,
                                      straggle_aware=flag)
            eng = PhaseEngine(_loss_fn, SGD(0.05), sched, faults=base)
            outs.append(eng.run(_params(), _batches(block),
                                num_workers=WORKERS, seed=0,
                                record_every=1))
        _leaves_equal(outs[0][0], outs[1][0])
        assert outs[0][1]["loss"] == outs[1][1]["loss"]

    def test_straggle_aware_refused_for_static_kinds(self):
        with pytest.raises(ValueError, match="straggle_aware"):
            AveragingSchedule("periodic", 8, straggle_aware=True)

    def test_elastic_with_outer_refused(self):
        from repro.core import OuterOptimizer
        eng = PhaseEngine(_loss_fn, SGD(0.05),
                          AveragingSchedule("periodic", 8),
                          outer=OuterOptimizer(lr=1.0, momentum=0.5))
        with pytest.raises(ValueError, match="outer"):
            run_elastic(eng, _params(), _factory(_block()),
                        ElasticPlan(WORKERS, ((8, 3),)), steps=STEPS)

    def test_fault_plan_m_mismatch_refused(self):
        eng = PhaseEngine(_loss_fn, SGD(0.05),
                          AveragingSchedule("periodic", 8),
                          faults=FaultPlan(8))
        with pytest.raises(ValueError, match="elastic plan starts at"):
            run_elastic(eng, _params(), _factory(_block()),
                        ElasticPlan(WORKERS, ((8, 3),)), steps=STEPS)

    def test_completed_state_refused(self):
        block = _block()
        eng = PhaseEngine(_loss_fn, SGD(0.05),
                          AveragingSchedule("periodic", 8))
        plan = ElasticPlan(WORKERS, ((8, 3),))
        _, _, st_ = run_elastic(eng, _params(), _factory(block), plan,
                                steps=STEPS, seed=0, return_state=True)
        with pytest.raises(ValueError, match="already completed"):
            run_elastic(eng, _params(), _factory(block), plan,
                        steps=STEPS, state=st_)

    def test_resize_engine_rebuilds_topology(self):
        eng = PhaseEngine(_loss_fn, SGD(0.05),
                          AveragingSchedule("periodic", 8),
                          topology=Topology.full(WORKERS))
        small = resize_engine(eng, 3)
        assert small.topology.num_workers == 3
        assert small.topology.kind == "full"
        with pytest.raises(ValueError, match="ring"):
            resize_engine(PhaseEngine(
                _loss_fn, SGD(0.05), AveragingSchedule("periodic", 8),
                topology=Topology.ring(WORKERS)), 2)


# --------------------------------------------------------------------------
# Checkpoints: v5 + the M-mismatch refusal + the resized ladder
# --------------------------------------------------------------------------

class TestElasticCheckpoint:
    def _resized_state(self):
        block = _block()
        base = FaultPlan.parse(_PLAN, WORKERS, straggle_prob=0.1)
        plan = ElasticPlan(WORKERS, ((8, 3),), curriculum=2)
        eng = PhaseEngine(_loss_fn, SGD(0.05),
                          AveragingSchedule("periodic", 8), faults=base,
                          compression=Compression("int8"))
        _, _, st_ = run_elastic(eng, _params(), _factory(block), plan,
                                steps=12, seed=0, return_state=True)
        seg_eng, m = segment_engine(eng, plan, 12, STEPS)
        assert m == 3
        return st_, seg_eng, m

    def test_elastic_save_is_v5(self, tmp_path):
        import json
        st_, seg_eng, m = self._resized_state()
        path = str(tmp_path / "ck")
        save_engine_state(path, st_, elastic=True)
        meta = json.load(open(path + ".json"))["extra"]
        assert meta["engine_state_version"] == ENGINE_STATE_VERSION == 5
        assert meta["num_workers"] == 3
        assert meta["has_fault"] and meta["has_resid"]
        loaded, at = load_engine_state(path,
                                       seg_eng.init(_params(), m, 0))
        assert at == 12
        _leaves_equal(loaded.worker_params, st_.worker_params)

    def test_fixed_membership_saves_keep_v4(self, tmp_path):
        """Non-elastic fault saves still write the lowest version that
        describes their layout (v4) — loadable by older builds."""
        import json
        block = _block()
        eng = PhaseEngine(_loss_fn, SGD(0.05),
                          AveragingSchedule("periodic", 8),
                          faults=FaultPlan.parse(_PLAN, WORKERS))
        _, _, st_ = eng.run(_params(), _batches(block),
                            num_workers=WORKERS, seed=0,
                            return_state=True)
        path = str(tmp_path / "ck")
        save_engine_state(path, st_)
        assert json.load(open(path + ".json"))["extra"][
            "engine_state_version"] == 4

    def test_m_mismatch_refused_with_both_ms(self, tmp_path):
        st_, seg_eng, m = self._resized_state()
        path = str(tmp_path / "ck")
        save_engine_state(path, st_, elastic=True)
        full_eng = PhaseEngine(_loss_fn, SGD(0.05),
                               AveragingSchedule("periodic", 8))
        with pytest.raises(ValueError) as e:
            load_engine_state(path, full_eng.init(_params(), WORKERS, 0))
        msg = str(e.value)
        assert "3-row" in msg and "4 rows" in msg
        assert "repro.elastic" in msg

    def test_m_mismatch_refused_for_pre_v5_saves(self, tmp_path):
        """Older checkpoints carry no num_workers metadata — the shape
        table still names both Ms instead of an opaque assert."""
        import json
        st_, _, _ = self._resized_state()
        path = str(tmp_path / "ck")
        save_engine_state(path, st_)  # v4: no num_workers guarantee
        meta = json.load(open(path + ".json"))
        meta["extra"].pop("num_workers", None)
        json.dump(meta, open(path + ".json", "w"))
        full_eng = PhaseEngine(
            _loss_fn, SGD(0.05), AveragingSchedule("periodic", 8),
            faults=FaultPlan.parse(_PLAN, WORKERS),
            compression=Compression("int8"))
        with pytest.raises(ValueError, match="repro.elastic"):
            load_engine_state(path, full_eng.init(_params(), WORKERS, 0))

    def test_version_ladder_round_trip_resized(self, tmp_path):
        """v0-v5 ladder for the RESIZED (M=3) case: every stripped
        layout loads back into the resized like-state, missing fields
        starting fresh."""
        import json
        st_, seg_eng, m = self._resized_state()
        like = seg_eng.init(_params(), m, 0)
        cases = {
            0: st_._replace(sched=(), resid=(), fault=()),
            2: st_._replace(resid=(), fault=()),
            3: st_._replace(fault=()),
            4: st_,
        }
        for want_version, stripped in cases.items():
            path = str(tmp_path / f"v{want_version}")
            save_engine_state(path, stripped)
            meta = json.load(open(path + ".json"))["extra"]
            assert meta["engine_state_version"] == want_version
            loaded, at = load_engine_state(path, like)
            assert at == 12
            _leaves_equal(loaded.worker_params, st_.worker_params)
        path = str(tmp_path / "v5")
        save_engine_state(path, st_, elastic=True)
        loaded, at = load_engine_state(path, like)
        _leaves_equal(loaded.opt_state, st_.opt_state)
        _leaves_equal(loaded.fault, st_.fault)


class TestTrainCliElastic:
    """train.py elastic/straggle flags fail at parse time (argparse
    error, exit code 2) instead of deep inside a trace."""

    def _error(self, argv):
        from repro.launch.train import main
        with pytest.raises(SystemExit) as e:
            main(argv)
        assert e.value.code == 2

    def test_bad_resize_terms(self):
        self._error(["--shrink-at", "bogus"])
        self._error(["--workers", "4", "--shrink-at", "8:6"])
        self._error(["--workers", "4", "--grow-at", "8:2"])
        self._error(["--workers", "4", "--shrink-at", "8:3",
                     "--grow-at", "8:4"])

    def test_elastic_outer_conflict(self):
        self._error(["--workers", "4", "--shrink-at", "8:3",
                     "--outer-momentum", "0.5"])

    def test_resize_target_vs_schedule_and_topology(self):
        self._error(["--workers", "4", "--shrink-at", "8:3",
                     "--avg", "hierarchical", "--phase-len", "4",
                     "--outer-phase-len", "8", "--inner-groups", "2"])
        self._error(["--workers", "4", "--shrink-at", "8:2",
                     "--topology", "ring"])

    def test_orphan_rejoin_curriculum(self):
        self._error(["--rejoin-curriculum", "-1"])
        self._error(["--workers", "4", "--rejoin-curriculum", "3"])

    def test_straggle_aware_needs_adaptive_and_stragglers(self):
        self._error(["--straggle-aware", "--avg", "periodic",
                     "--straggle-prob", "0.1"])
        self._error(["--straggle-aware", "--avg", "adaptive_threshold",
                     "--disp-threshold", "0.05"])


# --------------------------------------------------------------------------
# Calibrated post-resize dispersion prediction
# --------------------------------------------------------------------------

class TestPostResizePrediction:
    def test_sgd_noise_window(self):
        """Pure-noise SGD from a shared start: measured K-step
        dispersion within 2x of the K-weighted prediction."""
        rng = np.random.default_rng(0)
        n, dim, k, lr, sigma = 8, 512, 8, 0.1, 0.7
        w = np.zeros((n, dim))
        for _ in range(k):
            w -= lr * sigma * rng.standard_normal((n, dim))
        disp = float((np.linalg.norm(w - w.mean(0), axis=1) ** 2).mean())
        pred = predict_post_resize_dispersion(
            [sigma * sigma * dim] * n, lr=lr, steps=k)
        assert pred["k"] == k
        assert pred["drift_dispersion"] == 0.0
        assert 0.5 < disp / pred["predicted_dispersion"] < 2.0

    def test_drift_term_quadratic_in_k(self):
        p4 = predict_post_resize_dispersion([0.0] * 4, lr=0.1, steps=4,
                                            drift2=1.0)
        p8 = predict_post_resize_dispersion([0.0] * 4, lr=0.1, steps=8,
                                            drift2=1.0)
        assert p8["drift_dispersion"] == pytest.approx(
            4.0 * p4["drift_dispersion"])
        # noise term is linear in K instead
        n4 = predict_post_resize_dispersion([1.0] * 4, lr=0.1, steps=4)
        n8 = predict_post_resize_dispersion([1.0] * 4, lr=0.1, steps=8)
        assert n8["noise_dispersion"] == pytest.approx(
            2.0 * n4["noise_dispersion"])

    def test_curvature_discounts_drift(self):
        """A positive curvature contracts the coherent drift (each
        local step descends the shard objective); curvature 0 keeps
        the raw quadratic budget, and the noise term never changes."""
        raw = predict_post_resize_dispersion([1.0] * 4, lr=0.1, steps=8,
                                             drift2=1.0)
        disc = predict_post_resize_dispersion([1.0] * 4, lr=0.1, steps=8,
                                              drift2=1.0, curvature=2.0)
        assert disc["drift_dispersion"] < raw["drift_dispersion"]
        assert disc["noise_dispersion"] == raw["noise_dispersion"]
        with pytest.raises(ValueError, match="curvature"):
            predict_post_resize_dispersion([1.0], lr=0.1, steps=4,
                                           curvature=11.0)

    def test_momentum_weights_exceed_sgd(self):
        sgd = predict_post_resize_dispersion([1.0] * 4, lr=0.1, steps=8)
        mom = predict_post_resize_dispersion([1.0] * 4, lr=0.1, steps=8,
                                             momentum=0.9)
        assert mom["predicted_dispersion"] > sgd["predicted_dispersion"]

    def test_merged_into_predict_averaging_benefit(self):
        out = predict_averaging_benefit([1.0] * 4, lr=0.1, steps=8,
                                        drift2=0.5)
        assert "predicted_dispersion" in out and "benefit" in out

    def test_validation(self):
        with pytest.raises(ValueError, match="steps"):
            predict_post_resize_dispersion([1.0], lr=0.1, steps=0)
        with pytest.raises(ValueError, match="momentum"):
            predict_post_resize_dispersion([1.0], lr=0.1, steps=4,
                                           momentum=1.0)


# --------------------------------------------------------------------------
# Sharded resize (subprocess, 8 host devices)
# --------------------------------------------------------------------------

_SHARD_SCRIPT = r"""
import jax
import jax.numpy as jnp
import numpy as np
from repro.core import AveragingSchedule, PhaseEngine, FaultPlan
from repro.elastic import ElasticPlan, run_elastic
from repro.optim import SGD

assert len(jax.devices()) == 8, jax.devices()
DIM, WORKERS, STEPS = 8, 4, 16
rng = np.random.default_rng(0)
w_true = rng.standard_normal(DIM)
bx = jnp.asarray(rng.standard_normal(
    (STEPS, WORKERS, 16, DIM)).astype(np.float32))
by = jnp.asarray((np.asarray(bx) @ w_true).astype(np.float32))

def loss_fn(params, batch, rng):
    x, y = batch
    r = x @ params["w"] - y
    return jnp.mean(r * r), {}

def factory(m, t0, k):
    return [(bx[t, :m], by[t, :m]) for t in range(t0 - 1, t0 - 1 + k)]

params = {"w": jnp.zeros((DIM,), jnp.float32)}
plan = FaultPlan.parse("crash:m=1@t=4,rejoin:m=1@t=10", WORKERS,
                       straggle_prob=0.1)
kw = dict(steps=STEPS, seed=3, record_every=1)
noop = ElasticPlan(WORKERS, ((8, WORKERS),))

# SGD keeps the shard_map programs bitwise (see test_faults); the
# elastic layer only adds phase cuts and host-side row repacks
from repro.launch.mesh import make_worker_mesh
SCHEDS = {
    "oneshot": AveragingSchedule("oneshot"),
    "minibatch": AveragingSchedule("minibatch"),
    "periodic": AveragingSchedule("periodic", 8),
    "stochastic": AveragingSchedule("stochastic", zeta=0.2),
    "hierarchical": AveragingSchedule("hierarchical", inner_phase_len=4,
                                      outer_phase_len=8, inner_groups=2),
    "adaptive_threshold": AveragingSchedule("adaptive_threshold",
                                            disp_threshold=0.05),
    "adaptive_budget": AveragingSchedule("adaptive_budget", comm_budget=4,
                                         budget_horizon=STEPS),
}
for sname, sched in SCHEDS.items():
    for coll in ("psum", "gather"):
        mesh = make_worker_mesh(WORKERS)
        eng = PhaseEngine(loss_fn, SGD(0.05), sched, faults=plan,
                          mesh=mesh, collective=coll)
        f0, h0 = eng.run(params, factory(WORKERS, 1, STEPS),
                         num_workers=WORKERS, seed=3, record_every=1)
        f1, h1 = run_elastic(eng, params, factory, noop, **kw)
        np.testing.assert_array_equal(np.asarray(f0["w"]),
                                      np.asarray(f1["w"]))
        assert h0["loss"] == h1["loss"], (sname, coll)
        assert h0["averages"] == h1["averages"]
        print("noop-ok", sname, coll)

# a real resize under both collectives: gather matches the unsharded
# elastic run bitwise; psum agrees to f32 roundoff
resize = ElasticPlan(WORKERS, ((6, 3), (12, 4)), curriculum=2)
eng0 = PhaseEngine(loss_fn, SGD(0.05), AveragingSchedule("periodic", 4),
                   faults=plan)
fu, hu = run_elastic(eng0, params, factory, resize, **kw)
for coll in ("gather", "psum"):
    eng = PhaseEngine(loss_fn, SGD(0.05), AveragingSchedule("periodic", 4),
                      faults=plan, mesh=make_worker_mesh(WORKERS),
                      collective=coll)
    fs, hs = run_elastic(eng, params, factory, resize, **kw)
    assert hs["resizes"] == [(6, 4, 3), (12, 3, 4)]
    if coll == "gather":
        np.testing.assert_array_equal(np.asarray(fu["w"]),
                                      np.asarray(fs["w"]))
        assert hu["loss"] == hs["loss"]
    else:
        np.testing.assert_allclose(np.asarray(fu["w"]),
                                   np.asarray(fs["w"]),
                                   rtol=1e-5, atol=1e-6)
        assert hu["averages"] == hs["averages"]
    print("resize-ok", coll)
print("ALL-OK")
"""


def test_sharded_resize_both_collectives():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALL-OK" in out.stdout

"""Gossip topology subsystem: mixing-matrix averaging.

System-level guarantees pinned here:

  1. Every builder yields a symmetric, doubly-stochastic W whose
     declared spectral gap matches the matrix spectrum (deterministic
     sweep; tests/test_topology_properties.py re-checks under
     hypothesis).
  2. ``Topology.full`` reproduces the existing mean path BIT-exactly —
     params and full history — for all 7 schedules and all four engine
     paths (flat-native, flat, tree, host loop), and ``groups`` is the
     ``inner_groups`` block mean as a block-diagonal W.
  3. The mix kernels agree with their jnp twins and the tree operator,
     and one mix event contracts the dispersion by at most λ₂².
  4. All engine paths replay identical decision streams and agree on
     the final params for sparse topologies (incl. the per-event
     random gossip matching, a pure function of (dec_key, step)).
  5. Checkpoint/resume with a gossip topology is bit-identical to the
     uninterrupted run — the matching stream needs no extra state.
  6. Invalid topology/worker combinations fail eagerly (builders,
     engine, and train.py at parse time).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_engine_state, save_engine_state
from repro.core import (AveragingSchedule, OuterOptimizer, PhaseEngine,
                        Topology)
from repro.core.averaging import average_inner
from repro.core.theory import (coarse_dispersion_bound, mixing_contraction,
                               mixed_dispersion_fixed_point)
from repro.data.pipeline import DeviceDataset
from repro.kernels.avg_disp import mix_disp
from repro.kernels.opt_step import opt_step
from repro.kernels.ref import mix_disp_ref, opt_step_ref
from repro.optim import SGD, Momentum
from repro.topology import gossip_matrix, mix_tree

WORKERS, STEPS, DIM, SAMPLES = 8, 33, 12, 256


def _convex_problem(seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((SAMPLES, DIM))
    y = X @ rng.standard_normal(DIM) + 0.1 * rng.standard_normal(SAMPLES)
    return X, y


def _loss_fn(params, batch, rng):
    r = batch["x"] @ params["w"] - batch["y"]
    return 0.5 * jnp.mean(r * r), {}


def _params():
    return {"w": jnp.zeros(DIM)}


def _batches(seed=1, steps=STEPS, workers=WORKERS):
    X, y = _convex_problem()
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, SAMPLES, (steps, workers, 8))
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    return [{"x": Xj[idx[t]], "y": yj[idx[t]]} for t in range(steps)]


def _slem(W):
    ev = np.linalg.eigvalsh(np.asarray(W, np.float64))
    return max(abs(ev[0]), ev[-2])


SCHEDULES = {
    "oneshot": AveragingSchedule("oneshot"),
    "minibatch": AveragingSchedule("minibatch"),
    "periodic": AveragingSchedule("periodic", 4),
    "stochastic": AveragingSchedule("stochastic", zeta=0.2),
    "hierarchical": AveragingSchedule("hierarchical", inner_phase_len=3,
                                      outer_phase_len=12, inner_groups=2),
    "adaptive_threshold": AveragingSchedule("adaptive_threshold",
                                            disp_threshold=0.05,
                                            disp_ema_beta=0.5),
    "adaptive_budget": AveragingSchedule("adaptive_budget", comm_budget=6,
                                         budget_horizon=STEPS),
}

BUILDER_CASES = [("full", 4), ("full", 7), ("ring", 3), ("ring", 8),
                 ("ring", 13), ("torus", 4), ("torus", 6), ("torus", 16),
                 ("hypercube", 2), ("hypercube", 8), ("hypercube", 16),
                 ("groups", 8), ("groups", 12), ("disconnected", 4),
                 ("gossip_pairs", 2), ("gossip_pairs", 8),
                 ("gossip_pairs", 16)]


# --------------------------------------------------------------------------
# builders: doubly-stochastic W + declared spectral gap
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind,m", BUILDER_CASES)
def test_builders_doubly_stochastic_symmetric_with_declared_gap(kind, m):
    t = Topology.build(kind, m, groups=2)
    W = t.expected_matrix()
    assert W.shape == (m, m)
    np.testing.assert_allclose(W, W.T, atol=1e-12)
    np.testing.assert_allclose(W.sum(axis=1), np.ones(m), atol=1e-12)
    np.testing.assert_allclose(W.sum(axis=0), np.ones(m), atol=1e-12)
    assert (W >= -1e-12).all()
    # the declared gap is 1 - SLEM of (the expectation of) W
    np.testing.assert_allclose(t.spectral_gap, 1.0 - _slem(W), atol=1e-9)
    assert 0.0 <= t.spectral_gap <= 1.0 + 1e-9


def test_known_spectral_gaps():
    assert Topology.full(8).spectral_gap == pytest.approx(1.0)
    assert Topology.disconnected(8).spectral_gap == pytest.approx(0.0)
    # groups > 1 is a disconnected graph: no global consensus direction
    assert Topology.blocks(8, 2).spectral_gap == pytest.approx(0.0)
    assert Topology.blocks(8, 1).spectral_gap == pytest.approx(1.0)
    # ring with uniform 1/3 weights: lambda_2 = (1 + 2 cos(2pi/M)) / 3
    m = 8
    lam2 = (1 + 2 * np.cos(2 * np.pi / m)) / 3
    assert Topology.ring(m).spectral_gap == pytest.approx(1 - lam2)
    # gossip E[W] spectrum: 1 and (1/2)(1 - 1/(M-1))
    assert Topology.gossip_pairs(m).spectral_gap == pytest.approx(
        0.5 + 0.5 / (m - 1))
    # hypercube with uniform 1/(d+1) weights: lambda_2 = 1 - 2/(d+1),
    # so the gap decays only logarithmically in M (d = log2 M) — the
    # exponential graph's scaling advantage over ring/torus
    for m in (8, 64):
        d = m.bit_length() - 1
        assert Topology.hypercube(m).spectral_gap == pytest.approx(
            2.0 / (d + 1))


@pytest.mark.parametrize("kind,m,match", [
    ("ring", 2, "ring"), ("torus", 7, "composite"), ("torus", 2, "composite"),
    ("hypercube", 6, "power-of-two"), ("gossip_pairs", 5, "even"),
    ("groups", 8, "dividing"), ("unknown", 4, "unknown topology")])
def test_builder_validation_is_eager_and_actionable(kind, m, match):
    with pytest.raises(ValueError, match=match):
        Topology.build(kind, m, groups=3)


def test_build_rejects_explicit_zero_groups():
    # groups defaults to 2 only when OMITTED; an explicit 0 must hit
    # the builder's validation, not silently become the default
    with pytest.raises(ValueError, match="group count >= 1"):
        Topology.build("groups", 8, groups=0)
    assert Topology.build("groups", 8).groups == 2


def test_comm_degree():
    assert Topology.full(8).comm_degree == 7.0
    assert Topology.ring(8).comm_degree == 2.0
    assert Topology.torus(16).comm_degree == 4.0
    assert Topology.hypercube(16).comm_degree == 4.0
    assert Topology.gossip_pairs(8).comm_degree == 1.0
    assert Topology.disconnected(8).comm_degree == 0.0
    assert Topology.blocks(8, 2).comm_degree == 3.0


# --------------------------------------------------------------------------
# gossip matchings: pure function of (key, step)
# --------------------------------------------------------------------------

def test_gossip_matrix_is_valid_and_deterministic():
    key = jax.random.PRNGKey(3)
    W = np.asarray(gossip_matrix(key, 5, WORKERS))
    np.testing.assert_allclose(W, W.T, atol=0)
    np.testing.assert_allclose(W.sum(1), np.ones(WORKERS), atol=1e-6)
    # a pair average is a projection: W^2 == W, diag exactly 1/2
    np.testing.assert_allclose(W @ W, W, atol=1e-6)
    np.testing.assert_array_equal(np.diag(W), np.full(WORKERS, 0.5))
    # replay: same (key, step) -> same matching, bitwise
    np.testing.assert_array_equal(
        W, np.asarray(gossip_matrix(key, 5, WORKERS)))
    # and the stream varies over steps
    others = [np.asarray(gossip_matrix(key, s, WORKERS))
              for s in range(1, 9)]
    assert any(not (o == W).all() for o in others)


# --------------------------------------------------------------------------
# kernels: pallas == ref == tree operator; dispersion contraction
# --------------------------------------------------------------------------

def test_mix_disp_kernel_matches_ref_and_tree():
    rng = np.random.default_rng(0)
    plane = jnp.asarray(rng.standard_normal((WORKERS, 37)), jnp.float32)
    W = Topology.ring(WORKERS).mixing_matrix()
    o_k, d_k = mix_disp(plane, W)
    o_r, d_r = mix_disp_ref(plane, W)
    np.testing.assert_array_equal(np.asarray(o_k), np.asarray(o_r))
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    tree = mix_tree({"w": plane}, W)["w"]
    np.testing.assert_allclose(np.asarray(o_r), np.asarray(tree),
                               rtol=1e-6, atol=1e-7)
    # doubly stochastic: the column means (consensus) are preserved
    np.testing.assert_allclose(np.asarray(o_r).mean(0),
                               np.asarray(plane).mean(0),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("codes", [None, "mixed"], ids=["f32", "codes"])
def test_opt_step_mix_mode_kernel_matches_ref(codes):
    rng = np.random.default_rng(1)
    if codes is not None:
        codes = np.zeros(37, np.float32)
        codes[10:20] = 1.0
    plane = jnp.asarray(rng.standard_normal((WORKERS, 37)), jnp.float32)
    grads = jnp.asarray(rng.standard_normal((WORKERS, 37)), jnp.float32)
    vel = jnp.asarray(rng.standard_normal((WORKERS, 37)), jnp.float32)
    scal = jnp.asarray([0.1, 1.0, 1.0, 0.0], jnp.float32)
    W = Topology.hypercube(WORKERS).mixing_matrix()
    kw = dict(kind="momentum", mode="mix", W=W, codes=codes)
    p_k, s_k, d_k = opt_step(plane, grads, (vel,), scal, **kw)
    p_r, s_r, d_r = opt_step_ref(plane, grads, (vel,), scal, **kw)
    # the in-kernel update fuses into the MXU contraction, so interpret
    # mode agrees with the separately-compiled ref to f32 roundoff (the
    # engine picks ONE implementation per backend, so path equivalence
    # never mixes the two)
    np.testing.assert_allclose(np.asarray(p_k), np.asarray(p_r),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(s_k[0]), np.asarray(s_r[0]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(d_k), float(d_r), rtol=1e-5)
    # and the ref mix composes exactly as update-then-mix (the rare-
    # schedule path: hoisted update + switched mix event)
    from repro.kernels.ref import plane_update_ref
    upd, _ = plane_update_ref(plane, grads, (vel,), scal, kind="momentum",
                              codes=codes)
    p_c, d_c = mix_disp_ref(upd, W, codes=codes)
    np.testing.assert_array_equal(np.asarray(p_r), np.asarray(p_c))
    np.testing.assert_array_equal(np.asarray(d_r), np.asarray(d_c))


@pytest.mark.parametrize("kind", ["ring", "torus", "hypercube"])
def test_mix_event_contracts_dispersion_by_slem_squared(kind):
    """One mix multiplies the Eq. 4 dispersion by at most λ₂² — the
    spectral-gap theory hook the engine's diagnostic rides on."""
    t = Topology.build(kind, 16)
    rng = np.random.default_rng(2)
    plane = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)

    def disp(x):
        x = np.asarray(x, np.float64)
        g = x.mean(0)
        return float(np.sum((x - g) ** 2) / x.shape[0])

    out, _ = mix_disp_ref(plane, t.mixing_matrix())
    lam2 = 1.0 - t.spectral_gap
    assert disp(out) <= lam2 ** 2 * disp(plane) * (1 + 1e-5)
    assert disp(out) > 0  # partial mixing does NOT collapse dispersion


def test_theory_fixed_point_limits():
    kw = dict(alpha=0.05, sigma2=1.0, L=1.0, c=1.0, k=8)
    g = coarse_dispersion_bound(**kw)
    # gap=1 (full averaging): exactly Eq. 4's schedule-independent bound
    assert mixed_dispersion_fixed_point(**kw, spectral_gap=1.0) == \
        pytest.approx(g)
    # gap=0 (disconnected): the k -> infinity envelope
    env = kw["alpha"] * kw["sigma2"] / (2 * kw["L"]
                                        - kw["alpha"] * kw["c"] ** 2)
    assert mixed_dispersion_fixed_point(**kw, spectral_gap=0.0) == \
        pytest.approx(env)
    # monotone: more gap, less steady-state dispersion
    gaps = [0.0, 0.2, 0.5, 0.8, 1.0]
    vals = [mixed_dispersion_fixed_point(**kw, spectral_gap=s)
            for s in gaps]
    assert all(a > b for a, b in zip(vals, vals[1:]))
    assert mixing_contraction(1.0) == 0.0 and mixing_contraction(0.0) == 1.0


# --------------------------------------------------------------------------
# engine: full topology == mean path, bitwise, everywhere
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(SCHEDULES))
@pytest.mark.parametrize("path", ["native", "flat", "tree", "host"])
def test_full_topology_bitexact_all_schedules_all_paths(name, path):
    """The subsystem's anchor: Topology.full lowers to the existing
    fused-mean path, so params AND the full history are bit-identical
    to running without a topology — per schedule, per engine path."""
    batches = _batches()
    kw = dict(num_workers=WORKERS, seed=3, record_every=1)
    opts = {"native": {}, "flat": {"fused_opt": False},
            "tree": {"flat": False}}

    def go(topo):
        eng = PhaseEngine(_loss_fn, Momentum(lr=0.05, mu=0.9),
                          SCHEDULES[name], topology=topo,
                          **opts.get(path, {}))
        if path == "host":
            return eng.run_host(_params(), batches, **kw)
        return eng.run(_params(), batches, **kw)

    f0, h0 = go(None)
    f1, h1 = go(Topology.full(WORKERS))
    np.testing.assert_array_equal(np.asarray(f0["w"]), np.asarray(f1["w"]))
    assert h0 == h1


def test_groups_topology_unifies_inner_block_mean():
    """Topology.blocks(M, g) IS the ``inner_groups`` block mean as a
    block-diagonal W: each all-scope event equals ``average_inner`` on
    the worker tree (the engine lowers it to the same fused group-mean
    kernel), and applying the explicit block-diagonal matrix lands on
    the same rows (matmul roundoff)."""
    t = Topology.blocks(WORKERS, 2)
    batches = _batches()
    kw = dict(num_workers=WORKERS, seed=3, record_every=1)
    eng = PhaseEngine(_loss_fn, SGD(lr=0.05),
                      AveragingSchedule("periodic", STEPS), topology=t)
    # run up to the single event, then take the event step from the
    # same checkpointed state twice: once with the groups topology
    # (periodic fires at STEPS) and once with no event at all
    _, _, st = eng.run(_params(), batches[:STEPS - 1], return_state=True,
                       **kw)
    # run_phase donates its state buffers — copy per replay
    snap = lambda s: jax.tree.map(jnp.array, s)
    f, h, st2 = eng.run(None, batches[STEPS - 1:], state=snap(st),
                        return_state=True, **kw)
    assert h["averages"] == 1
    # post-event: rows equal WITHIN each contiguous group, groups differ
    wp = np.asarray(st2.worker_params["w"])
    half = WORKERS // 2
    for g in range(2):
        grp = wp[g * half:(g + 1) * half]
        np.testing.assert_array_equal(grp, np.broadcast_to(grp[:1],
                                                           grp.shape))
    assert not (wp[0] == wp[half]).all()
    # and it matches average_inner of the post-update pre-event workers
    # (an oneshot run of the same step from the same state never
    # averages, exposing them)
    eng_one = PhaseEngine(_loss_fn, SGD(lr=0.05),
                          AveragingSchedule("oneshot"))
    _, _, st_no = eng_one.run(None, batches[STEPS - 1:], state=snap(st),
                              return_state=True, **kw)
    want = average_inner(st_no.worker_params, 2)["w"]
    np.testing.assert_array_equal(wp, np.asarray(want))
    # operator-level unification: W @ x == average_inner (roundoff)
    rng = np.random.default_rng(4)
    raw = {"w": jnp.asarray(rng.standard_normal((WORKERS, DIM)),
                            jnp.float32)}
    blocked = average_inner(raw, 2)["w"]
    mixed = mix_tree(raw, t.mixing_matrix())["w"]
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(mixed),
                               rtol=1e-6, atol=1e-7)


# --------------------------------------------------------------------------
# engine: sparse topologies agree across all four paths
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["ring", "gossip_pairs", "disconnected"])
@pytest.mark.parametrize("sched", ["periodic", "minibatch",
                                   "adaptive_threshold"])
def test_mix_paths_agree(kind, sched):
    """flat-native / PR 2 flat / tree / host / indexed replay identical
    event streams and land on the same mixed params for sparse
    topologies (the plane paths bitwise, tree/host to f32 roundoff)."""
    topo = Topology.build(kind, WORKERS)
    batches = _batches()
    X, y = _convex_problem()
    rng = np.random.default_rng(1)
    idx = rng.integers(0, SAMPLES, (STEPS, WORKERS, 8))
    kw = dict(num_workers=WORKERS, seed=3, record_every=1)
    mk = lambda **e: PhaseEngine(_loss_fn, SGD(lr=0.05), SCHEDULES[sched],
                                 topology=topo, **e)
    f_nat, h_nat = mk().run(_params(), batches, **kw)
    f_idx, h_idx = mk().run(
        _params(), DeviceDataset({"x": X, "y": y}, WORKERS, indices=idx),
        **kw)
    f_pr2, h_pr2 = mk(fused_opt=False).run(_params(), batches, **kw)
    f_tree, h_tree = mk(flat=False).run(_params(), batches, **kw)
    f_host, h_host = mk().run_host(_params(), batches, **kw)

    np.testing.assert_array_equal(np.asarray(f_nat["w"]),
                                  np.asarray(f_idx["w"]))
    assert h_nat == h_idx
    for f, h in ((f_pr2, h_pr2), (f_tree, h_tree), (f_host, h_host)):
        assert h_nat["averages"] == h["averages"] > 0
        assert [t for t, _ in h_nat["dispersion"]] == \
            [t for t, _ in h["dispersion"]]
        np.testing.assert_allclose(np.asarray(f_nat["w"]),
                                   np.asarray(f["w"]),
                                   rtol=1e-6, atol=1e-7)
    if kind == "disconnected":
        # the no-communication endpoint: events fire but mix nothing —
        # identical to oneshot worker trajectories
        f_one, _ = PhaseEngine(_loss_fn, SGD(lr=0.05),
                               AveragingSchedule("oneshot")).run(
            _params(), batches, **kw)
        np.testing.assert_array_equal(np.asarray(f_nat["w"]),
                                      np.asarray(f_one["w"]))


def test_gossip_decisions_invariant_to_phase_blocking():
    """The matching stream is a pure function of (dec_key, step), so
    phase blocking stays a pure perf knob under gossip mixing too."""
    topo = Topology.gossip_pairs(WORKERS)
    batches = _batches()
    eng = PhaseEngine(_loss_fn, SGD(lr=0.05),
                      AveragingSchedule("periodic", 4), topology=topo)
    kw = dict(num_workers=WORKERS, seed=0, record_every=1)
    ref, h_ref = eng.run(_params(), batches, phase_len=8, **kw)
    for block in (1, 7, 32):
        got, h_got = eng.run(_params(), batches, phase_len=block, **kw)
        np.testing.assert_array_equal(np.asarray(ref["w"]),
                                      np.asarray(got["w"]))
        assert h_ref == h_got


def test_gossip_checkpoint_resume_bit_identical(tmp_path):
    """Resume replays the remaining gossip matchings exactly: they are
    derived from the checkpointed (dec_key, step), no extra state."""
    topo = Topology.gossip_pairs(WORKERS)
    batches = _batches(seed=7)
    mk = lambda: PhaseEngine(_loss_fn, Momentum(lr=0.05, mu=0.9),
                             AveragingSchedule("periodic", 4),
                             topology=topo)
    kw = dict(num_workers=WORKERS, record_every=8)
    f_full, h_full = mk().run(_params(), batches, seed=7, **kw)
    cut = 18  # mid-phase AND between events
    _, h1, st = mk().run(_params(), batches[:cut], seed=7,
                         return_state=True, **kw)
    path = os.path.join(tmp_path, "ck")
    save_engine_state(path, st)
    loaded, at = load_engine_state(path, mk().init(_params(), WORKERS, 7))
    assert at == cut
    f_res, h2 = mk().run(None, batches[cut:], state=loaded, **kw)
    np.testing.assert_array_equal(np.asarray(f_full["w"]),
                                  np.asarray(f_res["w"]))
    assert h_full["dispersion"] == h1["dispersion"] + h2["dispersion"]
    assert h_full["averages"] == h1["averages"] + h2["averages"] > 0


# --------------------------------------------------------------------------
# eager validation: engine + train.py
# --------------------------------------------------------------------------

def test_engine_rejects_mismatched_topology_eagerly():
    eng = PhaseEngine(_loss_fn, SGD(lr=0.05),
                      AveragingSchedule("periodic", 4),
                      topology=Topology.ring(6))
    with pytest.raises(ValueError, match="built for 6 workers"):
        eng.init(_params(), WORKERS)
    with pytest.raises(ValueError, match="built for 6 workers"):
        eng.run(_params(), _batches(), num_workers=WORKERS)
    with pytest.raises(ValueError, match="built for 6 workers"):
        eng.run_host(_params(), _batches(), num_workers=WORKERS)


def test_engine_rejects_outer_optimizer_with_partial_mixing():
    eng = PhaseEngine(_loss_fn, SGD(lr=0.05),
                      AveragingSchedule("periodic", 4),
                      outer=OuterOptimizer(lr=0.9, momentum=0.5),
                      topology=Topology.ring(WORKERS))
    with pytest.raises(ValueError, match="consensus mean"):
        eng.init(_params(), WORKERS)
    # full topology keeps the consensus mean: outer is fine
    PhaseEngine(_loss_fn, SGD(lr=0.05), AveragingSchedule("periodic", 4),
                outer=OuterOptimizer(lr=0.9, momentum=0.5),
                topology=Topology.full(WORKERS)).init(_params(), WORKERS)


class TestTrainCliTopologyValidation:
    """train.py rejects invalid topology/worker-count combinations at
    parse time (argparse error, exit code 2) with the builders'
    actionable messages — mirroring the schedule-arg convention."""

    def _error(self, argv):
        from repro.launch.train import main
        with pytest.raises(SystemExit) as e:
            main(argv)
        assert e.value.code == 2

    def test_ring_needs_three_workers(self):
        self._error(["--topology", "ring", "--workers", "2"])

    def test_torus_needs_composite_workers(self):
        self._error(["--topology", "torus", "--workers", "7"])

    def test_hypercube_needs_power_of_two(self):
        self._error(["--topology", "hypercube", "--workers", "6"])

    def test_gossip_needs_even_workers(self):
        self._error(["--topology", "gossip_pairs", "--workers", "5"])

    def test_groups_must_divide_workers(self):
        self._error(["--topology", "groups", "--workers", "8",
                     "--topology-groups", "3"])

    def test_outer_optimizer_needs_full_topology(self):
        self._error(["--topology", "ring", "--workers", "4",
                     "--outer-momentum", "0.5"])

"""Property-based tests (hypothesis) for the averaging operators and
local-SGD runtime invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.averaging import (AveragingSchedule, OuterOptimizer,
                                  average_all, average_inner,
                                  worker_dispersion)
from repro.core.local_sgd import LocalSGD, consensus, replicate
from repro.optim import SGD

shapes = st.sampled_from([(4, 3), (2, 5, 2), (8, 1)])


def tree_from(seed, m, shape):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (m,) + shape),
            "b": {"c": jax.random.normal(k2, (m, 7))}}


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), m=st.sampled_from([2, 4, 8]),
       shape=shapes)
def test_average_all_idempotent_and_mean_preserving(seed, m, shape):
    t = tree_from(seed, m, shape)
    avg = average_all(t)
    # all workers equal after averaging
    for leaf in jax.tree.leaves(avg):
        np.testing.assert_allclose(np.asarray(leaf),
                                   np.asarray(leaf[:1]).repeat(m, 0), rtol=1e-6)
    # idempotent
    for a, b in zip(jax.tree.leaves(average_all(avg)), jax.tree.leaves(avg)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    # preserves the mean (consensus invariance)
    for a, b in zip(jax.tree.leaves(consensus(avg)), jax.tree.leaves(consensus(t))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    # dispersion collapses to ~0
    assert float(worker_dispersion(avg)) < 1e-8


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), groups=st.sampled_from([2, 4]))
def test_hierarchical_inner_average(seed, groups):
    m = 8
    t = tree_from(seed, m, (3,))
    inner = average_inner(t, groups)
    x = np.asarray(jax.tree.leaves(t)[0])
    got = np.asarray(jax.tree.leaves(inner)[0])
    per = m // groups
    for g in range(groups):
        expect = x[g * per:(g + 1) * per].mean(0)
        for i in range(per):
            np.testing.assert_allclose(got[g * per + i], expect, rtol=1e-5)
    # full average of inner-averaged == full average of original
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(consensus(inner))[0]),
        np.asarray(jax.tree.leaves(consensus(t))[0]), rtol=1e-5, atol=1e-6)


def test_outer_optimizer_identity_reduces_to_plain_mean():
    t = tree_from(3, 4, (5,))
    outer = OuterOptimizer(lr=1.0, momentum=0.0)
    prev = consensus(average_all(t))
    new = consensus(t)
    vel = outer.init(new)
    out, _ = outer.apply(prev, new, vel)
    # lr=1, mu=0: out = prev - (prev - new) = new
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(new)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(k=st.sampled_from([1, 3, 8]), steps=st.sampled_from([9, 16]))
def test_schedule_periodic_counts(k, steps):
    sch = AveragingSchedule(kind="periodic", phase_len=k)
    n = sum(sch.wants_average(s) == "all" for s in range(1, steps + 1))
    assert n == steps // k


def test_schedule_kinds():
    rng = np.random.default_rng(0)
    assert AveragingSchedule(kind="oneshot").wants_average(5, rng) == "none"
    assert AveragingSchedule(kind="minibatch").wants_average(5, rng) == "all"
    h = AveragingSchedule(kind="hierarchical", inner_phase_len=2,
                          outer_phase_len=6, inner_groups=2)
    kinds = [h.wants_average(s, rng) for s in range(1, 7)]
    assert kinds == ["none", "inner", "none", "inner", "none", "all"]


def test_local_sgd_runtime_on_quadratic():
    """M workers on a noisy scalar quadratic: periodic averaging converges
    to a smaller noise ball than one-shot (paper's variance claim) and the
    runtime machinery (init/local_step/average) holds its invariants."""
    def make(schedule):
        def loss_fn(params, batch, rng):
            b, h = batch["b"], batch["h"]
            w = params["w"]
            # grad = c w - b w - h realized via surrogate loss
            g = w - b * w - h
            return 0.5 * jnp.sum(jax.lax.stop_gradient(g) * w) * 2.0, {}
        return LocalSGD(loss_fn, SGD(lr=0.05), schedule)

    M, steps = 16, 400
    rng = np.random.default_rng(0)

    def batches():
        for _ in range(steps):
            yield {"b": jnp.asarray(rng.normal(0, 2.0, (M, 1))),
                   "h": jnp.asarray(rng.normal(0, 1.0, (M, 1)))}

    final_periodic, hist_p = make(AveragingSchedule("periodic", 10)).run(
        {"w": jnp.ones(1) * 5.0}, batches(), num_workers=M, seed=0)
    final_oneshot, hist_o = make(AveragingSchedule("oneshot")).run(
        {"w": jnp.ones(1) * 5.0}, batches(), num_workers=M, seed=0)
    assert hist_p["averages"] == steps // 10
    assert hist_o["averages"] == 0
    assert np.isfinite(float(final_periodic["w"][0]))
    assert abs(float(final_periodic["w"][0])) < abs(float(final_oneshot["w"][0])) + 0.5

"""Deterministic tests for the averaging operators, schedules and
local-SGD runtime invariants. Property-based (hypothesis) variants of the
operator invariants live in test_averaging_properties.py, which skips
itself when the optional ``hypothesis`` dev dependency is missing — this
module covers the same invariants without it.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.averaging import (AveragingSchedule, OuterOptimizer,
                                  average_all, average_inner,
                                  worker_dispersion)
from repro.core.local_sgd import LocalSGD, consensus
from repro.optim import SGD


def tree_from(seed, m, shape):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (m,) + shape),
            "b": {"c": jax.random.normal(k2, (m, 7))}}


@pytest.mark.parametrize("seed,m,shape", [
    (0, 2, (4, 3)), (17, 4, (2, 5, 2)), (998, 8, (8, 1)), (5, 4, (4, 3)),
])
def test_average_all_idempotent_and_mean_preserving(seed, m, shape):
    t = tree_from(seed, m, shape)
    avg = average_all(t)
    # all workers equal after averaging
    for leaf in jax.tree.leaves(avg):
        np.testing.assert_allclose(np.asarray(leaf),
                                   np.asarray(leaf[:1]).repeat(m, 0), rtol=1e-6)
    # idempotent
    for a, b in zip(jax.tree.leaves(average_all(avg)), jax.tree.leaves(avg)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    # preserves the mean (consensus invariance)
    for a, b in zip(jax.tree.leaves(consensus(avg)), jax.tree.leaves(consensus(t))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    # dispersion collapses to ~0
    assert float(worker_dispersion(avg)) < 1e-8


@pytest.mark.parametrize("seed,groups", [(0, 2), (3, 4), (1234, 2)])
def test_hierarchical_inner_average(seed, groups):
    m = 8
    t = tree_from(seed, m, (3,))
    inner = average_inner(t, groups)
    x = np.asarray(jax.tree.leaves(t)[0])
    got = np.asarray(jax.tree.leaves(inner)[0])
    per = m // groups
    for g in range(groups):
        expect = x[g * per:(g + 1) * per].mean(0)
        for i in range(per):
            np.testing.assert_allclose(got[g * per + i], expect, rtol=1e-5)
    # full average of inner-averaged == full average of original
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(consensus(inner))[0]),
        np.asarray(jax.tree.leaves(consensus(t))[0]), rtol=1e-5, atol=1e-6)


def test_outer_optimizer_identity_reduces_to_plain_mean():
    t = tree_from(3, 4, (5,))
    outer = OuterOptimizer(lr=1.0, momentum=0.0)
    prev = consensus(average_all(t))
    new = consensus(t)
    vel = outer.init(new)
    out, _ = outer.apply(prev, new, vel)
    # lr=1, mu=0: out = prev - (prev - new) = new
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(new)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_outer_optimizer_nested_params_and_momentum():
    """apply() must handle arbitrarily nested pytrees (incl. tuples as
    internal nodes) and reproduce the Nesterov recurrence leaf-by-leaf."""
    prev = {"layers": ({"w": jnp.ones((3, 2)), "b": jnp.zeros(2)},
                       {"w": jnp.full((2, 2), 2.0)}),
            "head": {"scale": jnp.asarray([4.0])}}
    new = jax.tree.map(lambda x: x - 0.5, prev)
    outer = OuterOptimizer(lr=0.7, momentum=0.9, nesterov=True)
    vel = outer.init(prev)
    out1, vel1 = outer.apply(prev, new, vel)
    assert jax.tree.structure(out1) == jax.tree.structure(prev)
    assert jax.tree.structure(vel1) == jax.tree.structure(prev)
    # delta = prev - new = 0.5 everywhere; v1 = 0.5; step = .9*.5 + .5
    for p, o, v in zip(jax.tree.leaves(prev), jax.tree.leaves(out1),
                       jax.tree.leaves(vel1)):
        np.testing.assert_allclose(np.asarray(v), 0.5, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(o),
                                   np.asarray(p) - 0.7 * (0.9 * 0.5 + 0.5),
                                   rtol=1e-6)
    # second application keeps structure and momentum accumulates
    out2, vel2 = outer.apply(out1, jax.tree.map(lambda x: x - 1.0, out1),
                             vel1)
    for v in jax.tree.leaves(vel2):
        np.testing.assert_allclose(np.asarray(v), 0.9 * 0.5 + 1.0, rtol=1e-6)
    assert jax.tree.structure(out2) == jax.tree.structure(prev)


@pytest.mark.parametrize("k,steps", [(1, 9), (3, 9), (3, 16), (8, 16)])
def test_schedule_periodic_counts(k, steps):
    sch = AveragingSchedule(kind="periodic", phase_len=k)
    n = sum(sch.wants_average(s) == "all" for s in range(1, steps + 1))
    assert n == steps // k


def test_schedule_kinds():
    rng = np.random.default_rng(0)
    assert AveragingSchedule(kind="oneshot").wants_average(5, rng) == "none"
    assert AveragingSchedule(kind="minibatch").wants_average(5, rng) == "all"
    h = AveragingSchedule(kind="hierarchical", inner_phase_len=2,
                          outer_phase_len=6, inner_groups=2)
    kinds = [h.wants_average(s, rng) for s in range(1, 7)]
    assert kinds == ["none", "inner", "none", "inner", "none", "all"]


def test_schedule_validation():
    """Invalid parameters must fail eagerly — traced mod-by-zero inside
    the engine would mis-schedule silently."""
    with pytest.raises(ValueError):
        AveragingSchedule("periodic", phase_len=0)
    with pytest.raises(ValueError):
        AveragingSchedule("stochastic", zeta=0.0)
    with pytest.raises(ValueError):
        AveragingSchedule("hierarchical", inner_phase_len=0)
    with pytest.raises(ValueError):
        AveragingSchedule("nonsense")
    AveragingSchedule("oneshot")  # unused fields are not validated
    # adaptive kinds: threshold/budget/beta validated eagerly too
    with pytest.raises(ValueError):
        AveragingSchedule("adaptive_threshold")  # default threshold 0
    with pytest.raises(ValueError):
        AveragingSchedule("adaptive_threshold", disp_threshold=0.1,
                          disp_ema_beta=1.0)
    with pytest.raises(ValueError):
        AveragingSchedule("adaptive_budget")  # default budget 0
    with pytest.raises(ValueError):
        AveragingSchedule("adaptive_budget", comm_budget=10,
                          budget_horizon=5)  # > 1 event/step
    AveragingSchedule("adaptive_threshold", disp_threshold=0.1)
    AveragingSchedule("adaptive_budget", comm_budget=4, budget_horizon=64)


def test_expected_phase_len_all_kinds():
    """Pin the a-priori expected steps between communication events for
    all 5 static + 2 adaptive kinds. ``hierarchical`` counts ANY event
    (inner or outer) — the harmonic rate 1/K_i + 1/K_o - 1/lcm, NOT the
    old inner-only answer."""
    assert AveragingSchedule("oneshot").expected_phase_len() == float("inf")
    assert AveragingSchedule("minibatch").expected_phase_len() == 1.0
    assert AveragingSchedule("periodic", 8).expected_phase_len() == 8.0
    assert AveragingSchedule("stochastic",
                             zeta=0.25).expected_phase_len() == 4.0
    # K_o a multiple of K_i: outer events coincide with inner -> K_i
    h = AveragingSchedule("hierarchical", inner_phase_len=5,
                          outer_phase_len=20, inner_groups=2)
    assert h.expected_phase_len() == pytest.approx(5.0)
    # coprime periods: events at multiples of 3 OR 5 -> 15 steps hold
    # 5 + 3 - 1 = 7 events -> 15/7 expected interval
    h2 = AveragingSchedule("hierarchical", inner_phase_len=3,
                          outer_phase_len=5)
    assert h2.expected_phase_len() == pytest.approx(15.0 / 7.0)
    # sanity: the event count over one lcm window matches wants_average
    events = sum(h2.wants_average(s) != "none" for s in range(1, 16))
    assert events == 7 and 15 / events == pytest.approx(
        h2.expected_phase_len())
    # defaults (the old bug returned inner_phase_len=16 by luck only
    # because 512 is a multiple of 16 — pin a non-dividing pair too)
    h3 = AveragingSchedule("hierarchical", inner_phase_len=4,
                          outer_phase_len=6)
    assert h3.expected_phase_len() == pytest.approx(1.0 / (1 / 4 + 1 / 6
                                                           - 1 / 12))
    assert math.isnan(AveragingSchedule(
        "adaptive_threshold", disp_threshold=0.1).expected_phase_len())
    assert AveragingSchedule(
        "adaptive_budget", comm_budget=4,
        budget_horizon=64).expected_phase_len() == 16.0


def test_decision_state_threshold_fires_and_resets():
    """adaptive_threshold: the EMA crosses the trip level -> code 2;
    the event resets the EMA and the bookkeeping fields advance."""
    sch = AveragingSchedule("adaptive_threshold", disp_threshold=0.5,
                            disp_ema_beta=0.5)
    st = sch.init_sched_state()
    # two quiet steps: EMA stays under threshold, no event
    code, st = sch.decision_state(1, st, 0.2)
    assert int(code) == 0 and int(st.since_avg) == 1
    code, st = sch.decision_state(2, st, 0.2)
    assert int(code) == 0 and float(st.disp_ema) == pytest.approx(0.15)
    # a dispersion burst trips the EMA -> all-average, EMA reset
    code, st = sch.decision_state(3, st, 2.0)
    assert int(code) == 2
    assert float(st.disp_ema) == 0.0
    assert int(st.comm_spent) == 1 and int(st.since_avg) == 0
    assert float(st.cum_disp) == pytest.approx(2.4)


def test_decision_state_budget_caps_and_paces():
    """adaptive_budget: never spends more than comm_budget events, and
    spends them where the dispersion envelope is high."""
    sch = AveragingSchedule("adaptive_budget", comm_budget=3,
                            budget_horizon=30, disp_ema_beta=0.0)
    st = sch.init_sched_state()
    codes = []
    # constant envelope: credit accrues at ~C/T per step -> <= C events
    for step in range(1, 31):
        code, st = sch.decision_state(step, st, 1.0)
        codes.append(int(code))
    assert sum(c == 2 for c in codes) <= 3
    assert int(st.comm_spent) == sum(c == 2 for c in codes) > 0
    # the cap binds even under a huge late burst
    sch2 = AveragingSchedule("adaptive_budget", comm_budget=2,
                             budget_horizon=20, disp_ema_beta=0.0)
    st2 = sch2.init_sched_state()
    spent = 0
    for step in range(1, 21):
        disp = 100.0 if step > 10 else 0.01
        code, st2 = sch2.decision_state(step, st2, disp)
        spent += int(code) == 2
    assert spent == 2 == int(st2.comm_spent)


def test_decision_state_static_kinds_match_decision_code():
    """Static kinds flow through decision_state with identical codes
    (pure bookkeeping on the state) — one uniform engine carry."""
    key = jax.random.PRNGKey(0)
    for sch in [AveragingSchedule("oneshot"),
                AveragingSchedule("minibatch"),
                AveragingSchedule("periodic", 4),
                AveragingSchedule("stochastic", zeta=0.3),
                AveragingSchedule("hierarchical", inner_phase_len=2,
                                  outer_phase_len=6, inner_groups=2)]:
        st = sch.init_sched_state()
        events = 0
        for step in range(1, 13):
            code, st = sch.decision_state(step, st, 0.1, key)
            want = int(sch.decision_code(step, key))
            assert int(code) == want, (sch.kind, step)
            events += want > 0
        assert int(st.comm_spent) == events
        assert float(st.cum_disp) == pytest.approx(1.2)


def test_decision_state_is_pure_and_jittable():
    """Same (step, state, disp) -> same decision, eagerly and under jit
    (the engine evaluates the transition inside the phase scan)."""
    sch = AveragingSchedule("adaptive_threshold", disp_threshold=0.3,
                            disp_ema_beta=0.5)
    disps = [0.1, 0.5, 0.9, 0.05, 0.8, 0.02]

    def replay(fn):
        st, out = sch.init_sched_state(), []
        for step, d in enumerate(disps, 1):
            code, st = fn(jnp.asarray(step, jnp.int32), st,
                          jnp.asarray(d, jnp.float32))
            out.append(int(code))
        return out, st

    eager, st_e = replay(sch.decision_state)
    jitted, st_j = replay(jax.jit(sch.decision_state))
    assert eager == jitted and any(eager)
    for a, b in zip(st_e, st_j):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_adaptive_kinds_reject_stateless_decision_apis():
    sch = AveragingSchedule("adaptive_threshold", disp_threshold=0.1)
    with pytest.raises(ValueError, match="decision_state"):
        sch.decision_code(5)
    with pytest.raises(ValueError, match="decision_state"):
        sch.wants_average(5, np.random.default_rng(0))


def test_decision_code_matches_wants_average():
    """The on-device decision (engine path) agrees with the legacy
    host-side decision for every deterministic schedule."""
    names = {0: "none", 1: "inner", 2: "all"}
    key = jax.random.PRNGKey(0)
    for sch in [AveragingSchedule("oneshot"),
                AveragingSchedule("minibatch"),
                AveragingSchedule("periodic", 4),
                AveragingSchedule("hierarchical", inner_phase_len=2,
                                  outer_phase_len=6, inner_groups=2)]:
        for step in range(1, 13):
            assert names[int(sch.decision_code(step, key))] == \
                sch.wants_average(step, np.random.default_rng(0)), (sch, step)


def test_decision_code_stochastic_reproducible_and_calibrated():
    sch = AveragingSchedule("stochastic", zeta=0.25)
    key = jax.random.PRNGKey(7)
    codes = [int(sch.decision_code(s, key)) for s in range(1, 401)]
    # pure function of (key, step): replaying gives the identical stream
    assert codes == [int(sch.decision_code(s, key)) for s in range(1, 401)]
    # and under jit (the engine's path) the very same stream
    jitted = jax.jit(lambda s: sch.decision_code(s, key))
    assert codes[:50] == [int(jitted(s)) for s in range(1, 51)]
    rate = sum(c == 2 for c in codes) / len(codes)
    assert 0.15 < rate < 0.35, rate
    assert set(codes) <= {0, 2}


def test_local_sgd_runtime_on_quadratic():
    """M workers on a noisy scalar quadratic: periodic averaging converges
    to a smaller noise ball than one-shot (paper's variance claim) and the
    runtime machinery (engine-backed init/run) holds its invariants."""
    def make(schedule):
        def loss_fn(params, batch, rng):
            b, h = batch["b"], batch["h"]
            w = params["w"]
            # grad = c w - b w - h realized via surrogate loss
            g = w - b * w - h
            return 0.5 * jnp.sum(jax.lax.stop_gradient(g) * w) * 2.0, {}
        return LocalSGD(loss_fn, SGD(lr=0.05), schedule)

    M, steps = 16, 400
    rng = np.random.default_rng(0)

    def batches():
        for _ in range(steps):
            yield {"b": jnp.asarray(rng.normal(0, 2.0, (M, 1))),
                   "h": jnp.asarray(rng.normal(0, 1.0, (M, 1)))}

    final_periodic, hist_p = make(AveragingSchedule("periodic", 10)).run(
        {"w": jnp.ones(1) * 5.0}, batches(), num_workers=M, seed=0)
    final_oneshot, hist_o = make(AveragingSchedule("oneshot")).run(
        {"w": jnp.ones(1) * 5.0}, batches(), num_workers=M, seed=0)
    assert hist_p["averages"] == steps // 10
    assert hist_o["averages"] == 0
    assert np.isfinite(float(final_periodic["w"][0]))
    assert abs(float(final_periodic["w"][0])) < abs(float(final_oneshot["w"][0])) + 0.5

"""Paper theory: Lemma 1, Example 1 (homogeneous quadratics), Example 2
(coarse bound), and the §2.4 non-convex quartic example."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.theory import (coarse_dispersion_bound, lemma1_asymptotic_variance,
                               run_homogeneous_quadratic, simulate_quadratic)


class TestLemma1:
    @pytest.mark.parametrize("zeta", [0.0, 0.02, 0.1, 0.5, 1.0])
    def test_matches_simulation(self, zeta):
        alpha, c, beta2, sigma2, M = 0.05, 1.0, 4.0, 1.0, 16
        pred = lemma1_asymptotic_variance(alpha, c, beta2, sigma2, M, zeta)
        sim = simulate_quadratic(alpha, c, beta2, sigma2, M, zeta,
                                 steps=2500, reps=3000)
        assert sim == pytest.approx(pred, rel=0.15)

    def test_monotone_in_zeta(self):
        """More frequent averaging -> smaller asymptotic variance (the
        paper's headline claim, requires beta2 > 0)."""
        vs = [lemma1_asymptotic_variance(0.05, 1.0, 4.0, 1.0, 24, z)
              for z in [0.0, 0.01, 0.1, 0.5, 1.0]]
        assert all(a >= b - 1e-15 for a, b in zip(vs, vs[1:]))

    def test_no_benefit_when_beta2_zero(self):
        """Example 2 regime: with a uniform variance bound (beta2=0)
        averaging frequency has NO effect on the asymptotic variance."""
        vs = [lemma1_asymptotic_variance(0.05, 1.0, 0.0, 1.0, 24, z)
              for z in [0.0, 0.1, 1.0]]
        assert max(vs) == pytest.approx(min(vs), rel=1e-12)

    def test_minibatch_limit(self):
        """zeta=1 equals the M-times-variance-reduced single worker."""
        alpha, c, sigma2, M = 0.05, 1.0, 1.0, 8
        v = lemma1_asymptotic_variance(alpha, c, 4.0, sigma2, M, 1.0)
        single = alpha * sigma2 / (2 * c - alpha * c**2 - alpha * 4.0 / M)
        assert v == pytest.approx(single / M, rel=1e-12)


class TestExample1:
    def test_homogeneous_quadratic_schedule_invariance(self):
        """Same Hessian => one-shot == periodic == minibatch averaging,
        sample-path-wise (paper Example 1)."""
        key = jax.random.PRNGKey(0)
        dim, m = 6, 40
        A = jax.random.normal(key, (dim, dim)) * 0.2
        P = A @ A.T + jnp.eye(dim)
        qs = jax.random.normal(jax.random.PRNGKey(1), (m, dim))
        w0 = jnp.ones(dim)
        outs = [run_homogeneous_quadratic(P, qs, w0, 0.02, 200, M=8,
                                          phase_len=k, seed=3)
                for k in [0, 1, 10, 200]]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                       rtol=1e-5, atol=1e-6)


class TestCoarseBound:
    def test_bound_saturates(self):
        b_small = coarse_dispersion_bound(0.01, 1.0, 1.0, 1.0, 5)
        b_large = coarse_dispersion_bound(0.01, 1.0, 1.0, 1.0, 10_000)
        cap = 0.01 * 1.0 / (2 * 1.0 - 0.01 * 1.0)
        assert b_small < b_large <= cap + 1e-12


class TestQuartic:
    def test_periodic_beats_oneshot_nonconvex(self):
        """§2.4: f(w)=(w²-1)², one-shot averages workers from the ±1
        basins -> large objective; periodic averaging pins them in one
        basin -> near-zero objective."""
        key = jax.random.PRNGKey(0)
        M, steps, alpha = 24, 4000, 0.025

        def run(phase_len):
            w = jnp.zeros((M,)) + 0.0
            key_ = key
            ws = w
            for t in range(steps):
                key_, sub = jax.random.split(key_)
                u = jax.random.normal(sub, (M,))
                g = 4.0 * (ws ** 3 - ws + u)
                ws = ws - alpha * g
                if phase_len and (t + 1) % phase_len == 0:
                    ws = jnp.full_like(ws, jnp.mean(ws))
            return float((jnp.mean(ws) ** 2 - 1.0) ** 2)

        one_shot = run(0)
        periodic = run(100)
        assert periodic < 0.15
        assert one_shot > periodic

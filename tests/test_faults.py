"""Elastic fault-tolerant training: deterministic fault injection.

Covers the repro.faults subsystem end to end:

  - FaultPlan validation / parsing (eager, actionable errors);
  - masked-mixing algebra (degraded_matrix / masked_event_matrix
    stochasticity, all-alive lowering to the plain mean);
  - bitwise equality of a scripted crash + rejoin + straggler run
    across the flat-native / flat / tree engine carries and the
    per-step run_host loop;
  - all-alive FaultPlan == no-fault engine, bit-exact, across all 7
    schedules (graceful degradation is BY CONSTRUCTION: a trivial plan
    lowers to the unmodified paths);
  - checkpoint resume inside a fault window == uninterrupted run;
  - the v0..v4 engine-state checkpoint ladder (fault rows are v4;
    older layouts load with fresh all-alive rows; v4 into a no-fault
    engine is refused);
  - crash-safe checkpoint saves (temp + atomic rename; torn/partial
    files refused with an actionable error);
  - sharded gather collective bit-identity with dead rows (subprocess
    with 8 host devices, like tests/test_sharded.py);
  - Dirichlet label-skew (non-IID) worker shards;
  - Prefetcher producer-failure propagation without deadlock;
  - Topology.effective_spectral_gap under dropped workers;
  - the predict_averaging_benefit hook's qualitative predictions.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (load_engine_state, save_checkpoint,
                              save_engine_state)
from repro.core import PhaseEngine
from repro.core.averaging import AveragingSchedule
from repro.core.compress import Compression
from repro.core.variance_model import predict_averaging_benefit
from repro.data.pipeline import Prefetcher, WorkerSharder
from repro.faults import (FaultEvent, FaultPlan, FaultState,
                          degraded_matrix, masked_mean, masked_event_matrix)
from repro.optim import SGD, Momentum
from repro.topology import Topology

DIM, WORKERS, STEPS = 8, 4, 24


def _loss_fn(params, batch, rng):
    x, y = batch
    r = x @ params["w"] - y
    return jnp.mean(r * r), {}


def _params():
    return {"w": jnp.zeros((DIM,), jnp.float32)}


def _batches(steps=STEPS, m=WORKERS, seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.standard_normal(DIM)
    out = []
    for _ in range(steps):
        x = rng.standard_normal((m, 16, DIM)).astype(np.float32)
        y = (x @ w_true + 0.1 * rng.standard_normal((m, 16))).astype(
            np.float32)
        out.append((jnp.asarray(x), jnp.asarray(y)))
    return out


_PLAN = "crash:m=1@t=6,rejoin:m=1@t=14"


# --------------------------------------------------------------------------
# FaultPlan validation / parsing
# --------------------------------------------------------------------------

class TestFaultPlan:
    def test_parse_roundtrip(self):
        plan = FaultPlan.parse(_PLAN, WORKERS, straggle_prob=0.25)
        assert plan.events == (FaultEvent("crash", 1, 6),
                               FaultEvent("rejoin", 1, 14))
        assert plan.straggle_prob == 0.25
        assert not plan.is_trivial
        assert plan.has_rejoin

    def test_parse_auto_rejoin(self):
        plan = FaultPlan.parse("crash:m=2@t=5", WORKERS, rejoin_after=7)
        assert FaultEvent("rejoin", 2, 12) in plan.events
        # a crash with a later scripted event is left alone
        plan = FaultPlan.parse(_PLAN, WORKERS, rejoin_after=7)
        assert sum(e.kind == "rejoin" for e in plan.events) == 1

    @pytest.mark.parametrize("text,match", [
        ("crash:m=9@t=2", "out of range"),
        ("explode:m=1@t=2", "unknown fault kind"),
        ("crash m=1@t=2", "cannot parse"),
        ("rejoin:m=1@t=2", "without a prior crash"),
        ("crash:m=1@t=2,crash:m=1@t=5", "already dead"),
        ("crash:m=0@t=2,crash:m=1@t=2,crash:m=2@t=2,crash:m=3@t=2",
         "all .* dead|no alive"),
    ])
    def test_invalid_plans_refused(self, text, match):
        with pytest.raises(ValueError, match=match):
            FaultPlan.parse(text, WORKERS)

    def test_bad_straggle_prob(self):
        with pytest.raises(ValueError, match="straggle_prob"):
            FaultPlan(WORKERS, (), 1.5)

    def test_trivial_lowering(self):
        assert FaultPlan(WORKERS).is_trivial
        eng = PhaseEngine(_loss_fn, SGD(0.05),
                          AveragingSchedule("periodic", 8),
                          faults=FaultPlan(WORKERS))
        assert eng._faults() is None

    def test_shrink(self):
        plan = FaultPlan.shrink(8, 5, step=10)
        assert len(plan.events) == 3
        alive = np.asarray(plan.alive_at(jnp.int32(10)))
        np.testing.assert_array_equal(alive, [1, 1, 1, 1, 1, 0, 0, 0])

    def test_worker_count_mismatch_refused(self):
        eng = PhaseEngine(_loss_fn, SGD(0.05),
                          AveragingSchedule("periodic", 8),
                          faults=FaultPlan.parse("crash:m=1@t=2", 8))
        with pytest.raises(ValueError, match="worker count"):
            eng.run(_params(), _batches(4), num_workers=WORKERS, seed=0)

    def test_faults_with_outer_optimizer_refused(self):
        from repro.core import OuterOptimizer
        eng = PhaseEngine(_loss_fn, SGD(0.05),
                          AveragingSchedule("periodic", 8),
                          outer=OuterOptimizer(lr=0.8, momentum=0.5),
                          faults=FaultPlan.parse("crash:m=1@t=2", WORKERS))
        with pytest.raises(ValueError, match="outer optimizer"):
            eng.run(_params(), _batches(4), num_workers=WORKERS, seed=0)

    def test_straggle_mask_deterministic(self):
        plan = FaultPlan(WORKERS, (), 0.5)
        key = jax.random.PRNGKey(7)
        rows = jnp.arange(WORKERS, dtype=jnp.int32)
        a = np.asarray(plan.straggle_mask(key, jnp.int32(3), rows))
        b = np.asarray(plan.straggle_mask(key, jnp.int32(3), rows))
        np.testing.assert_array_equal(a, b)
        # different steps decorrelate; per-row slices match the full draw
        c = np.asarray(plan.straggle_mask(key, jnp.int32(4), rows))
        assert not np.array_equal(a, c) or True  # may collide, not req.
        half = np.asarray(plan.straggle_mask(key, jnp.int32(3), rows[2:]))
        np.testing.assert_array_equal(a[2:], half)


# --------------------------------------------------------------------------
# Masked-mixing algebra
# --------------------------------------------------------------------------

class TestMaskedAlgebra:
    def test_masked_event_matrix_doubly_stochastic(self):
        alive = jnp.asarray([1.0, 0.0, 1.0, 1.0])
        A = np.asarray(masked_event_matrix(alive))
        np.testing.assert_allclose(A.sum(0), 1.0, atol=1e-6)
        np.testing.assert_allclose(A.sum(1), 1.0, atol=1e-6)
        # the dead row is identity: it neither sends nor receives
        np.testing.assert_array_equal(A[1], np.eye(4)[1])
        np.testing.assert_array_equal(A[:, 1], np.eye(4)[:, 1])

    def test_degraded_matrix_all_alive_is_identity_op(self):
        W = Topology.ring(4).expected_matrix().astype(np.float32)
        out = np.asarray(degraded_matrix(jnp.asarray(W), jnp.ones(4)))
        np.testing.assert_array_equal(out, W)

    def test_degraded_matrix_masks_and_renormalizes(self):
        W = jnp.asarray(Topology.ring(4).expected_matrix(), jnp.float32)
        alive = jnp.asarray([1.0, 0.0, 1.0, 1.0])
        Wm = np.asarray(degraded_matrix(W, alive))
        np.testing.assert_allclose(Wm.sum(1), 1.0, atol=1e-6)
        np.testing.assert_allclose(Wm.sum(0), 1.0, atol=1e-6)
        assert Wm[0, 1] == 0.0 and Wm[1, 0] == 0.0
        np.testing.assert_array_equal(Wm[1], np.eye(4)[1])

    def test_masked_ref_events_keep_dead_rows(self):
        from repro.kernels.ref import plane_average_ref
        plane = jnp.asarray(np.random.default_rng(0).standard_normal(
            (4, 6)), jnp.float32)
        alive = jnp.asarray([1.0, 0.0, 1.0, 1.0])
        out, disp = plane_average_ref(plane, alive=alive)
        glob = np.asarray(masked_mean(plane, alive))
        np.testing.assert_array_equal(np.asarray(out)[1],
                                      np.asarray(plane)[1])
        for i in (0, 2, 3):
            np.testing.assert_array_equal(np.asarray(out)[i], glob)

    def test_all_ones_mask_matches_plain_mean(self):
        from repro.kernels.ref import plane_average_ref
        plane = jnp.asarray(np.random.default_rng(1).standard_normal(
            (4, 6)), jnp.float32)
        out0, d0 = plane_average_ref(plane)
        out1, d1 = plane_average_ref(plane, alive=jnp.ones(4))
        np.testing.assert_allclose(np.asarray(out0), np.asarray(out1),
                                   rtol=1e-6)
        np.testing.assert_allclose(float(d0), float(d1), rtol=1e-6)


# --------------------------------------------------------------------------
# Engine equivalences
# --------------------------------------------------------------------------

SCHEDS = {
    "oneshot": AveragingSchedule("oneshot"),
    "minibatch": AveragingSchedule("minibatch"),
    "periodic": AveragingSchedule("periodic", 8),
    "stochastic": AveragingSchedule("stochastic", zeta=0.2),
    "hierarchical": AveragingSchedule("hierarchical", inner_phase_len=4,
                                      outer_phase_len=8, inner_groups=2),
    "adaptive_threshold": AveragingSchedule("adaptive_threshold",
                                            disp_threshold=0.05),
    "adaptive_budget": AveragingSchedule("adaptive_budget", comm_budget=4,
                                         budget_horizon=STEPS),
}


class TestEngineFaults:
    @pytest.mark.parametrize("sname", list(SCHEDS))
    def test_all_alive_plan_bitwise_equals_no_faults(self, sname):
        sched = SCHEDS[sname]
        batches = _batches()
        f0, h0 = PhaseEngine(_loss_fn, SGD(0.05), sched).run(
            _params(), batches, num_workers=WORKERS, seed=0,
            record_every=4)
        f1, h1 = PhaseEngine(_loss_fn, SGD(0.05), sched,
                             faults=FaultPlan(WORKERS)).run(
            _params(), batches, num_workers=WORKERS, seed=0,
            record_every=4)
        np.testing.assert_array_equal(np.asarray(f0["w"]),
                                      np.asarray(f1["w"]))
        assert h0 == h1

    @pytest.mark.parametrize("sname", ["periodic", "stochastic",
                                       "adaptive_threshold"])
    def test_crash_rejoin_bitwise_across_paths(self, sname):
        sched = SCHEDS[sname]
        plan = FaultPlan.parse(_PLAN, WORKERS, straggle_prob=0.1)
        batches = _batches()
        res = {}
        for name, kw in [("flat_native", {}),
                         ("flat", dict(fused_opt=False)),
                         ("tree", dict(flat=False))]:
            eng = PhaseEngine(_loss_fn, Momentum(0.05, 0.9), sched,
                              faults=plan, **kw)
            f, _ = eng.run(_params(), batches, num_workers=WORKERS,
                           seed=0)
            res[name] = np.asarray(f["w"])
        fh, _ = PhaseEngine(_loss_fn, Momentum(0.05, 0.9), sched,
                            faults=plan).run_host(
            _params(), batches, num_workers=WORKERS, seed=0)
        res["host"] = np.asarray(fh["w"])
        for k in ("flat", "tree", "host"):
            np.testing.assert_array_equal(res["flat_native"], res[k],
                                          err_msg=k)

    def test_compressed_crash_rejoin_bitwise_across_paths(self):
        plan = FaultPlan.parse(_PLAN, WORKERS, straggle_prob=0.1)
        comp = Compression("int8")
        batches = _batches()
        res = {}
        for name, kw in [("flat_native", {}),
                         ("flat", dict(fused_opt=False)),
                         ("tree", dict(flat=False))]:
            eng = PhaseEngine(_loss_fn, SGD(0.05),
                              SCHEDS["periodic"], faults=plan,
                              compression=comp, **kw)
            f, _ = eng.run(_params(), batches, num_workers=WORKERS,
                           seed=0)
            res[name] = np.asarray(f["w"])
        fh, _ = PhaseEngine(_loss_fn, SGD(0.05), SCHEDS["periodic"],
                            faults=plan, compression=comp).run_host(
            _params(), batches, num_workers=WORKERS, seed=0)
        res["host"] = np.asarray(fh["w"])
        for k in ("flat", "tree", "host"):
            np.testing.assert_array_equal(res["flat_native"], res[k],
                                          err_msg=k)

    def test_dead_rows_frozen_and_rejoin_warm_starts(self):
        plan = FaultPlan.parse(_PLAN, WORKERS)
        eng = PhaseEngine(_loss_fn, Momentum(0.05, 0.9),
                          AveragingSchedule("oneshot"), faults=plan)
        batches = _batches()
        # run to just before the rejoin: worker 1 froze at its step-5
        # params (crash step 6 masks its update and every event)
        _, _, st13 = eng.run(_params(), batches[:13],
                             num_workers=WORKERS, seed=0,
                             return_state=True)
        _, _, st5 = eng.run(_params(), batches[:5], num_workers=WORKERS,
                            seed=0, return_state=True)
        np.testing.assert_array_equal(
            np.asarray(st13.worker_params["w"][1]),
            np.asarray(st5.worker_params["w"][1]))
        np.testing.assert_array_equal(np.asarray(st13.fault.alive),
                                      [1.0, 0.0, 1.0, 1.0])
        # at the rejoin step the row warm-starts from the alive mean of
        # the pre-step plane and its momentum is zeroed
        _, _, st14 = eng.run(_params(), batches[:14],
                             num_workers=WORKERS, seed=0,
                             return_state=True)
        assert not np.array_equal(np.asarray(st14.worker_params["w"][1]),
                                  np.asarray(st5.worker_params["w"][1]))
        np.testing.assert_array_equal(np.asarray(st14.fault.alive),
                                      np.ones(WORKERS))

    def test_straggler_only_plan_runs_and_differs(self):
        batches = _batches()
        f0, _ = PhaseEngine(_loss_fn, SGD(0.05),
                            SCHEDS["periodic"]).run(
            _params(), batches, num_workers=WORKERS, seed=0)
        plan = FaultPlan(WORKERS, (), 0.5)
        eng = PhaseEngine(_loss_fn, SGD(0.05), SCHEDS["periodic"],
                          faults=plan)
        f1, _ = eng.run(_params(), batches, num_workers=WORKERS, seed=0)
        f2, _ = eng.run(_params(), batches, num_workers=WORKERS, seed=0)
        # deterministic across repeats, different from the no-fault run
        np.testing.assert_array_equal(np.asarray(f1["w"]),
                                      np.asarray(f2["w"]))
        assert not np.array_equal(np.asarray(f0["w"]),
                                  np.asarray(f1["w"]))


# --------------------------------------------------------------------------
# Checkpointing: resume under faults + the v0..v4 ladder + crash safety
# --------------------------------------------------------------------------

class TestFaultCheckpoints:
    def _engine(self, **kw):
        return PhaseEngine(_loss_fn, Momentum(0.05, 0.9),
                           SCHEDS["adaptive_threshold"],
                           faults=FaultPlan.parse(_PLAN, WORKERS,
                                                  straggle_prob=0.2),
                           **kw)

    def test_resume_inside_fault_window_bitwise(self, tmp_path):
        eng = self._engine()
        batches = _batches()
        fU, hU = eng.run(_params(), batches, num_workers=WORKERS, seed=0)
        # interrupt at step 10 — worker 1 is dead, stragglers mid-stream
        _, _, st = eng.run(_params(), batches[:10], num_workers=WORKERS,
                           seed=0, return_state=True)
        path = os.path.join(tmp_path, "ck")
        save_engine_state(path, st)
        meta = json.load(open(path + ".json"))
        assert meta["extra"]["engine_state_version"] == 4
        assert meta["extra"]["has_resid"] is False
        like = eng.init(_params(), WORKERS, seed=0)
        loaded, at = load_engine_state(path, like)
        assert at == 10
        fR, _ = eng.run(_params(), batches[10:], num_workers=WORKERS,
                        seed=0, state=loaded)
        np.testing.assert_array_equal(np.asarray(fU["w"]),
                                      np.asarray(fR["w"]))

    def test_v4_with_residuals_roundtrip(self, tmp_path):
        eng = self._engine(compression=Compression("int8"))
        _, _, st = eng.run(_params(), _batches()[:10],
                           num_workers=WORKERS, seed=0,
                           return_state=True)
        path = os.path.join(tmp_path, "ck")
        save_engine_state(path, st)
        meta = json.load(open(path + ".json"))
        assert meta["extra"]["engine_state_version"] == 4
        assert meta["extra"]["has_resid"] is True
        like = eng.init(_params(), WORKERS, seed=0)
        loaded, _ = load_engine_state(path, like)
        np.testing.assert_array_equal(np.asarray(st.resid),
                                      np.asarray(loaded.resid))
        np.testing.assert_array_equal(np.asarray(st.fault.alive),
                                      np.asarray(loaded.fault.alive))

    def test_v4_into_no_fault_engine_refused(self, tmp_path):
        eng = self._engine()
        _, _, st = eng.run(_params(), _batches()[:8],
                           num_workers=WORKERS, seed=0,
                           return_state=True)
        path = os.path.join(tmp_path, "ck")
        save_engine_state(path, st)
        plain = PhaseEngine(_loss_fn, Momentum(0.05, 0.9),
                            SCHEDS["adaptive_threshold"])
        with pytest.raises(ValueError, match="no fault plan"):
            load_engine_state(path, plain.init(_params(), WORKERS,
                                               seed=0))

    def test_pre_fault_versions_load_all_alive(self, tmp_path):
        # a v2 (no resid, no fault) checkpoint loads into a fault
        # engine with fresh all-alive rows
        plain = PhaseEngine(_loss_fn, Momentum(0.05, 0.9),
                            SCHEDS["adaptive_threshold"])
        _, _, st = plain.run(_params(), _batches()[:8],
                             num_workers=WORKERS, seed=0,
                             return_state=True)
        path = os.path.join(tmp_path, "v2")
        save_engine_state(path, st)
        assert json.load(open(path + ".json"))[
            "extra"]["engine_state_version"] == 2
        eng = self._engine()
        like = eng.init(_params(), WORKERS, seed=0)
        loaded, at = load_engine_state(path, like)
        assert at == 8
        assert isinstance(loaded.fault, FaultState)
        np.testing.assert_array_equal(np.asarray(loaded.fault.alive),
                                      np.ones(WORKERS))
        np.testing.assert_array_equal(
            np.asarray(st.worker_params["w"]),
            np.asarray(loaded.worker_params["w"]))

    def test_atomic_save_leaves_no_temp_files(self, tmp_path):
        path = os.path.join(tmp_path, "ck")
        save_checkpoint(path, {"w": np.zeros(3)}, step=1)
        assert sorted(os.listdir(tmp_path)) == ["ck.json", "ck.npz"]

    def test_torn_metadata_refused(self, tmp_path):
        path = os.path.join(tmp_path, "ck")
        save_checkpoint(path, {"w": np.zeros(3)}, step=1)
        raw = open(path + ".json").read()
        open(path + ".json", "w").write(raw[:len(raw) // 2])
        with pytest.raises(ValueError, match="torn/partial metadata"):
            load_engine_state(path, None)
        from repro.checkpoint import load_checkpoint
        with pytest.raises(ValueError, match="torn/partial metadata"):
            load_checkpoint(path, {"w": np.zeros(3)})

    def test_torn_arrays_refused(self, tmp_path):
        from repro.checkpoint import load_checkpoint
        path = os.path.join(tmp_path, "ck")
        save_checkpoint(path, {"w": np.zeros(3)}, step=1)
        blob = open(path + ".npz", "rb").read()
        open(path + ".npz", "wb").write(blob[:len(blob) // 2])
        with pytest.raises(ValueError, match="torn/partial array"):
            load_checkpoint(path, {"w": np.zeros(3)})

    def test_missing_arrays_refused(self, tmp_path):
        from repro.checkpoint import load_checkpoint
        path = os.path.join(tmp_path, "ck")
        save_checkpoint(path, {"w": np.zeros(3)}, step=1)
        os.remove(path + ".npz")
        with pytest.raises(ValueError, match="no array file"):
            load_checkpoint(path, {"w": np.zeros(3)})


# --------------------------------------------------------------------------
# Sharded collectives with dead rows (subprocess, 8 host devices)
# --------------------------------------------------------------------------

_SHARD_SCRIPT = r"""
import jax
import jax.numpy as jnp
import numpy as np
from repro.core import AveragingSchedule, PhaseEngine, Compression, FaultPlan

assert len(jax.devices()) == 8, jax.devices()
DIM, WORKERS, STEPS = 8, 8, 24
rng = np.random.default_rng(0)
w_true = rng.standard_normal(DIM)
batches = []
for _ in range(STEPS):
    x = rng.standard_normal((WORKERS, 16, DIM)).astype(np.float32)
    y = (x @ w_true).astype(np.float32)
    batches.append((jnp.asarray(x), jnp.asarray(y)))

def loss_fn(params, batch, rng):
    x, y = batch
    r = x @ params["w"] - y
    return jnp.mean(r * r), {}

params = {"w": jnp.zeros((DIM,), jnp.float32)}
# SGD keeps the single-device and shard_map programs bitwise: the
# momentum update chain (v = mu v + g; p -= lr v) is contraction-bait
# whose FMA fusion LLVM picks per whole-program shape, so its
# cross-sharding identity is not guaranteed (Momentum parity across
# engine paths is asserted by the single-device tests above)
from repro.optim import SGD
opt = lambda: SGD(0.05)
mesh = jax.make_mesh((8,), ("data",))
kw = dict(num_workers=WORKERS, seed=3, record_every=1)
plan = FaultPlan.parse("crash:m=1@t=6,rejoin:m=1@t=14,crash:m=5@t=10",
                       WORKERS, straggle_prob=0.1)
for sched in (AveragingSchedule("periodic", 4),
              AveragingSchedule("adaptive_threshold",
                                disp_threshold=0.05)):
    for comp in (None, Compression("int8")):
        mk = lambda **e: PhaseEngine(loss_fn, opt(), sched, faults=plan,
                                     compression=comp, **e)
        f0, h0 = mk().run(params, batches, **kw)
        # gather collective: bit-identical params AND history
        f1, h1 = mk(mesh=mesh, collective="gather").run(
            params, batches, **kw)
        np.testing.assert_array_equal(np.asarray(f0["w"]),
                                      np.asarray(f1["w"]))
        assert h0 == h1
        # psum collective: same decision stream, f32-roundoff params
        f2, h2 = mk(mesh=mesh, collective="psum").run(
            params, batches, **kw)
        assert h0["averages"] == h2["averages"]
        assert [t for t, _ in h0["dispersion"]] == \
            [t for t, _ in h2["dispersion"]]
        np.testing.assert_allclose(np.asarray(f0["w"]),
                                   np.asarray(f2["w"]),
                                   rtol=1e-5, atol=1e-6)
        print("ok", sched.kind, comp.wire if comp else "f32")
print("ALL-OK")
"""


def test_sharded_collectives_with_dead_rows():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALL-OK" in out.stdout


# --------------------------------------------------------------------------
# Non-IID Dirichlet shards
# --------------------------------------------------------------------------

class TestDirichletSharder:
    def _labels(self, n=400, classes=4, seed=0):
        return np.random.default_rng(seed).integers(0, classes, n)

    def test_requires_labels(self):
        with pytest.raises(ValueError, match="labels"):
            WorkerSharder(100, 4, mode="dirichlet")

    def test_rejects_bad_alpha_and_label_shape(self):
        labels = self._labels()
        with pytest.raises(ValueError, match="alpha"):
            WorkerSharder(400, 4, mode="dirichlet", labels=labels,
                          alpha=0.0)
        with pytest.raises(ValueError, match="cover"):
            WorkerSharder(300, 4, mode="dirichlet", labels=labels)

    def test_deterministic_and_in_pool(self):
        labels = self._labels()
        a = WorkerSharder(400, 4, seed=5, mode="dirichlet", labels=labels)
        b = WorkerSharder(400, 4, seed=5, mode="dirichlet", labels=labels)
        ia, ib = a.next_indices(32), b.next_indices(32)
        np.testing.assert_array_equal(ia, ib)
        for i in range(4):
            assert set(ia[i]) <= set(a._pools[i].tolist())

    def test_small_alpha_skews_labels(self):
        labels = self._labels()
        skew = WorkerSharder(400, 4, seed=1, mode="dirichlet",
                             labels=labels, alpha=0.05)
        near = WorkerSharder(400, 4, seed=1, mode="dirichlet",
                             labels=labels, alpha=100.0)
        def max_frac(sh):
            return sh.class_fractions(labels).max(axis=1).mean()
        # α→0 concentrates each worker on few classes; α→∞ matches the
        # global (uniform) class mix
        assert max_frac(skew) > max_frac(near) + 0.2
        assert all(len(p) > 0 for p in skew._pools)

    def test_block_equals_successive_draws(self):
        labels = self._labels()
        a = WorkerSharder(400, 4, seed=2, mode="dirichlet", labels=labels)
        b = WorkerSharder(400, 4, seed=2, mode="dirichlet", labels=labels)
        blk = a.next_index_block(3, 8)
        seq = np.stack([b.next_indices(8) for _ in range(3)])
        np.testing.assert_array_equal(blk, seq)


# --------------------------------------------------------------------------
# Prefetcher failure handling
# --------------------------------------------------------------------------

class TestPrefetcherFailure:
    def test_error_then_stop_iteration_no_deadlock(self):
        def bad():
            yield 1
            raise RuntimeError("source died")

        pf = Prefetcher(bad())
        assert next(pf) == 1
        with pytest.raises(RuntimeError, match="source died"):
            next(pf)
        # a consumer that catches the error and retries must get a
        # clean end-of-stream, not block forever on the empty queue
        with pytest.raises(StopIteration):
            next(pf)
        pf._thread.join(timeout=5.0)
        assert not pf._thread.is_alive()

    def test_engine_surfaces_producer_error(self):
        def bad_stream():
            yield from _batches(4)
            raise RuntimeError("loader exploded")

        eng = PhaseEngine(_loss_fn, SGD(0.05),
                          AveragingSchedule("periodic", 2))
        with pytest.raises(RuntimeError, match="loader exploded"):
            eng.run(_params(), bad_stream(), num_workers=WORKERS,
                    seed=0, phase_len=2)


# --------------------------------------------------------------------------
# Degraded-topology spectrum + the variance-model hook
# --------------------------------------------------------------------------

class TestDegradedAnalysis:
    def test_effective_gap_all_alive_matches(self):
        topo = Topology.ring(6)
        assert (topo.effective_spectral_gap(np.ones(6))
                == pytest.approx(topo.spectral_gap, abs=1e-8))

    def test_effective_gap_shrinks_with_deaths(self):
        topo = Topology.ring(8)
        alive = np.ones(8)
        alive[3] = 0
        # cutting a ring node leaves a path graph: mixing slows
        assert topo.effective_spectral_gap(alive) < topo.spectral_gap

    def test_effective_gap_disconnected_is_zero(self):
        topo = Topology.blocks(8, 2)
        assert topo.spectral_gap == pytest.approx(0.0, abs=1e-9)
        assert topo.effective_spectral_gap(np.ones(8)) == pytest.approx(
            0.0, abs=1e-9)

    def test_effective_gap_single_survivor(self):
        topo = Topology.ring(4)
        assert topo.effective_spectral_gap([1, 0, 0, 0]) == 1.0

    def test_effective_gap_validates(self):
        topo = Topology.ring(4)
        with pytest.raises(ValueError, match="alive"):
            topo.effective_spectral_gap(np.ones(5))
        with pytest.raises(ValueError, match="alive"):
            topo.effective_spectral_gap(np.zeros(4))

    def test_predict_benefit_qualitative(self):
        iid = predict_averaging_benefit([1.0, 1.0, 1.0, 1.0])
        skew = predict_averaging_benefit([4.0, 3.0, 2.0, 3.0])
        # non-IID shards measure higher σ² -> larger absolute benefit
        assert skew["benefit"] > iid["benefit"]
        assert iid["variance_reduction"] == 0.25
        # dead workers shrink n: weaker reduction (larger 1/n)
        degraded = predict_averaging_benefit([1.0, 1.0, 1.0, 1.0],
                                             alive=[1, 0, 1, 0])
        assert degraded["n_alive"] == 2
        assert (degraded["variance_reduction"]
                > iid["variance_reduction"])
        assert degraded["benefit"] < iid["benefit"]
        with pytest.raises(ValueError):
            predict_averaging_benefit([1.0], alive=[0.0])

"""Tests for repro.analysis: per-rule seeded violations (plus clean
twins), suppression comments, baseline round-trip, and the committed
tree staying clean.

Each fixture builds a miniature repo tree under tmp_path (the analyzer
only reads ``src/``, ``tests/``, ``benchmarks/``) and runs a single rule
against it, so a finding can only come from the seeded violation.
"""
from __future__ import annotations

import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (
    RepoModel,
    analyze,
    get_rule,
    load_baseline,
    save_baseline,
)
from repro.analysis.__main__ import main as cli_main
from repro.analysis.runner import run_rules

REPO_ROOT = Path(__file__).resolve().parents[1]


def write_tree(root: Path, files: dict) -> Path:
    for rel, text in files.items():
        path = root / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(textwrap.dedent(text))
    return root


def findings_for(root: Path, rule_id: str):
    model = RepoModel.load(root)
    return run_rules(model, [get_rule(rule_id)])


# ---------------------------------------------------------------- trace-purity

JIT_BRANCH = """
    import jax

    @jax.jit
    def step(x):
        if x > 0:
            return x
        return -x
"""

JIT_CLEAN = """
    import jax
    import jax.numpy as jnp

    @jax.jit
    def step(x):
        return jnp.where(x > 0, x, -x)
"""


class TestTracePurity:
    def test_branch_on_traced_value_flagged(self, tmp_path):
        write_tree(tmp_path, {"src/repro/foo.py": JIT_BRANCH})
        found = findings_for(tmp_path, "trace-purity")
        assert len(found) == 1
        assert "`if` on a traced value" in found[0].message
        assert found[0].path == "src/repro/foo.py"

    def test_clean_twin_passes(self, tmp_path):
        write_tree(tmp_path, {"src/repro/foo.py": JIT_CLEAN})
        assert findings_for(tmp_path, "trace-purity") == []

    def test_scan_body_coercion_flagged(self, tmp_path):
        write_tree(tmp_path, {"src/repro/foo.py": """
            import jax

            def run(xs):
                def body(c, x):
                    c = c + float(x)
                    return c, c
                return jax.lax.scan(body, 0.0, xs)
        """})
        found = findings_for(tmp_path, "trace-purity")
        assert len(found) == 1
        assert "float()" in found[0].message

    def test_static_argnames_not_tainted(self, tmp_path):
        write_tree(tmp_path, {"src/repro/foo.py": """
            from functools import partial
            import jax

            @partial(jax.jit, static_argnames=("k",))
            def step(x, k):
                if k:
                    return x + 1
                return x
        """})
        assert findings_for(tmp_path, "trace-purity") == []

    def test_numpy_coercion_and_impure_calls(self, tmp_path):
        write_tree(tmp_path, {"src/repro/foo.py": """
            import time
            import numpy as np
            import jax

            @jax.jit
            def step(x):
                t = time.time()
                return np.asarray(x) * t
        """})
        msgs = [f.message for f in findings_for(tmp_path, "trace-purity")]
        assert any("`time`" in m for m in msgs)
        assert any("np.*" in m for m in msgs)

    def test_interprocedural_taint(self, tmp_path):
        write_tree(tmp_path, {"src/repro/foo.py": """
            import jax

            def helper(y):
                assert y > 0
                return y

            @jax.jit
            def step(x):
                return helper(x)
        """})
        found = findings_for(tmp_path, "trace-purity")
        assert len(found) == 1
        assert "`assert` on a traced value" in found[0].message
        assert "helper" in found[0].message

    def test_suppression_comment(self, tmp_path):
        src = JIT_BRANCH.replace(
            "if x > 0:",
            "if x > 0:  # analysis: ignore[trace-purity] -- fixture",
        )
        write_tree(tmp_path, {"src/repro/foo.py": src})
        assert findings_for(tmp_path, "trace-purity") == []

    def test_wrong_rule_suppression_does_not_apply(self, tmp_path):
        src = JIT_BRANCH.replace(
            "if x > 0:", "if x > 0:  # analysis: ignore[rng-salt]"
        )
        write_tree(tmp_path, {"src/repro/foo.py": src})
        assert len(findings_for(tmp_path, "trace-purity")) == 1


# ------------------------------------------------------------------- rng-salt

class TestRngSalt:
    def test_colliding_streams_flagged(self, tmp_path):
        write_tree(tmp_path, {"src/repro/foo.py": """
            import jax

            _SALT = 7

            def a(key, step):
                return jax.random.fold_in(jax.random.fold_in(key, _SALT), step)

            def b(key, step):
                return jax.random.fold_in(jax.random.fold_in(key, _SALT), step)
        """})
        found = findings_for(tmp_path, "rng-salt")
        assert len(found) == 1
        assert "collides" in found[0].message

    def test_distinct_salts_pass(self, tmp_path):
        write_tree(tmp_path, {"src/repro/foo.py": """
            import jax

            _A_SALT = 7
            _B_SALT = 8

            def a(key, step):
                return jax.random.fold_in(jax.random.fold_in(key, _A_SALT), step)

            def b(key, step):
                return jax.random.fold_in(jax.random.fold_in(key, _B_SALT), step)
        """})
        assert findings_for(tmp_path, "rng-salt") == []

    def test_duplicate_salt_constants_flagged(self, tmp_path):
        write_tree(tmp_path, {
            "src/repro/a.py": "_GOSSIP_SALT = 5\n",
            "src/repro/b.py": "_ENC_SALT = 5\n",
        })
        found = findings_for(tmp_path, "rng-salt")
        assert len(found) == 1
        assert "duplicates" in found[0].message

    def test_key_reuse_after_split_flagged(self, tmp_path):
        write_tree(tmp_path, {"src/repro/foo.py": """
            import jax

            def f(key):
                k1, k2 = jax.random.split(key)
                return jax.random.normal(key, (2,))
        """})
        found = findings_for(tmp_path, "rng-salt")
        assert len(found) == 1
        assert "used after" in found[0].message

    def test_rebound_key_passes(self, tmp_path):
        write_tree(tmp_path, {"src/repro/foo.py": """
            import jax

            def f(key):
                key, sub = jax.random.split(key)
                return jax.random.normal(sub, (2,))
        """})
        assert findings_for(tmp_path, "rng-salt") == []

    def test_registry_covers_real_tree(self):
        from repro.analysis.rules.rng_salt import registry

        sites = registry(RepoModel.load(REPO_ROOT))
        rels = {s.mod.rel for s in sites}
        assert "src/repro/core/compress.py" in rels
        assert "src/repro/faults.py" in rels
        assert "src/repro/topology.py" in rels
        assert "src/repro/core/averaging.py" in rels
        # every head stream resolves to a distinct chain
        heads = [s for s in sites if s.is_head]
        assert len(heads) >= 4


# ---------------------------------------------------------------- kernel-twin

KERNEL_TREE = {
    "src/repro/kernels/foo.py": """
        from jax.experimental import pallas as pl

        def _foo_kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def foo(x, *, block_p=8, interpret=False):
            return pl.pallas_call(_foo_kernel)(x)
    """,
    "src/repro/kernels/ref.py": """
        TWINS = {"foo": "foo_ref"}

        def foo_ref(x):
            return x
    """,
    "tests/test_foo.py": """
        from repro.kernels.foo import foo
        from repro.kernels.ref import foo_ref

        def test_eq():
            assert foo is not foo_ref
    """,
}


class TestKernelTwin:
    def test_complete_registration_passes(self, tmp_path):
        write_tree(tmp_path, KERNEL_TREE)
        assert findings_for(tmp_path, "kernel-twin") == []

    def test_unregistered_kernel_flagged(self, tmp_path):
        files = dict(KERNEL_TREE)
        files["src/repro/kernels/ref.py"] = """
            TWINS = {}

            def foo_ref(x):
                return x
        """
        write_tree(tmp_path, files)
        found = findings_for(tmp_path, "kernel-twin")
        assert any("no TWINS entry" in f.message for f in found)

    def test_deleted_twin_flagged(self, tmp_path):
        files = dict(KERNEL_TREE)
        files["src/repro/kernels/ref.py"] = 'TWINS = {"foo": "foo_ref"}\n'
        write_tree(tmp_path, files)
        found = findings_for(tmp_path, "kernel-twin")
        assert any("not defined in" in f.message for f in found)

    def test_signature_drift_flagged(self, tmp_path):
        files = dict(KERNEL_TREE)
        files["src/repro/kernels/foo.py"] = """
            from jax.experimental import pallas as pl

            def _foo_kernel(x_ref, o_ref):
                o_ref[...] = x_ref[...]

            def foo(x, *, alpha=0.5, block_p=8, interpret=False):
                return pl.pallas_call(_foo_kernel)(x)
        """
        write_tree(tmp_path, files)
        found = findings_for(tmp_path, "kernel-twin")
        assert any("twin-signature drift" in f.message and "alpha" in f.message
                   for f in found)

    def test_missing_equivalence_test_flagged(self, tmp_path):
        files = {k: v for k, v in KERNEL_TREE.items() if not k.startswith("tests/")}
        files["tests/test_other.py"] = "def test_nothing():\n    pass\n"
        write_tree(tmp_path, files)
        found = findings_for(tmp_path, "kernel-twin")
        assert any("no equivalence test" in f.message for f in found)

    def test_stale_twins_entry_flagged(self, tmp_path):
        files = dict(KERNEL_TREE)
        files["src/repro/kernels/ref.py"] = """
            TWINS = {"foo": "foo_ref", "bar": "bar_ref"}

            def foo_ref(x):
                return x

            def bar_ref(x):
                return x
        """
        write_tree(tmp_path, files)
        found = findings_for(tmp_path, "kernel-twin")
        assert any("stale TWINS entry" in f.message for f in found)


# ---------------------------------------------------------- checkpoint-ladder

CKPT_TREE = {
    "src/repro/checkpoint/io.py": """
        ENGINE_STATE_VERSION = 2
        _VERSION_KEY = "engine_state_version"
        _OPTIONAL_FIELDS = ("sched",)

        def load_engine_state(path, like_state):
            version = 0
            if version > ENGINE_STATE_VERSION:
                raise ValueError("future version")
            if version == 0:
                return like_state._replace()
            if version == 1:
                return like_state._replace()
            return like_state._replace()
    """,
    "src/repro/core/engine.py": """
        from typing import NamedTuple

        class EngineState(NamedTuple):
            params: tuple
            step: int
            sched: tuple = ()
    """,
    "tests/test_ckpt.py": """
        def test_v0_roundtrip():
            payload = {"engine_state_version": 0}
            assert payload

        def test_v1_roundtrip():
            build_legacy(version=1)

        def build_legacy(version):
            return version
    """,
}


class TestCheckpointLadder:
    def test_complete_ladder_passes(self, tmp_path):
        write_tree(tmp_path, CKPT_TREE)
        assert findings_for(tmp_path, "checkpoint-ladder") == []

    def test_deleted_loader_branch_flagged(self, tmp_path):
        files = dict(CKPT_TREE)
        files["src/repro/checkpoint/io.py"] = files[
            "src/repro/checkpoint/io.py"
        ].replace(
            "            if version == 1:\n"
            "                return like_state._replace()\n",
            "",
        )
        write_tree(tmp_path, files)
        found = findings_for(tmp_path, "checkpoint-ladder")
        assert any("no loader branch for layout version 1" in f.message
                   for f in found)

    def test_missing_future_guard_flagged(self, tmp_path):
        files = dict(CKPT_TREE)
        files["src/repro/checkpoint/io.py"] = files[
            "src/repro/checkpoint/io.py"
        ].replace(
            "            if version > ENGINE_STATE_VERSION:\n"
            "                raise ValueError(\"future version\")\n",
            "",
        )
        write_tree(tmp_path, files)
        found = findings_for(tmp_path, "checkpoint-ladder")
        assert any("refuse payloads" in f.message for f in found)

    def test_optional_fields_drift_flagged(self, tmp_path):
        files = dict(CKPT_TREE)
        files["src/repro/checkpoint/io.py"] = files[
            "src/repro/checkpoint/io.py"
        ].replace('_OPTIONAL_FIELDS = ("sched",)',
                  '_OPTIONAL_FIELDS = ("sched", "resid")')
        write_tree(tmp_path, files)
        found = findings_for(tmp_path, "checkpoint-ladder")
        assert any("does not match" in f.message for f in found)

    def test_untested_version_flagged(self, tmp_path):
        files = dict(CKPT_TREE)
        files["tests/test_ckpt.py"] = """
            def test_v0_roundtrip():
                payload = {"engine_state_version": 0}
                assert payload
        """
        write_tree(tmp_path, files)
        found = findings_for(tmp_path, "checkpoint-ladder")
        assert any("version(s) [1]" in f.message for f in found)


# ---------------------------------------------------------- eager-validation

class TestEagerValidation:
    def test_validating_constructor_passes(self, tmp_path):
        write_tree(tmp_path, {"src/repro/core/averaging.py": """
            class AveragingSchedule:
                def __post_init__(self):
                    if self.period <= 0:
                        raise ValueError("period must be positive")
        """})
        assert findings_for(tmp_path, "eager-validation") == []

    def test_missing_validation_flagged(self, tmp_path):
        write_tree(tmp_path, {"src/repro/core/averaging.py": """
            class AveragingSchedule:
                def __post_init__(self):
                    self.warmup = 0
        """})
        found = findings_for(tmp_path, "eager-validation")
        assert len(found) == 1
        assert "no eager validation" in found[0].message

    def test_parser_error_counts_for_main(self, tmp_path):
        write_tree(tmp_path, {"src/repro/launch/train.py": """
            import argparse

            def main():
                ap = argparse.ArgumentParser()
                args = ap.parse_args()
                if args.workers < 1:
                    ap.error("need at least one worker")
        """})
        assert findings_for(tmp_path, "eager-validation") == []


# --------------------------------------------------------- jit-cache-hygiene

HYGIENE_CONFTEST = """
    import jax
    import pytest

    @pytest.fixture(autouse=True, scope="module")
    def _release_compiled_executables():
        yield
        jax.clear_caches()
"""


class TestJitCacheHygiene:
    def test_convention_respected_passes(self, tmp_path):
        write_tree(tmp_path, {
            "tests/conftest.py": HYGIENE_CONFTEST,
            "tests/test_ok.py": """
                import jax

                def test_ok():
                    f = jax.jit(lambda x: x)
                    assert f is not None
            """,
        })
        assert findings_for(tmp_path, "jit-cache-hygiene") == []

    def test_missing_fixture_flagged(self, tmp_path):
        write_tree(tmp_path, {
            "tests/conftest.py": "import jax\n",
            "tests/test_ok.py": "def test_ok():\n    pass\n",
        })
        found = findings_for(tmp_path, "jit-cache-hygiene")
        assert any("module-scoped autouse" in f.message for f in found)

    def test_import_time_executable_flagged(self, tmp_path):
        write_tree(tmp_path, {
            "tests/conftest.py": HYGIENE_CONFTEST,
            "tests/test_leak.py": """
                import jax

                f = jax.jit(lambda x: x)

                def test_leak():
                    assert f is not None
            """,
        })
        found = findings_for(tmp_path, "jit-cache-hygiene")
        assert any("import-time" in f.message for f in found)

    def test_ad_hoc_clear_caches_flagged(self, tmp_path):
        write_tree(tmp_path, {
            "tests/conftest.py": HYGIENE_CONFTEST,
            "tests/test_adhoc.py": """
                import jax

                def test_adhoc():
                    jax.clear_caches()
            """,
        })
        found = findings_for(tmp_path, "jit-cache-hygiene")
        assert any("ad-hoc" in f.message for f in found)


# ------------------------------------------------------ telemetry-host-sync

TELE_METRICS_OK = """
    import jax.numpy as jnp
    import numpy as np

    FLUSH_FUNCTIONS = ("flush_metrics",)

    def accumulate(acc, loss):
        return acc + jnp.asarray(loss)

    def flush_metrics(vec):
        v = np.asarray(vec)
        return {"loss": float(v[0]), "steps": int(v[1])}
"""


class TestTelemetryHostSync:
    def test_flush_functions_exempt(self, tmp_path):
        write_tree(tmp_path, {
            "src/repro/telemetry/metrics.py": TELE_METRICS_OK,
        })
        assert findings_for(tmp_path, "telemetry-host-sync") == []

    def test_coercion_outside_flush_flagged(self, tmp_path):
        write_tree(tmp_path, {
            "src/repro/telemetry/metrics.py": TELE_METRICS_OK,
            "src/repro/telemetry/extra.py": """
                import jax

                def peek(acc):
                    return float(acc[0])
            """,
        })
        found = findings_for(tmp_path, "telemetry-host-sync")
        assert any(f.path.endswith("extra.py")
                   and "`float()`" in f.message for f in found)

    def test_item_and_device_get_flagged(self, tmp_path):
        write_tree(tmp_path, {
            "src/repro/telemetry/metrics.py": TELE_METRICS_OK,
            "src/repro/telemetry/extra.py": """
                import jax

                def peek(acc):
                    return jax.device_get(acc), acc[0].item()
            """,
        })
        found = findings_for(tmp_path, "telemetry-host-sync")
        msgs = " | ".join(f.message for f in found)
        assert "`device_get`" in msgs and "`.item()`" in msgs

    def test_numpy_materializer_flagged_jnp_legal(self, tmp_path):
        write_tree(tmp_path, {
            "src/repro/telemetry/metrics.py": TELE_METRICS_OK,
            "src/repro/telemetry/extra.py": """
                import jax.numpy as jnp
                import numpy as np

                def fold(acc):
                    return jnp.asarray(acc) + 1  # on-device: legal

                def leak(acc):
                    return np.asarray(acc)
            """,
        })
        found = findings_for(tmp_path, "telemetry-host-sync")
        assert len(found) == 1
        assert "materializes" in found[0].message

    def test_module_without_jax_out_of_scope(self, tmp_path):
        write_tree(tmp_path, {
            "src/repro/telemetry/metrics.py": TELE_METRICS_OK,
            "src/repro/telemetry/report.py": """
                import json

                def render(path):
                    return float(json.loads(path)["loss"])
            """,
        })
        assert findings_for(tmp_path, "telemetry-host-sync") == []

    def test_missing_registry_flagged(self, tmp_path):
        write_tree(tmp_path, {
            "src/repro/telemetry/metrics.py": """
                import jax.numpy as jnp

                def accumulate(acc):
                    return acc
            """,
        })
        found = findings_for(tmp_path, "telemetry-host-sync")
        assert any("FLUSH_FUNCTIONS registry missing" in f.message
                   for f in found)

    def test_stale_registry_entry_flagged(self, tmp_path):
        write_tree(tmp_path, {
            "src/repro/telemetry/metrics.py": """
                import jax.numpy as jnp

                FLUSH_FUNCTIONS = ("flush_metrics", "gone")

                def flush_metrics(vec):
                    return float(vec[0])
            """,
        })
        found = findings_for(tmp_path, "telemetry-host-sync")
        assert any("'gone'" in f.message for f in found)


# ------------------------------------------------------- baseline round-trip

class TestBaseline:
    def test_baseline_accepts_known_findings(self, tmp_path):
        write_tree(tmp_path, {"src/repro/foo.py": JIT_BRANCH})
        rules = [get_rule("trace-purity")]
        report = analyze(tmp_path, rules=rules)
        assert not report.ok and len(report.new) == 1
        save_baseline(tmp_path, report.findings,
                      {report.findings[0].fingerprint: "fixture exception"})
        report2 = analyze(tmp_path, rules=rules)
        assert report2.ok
        assert len(report2.accepted) == 1 and report2.new == []

    def test_stale_baseline_entry_fails(self, tmp_path):
        write_tree(tmp_path, {"src/repro/foo.py": JIT_CLEAN})
        (tmp_path / "analysis-baseline.json").write_text(json.dumps({
            "version": 1,
            "findings": [{"fingerprint": "deadbeefdeadbeef",
                          "justification": "gone"}],
        }))
        report = analyze(tmp_path, rules=[get_rule("trace-purity")])
        assert not report.ok
        assert report.stale_baseline == ["deadbeefdeadbeef"]

    def test_unjustified_baseline_entry_rejected(self, tmp_path):
        write_tree(tmp_path, {"src/repro/foo.py": JIT_CLEAN})
        (tmp_path / "analysis-baseline.json").write_text(json.dumps({
            "version": 1,
            "findings": [{"fingerprint": "deadbeefdeadbeef"}],
        }))
        with pytest.raises(ValueError, match="justification"):
            load_baseline(tmp_path)

    def test_fingerprint_is_line_insensitive(self, tmp_path):
        write_tree(tmp_path, {"src/repro/foo.py": JIT_BRANCH})
        rules = [get_rule("trace-purity")]
        fp1 = analyze(tmp_path, rules=rules).findings[0].fingerprint
        # shift the finding down two lines; fingerprint must not move
        write_tree(tmp_path, {"src/repro/foo.py": "# pad\n# pad\n" +
                              textwrap.dedent(JIT_BRANCH)})
        report = analyze(tmp_path, rules=rules)
        assert report.findings[0].fingerprint == fp1


# ------------------------------------------------------------------ CLI + API

class TestCli:
    def test_json_output_and_exit_codes(self, tmp_path, capsys):
        write_tree(tmp_path, {"src/repro/foo.py": JIT_BRANCH})
        rc = cli_main(["--root", str(tmp_path), "--format", "json",
                       "--rules", "trace-purity"])
        out = json.loads(capsys.readouterr().out)
        assert rc == 1
        assert out["ok"] is False and out["counts"]["new"] == 1
        assert out["new"][0]["rule"] == "trace-purity"

    def test_update_baseline_then_clean_exit(self, tmp_path, capsys):
        write_tree(tmp_path, {"src/repro/foo.py": JIT_BRANCH})
        rc = cli_main(["--root", str(tmp_path), "--rules", "trace-purity",
                       "--update-baseline"])
        assert rc == 0
        capsys.readouterr()
        rc = cli_main(["--root", str(tmp_path), "--rules", "trace-purity"])
        assert rc == 0
        assert "[baseline]" in capsys.readouterr().out

    def test_list_rules_names_all_five_contracts(self, tmp_path, capsys):
        rc = cli_main(["--list-rules"])
        assert rc == 0
        out = capsys.readouterr().out
        for rule_id in ("trace-purity", "rng-salt", "kernel-twin",
                        "checkpoint-ladder", "eager-validation",
                        "jit-cache-hygiene"):
            assert rule_id in out

    def test_output_file_written(self, tmp_path, capsys):
        write_tree(tmp_path, {"src/repro/foo.py": JIT_CLEAN})
        out_path = tmp_path / "artifacts" / "analysis.json"
        rc = cli_main(["--root", str(tmp_path), "--rules", "trace-purity",
                       "--output", str(out_path)])
        assert rc == 0
        assert json.loads(out_path.read_text())["ok"] is True


class TestRealTree:
    def test_committed_tree_is_clean(self):
        report = analyze(REPO_ROOT)
        assert report.ok, report.to_text()

    def test_real_twins_registry_complete(self):
        from repro.analysis.rules.kernel_twin import discover_kernels

        model = RepoModel.load(REPO_ROOT)
        kernels = {name for _, name, _ in discover_kernels(model)}
        assert {"opt_step", "avg_disp", "mix_disp", "avg_disp_outer",
                "compressed_mix", "flash_attention"} <= kernels

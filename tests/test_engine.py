"""Phase-engine correctness: the compiled phase program (one donated
scan per phase, on-device averaging decisions) must match the step-by-step
host-driven loop numerically — same final consensus params, same loss
trace, same averaging events — for all four paper schedules, and be
invariant to how steps are blocked into phases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AveragingSchedule, EngineState, LocalSGD,
                        OuterOptimizer, PhaseEngine, consensus, tree_stack)
from repro.optim import SGD, Momentum

WORKERS, STEPS, DIM, SAMPLES = 4, 65, 12, 256


def _convex_problem(seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((SAMPLES, DIM))
    w_true = rng.standard_normal(DIM)
    y = X @ w_true + 0.1 * rng.standard_normal(SAMPLES)
    return jnp.asarray(X), jnp.asarray(y)


def _loss_fn(params, batch, rng):
    r = batch["x"] @ params["w"]["inner"] - batch["y"]
    return 0.5 * jnp.mean(r * r), {}


def _params():
    # nested dict on purpose: the engine must be tree-structure agnostic
    return {"w": {"inner": jnp.zeros(DIM)}}


def _batches(X, y, workers=WORKERS, steps=STEPS, seed=1):
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        idx = rng.integers(0, SAMPLES, (workers, 8))
        yield {"x": X[idx], "y": y[idx]}


SCHEDULES = {
    "oneshot": AveragingSchedule("oneshot"),
    "minibatch": AveragingSchedule("minibatch"),
    "periodic": AveragingSchedule("periodic", 8),
    "stochastic": AveragingSchedule("stochastic", zeta=0.2),
    "hierarchical": AveragingSchedule("hierarchical", inner_phase_len=5,
                                      outer_phase_len=20, inner_groups=2),
    # stateful kinds: the engine's decisions consume the on-device
    # per-step dispersion through SchedState; the host loop must replay
    # the identical decision sequence from its own dispersion stream
    "adaptive_threshold": AveragingSchedule("adaptive_threshold",
                                            disp_threshold=0.05,
                                            disp_ema_beta=0.5),
    "adaptive_budget": AveragingSchedule("adaptive_budget", comm_budget=6,
                                         budget_horizon=STEPS),
}


@pytest.mark.parametrize("name", list(SCHEDULES))
def test_engine_matches_host_loop(name):
    """Compiled phase == step-by-step dispatch, bit-for-bit history."""
    X, y = _convex_problem()
    engine = PhaseEngine(_loss_fn, SGD(lr=0.05), SCHEDULES[name])
    f_eng, h_eng = engine.run(_params(), _batches(X, y), seed=3,
                              num_workers=WORKERS, record_every=1)
    f_host, h_host = engine.run_host(_params(), _batches(X, y), seed=3,
                                     num_workers=WORKERS, record_every=1)
    np.testing.assert_allclose(np.asarray(f_eng["w"]["inner"]),
                               np.asarray(f_host["w"]["inner"]),
                               rtol=1e-6, atol=1e-7)
    assert h_eng["averages"] == h_host["averages"]
    assert [t for t, _ in h_eng["dispersion"]] == \
        [t for t, _ in h_host["dispersion"]]
    np.testing.assert_allclose([v for _, v in h_eng["loss"]],
                               [v for _, v in h_host["loss"]],
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose([v for _, v in h_eng["dispersion"]],
                               [v for _, v in h_host["dispersion"]],
                               rtol=1e-5, atol=1e-8)


@pytest.mark.parametrize("block", [1, 7, 32, 100])
def test_engine_block_size_invariance(block):
    """Phase blocking is a perf knob, not semantics: any block size gives
    the identical trajectory (decisions are per-step, on-device)."""
    X, y = _convex_problem()
    engine = PhaseEngine(_loss_fn, SGD(lr=0.05),
                         AveragingSchedule("periodic", 8))
    ref, _ = engine.run(_params(), _batches(X, y), num_workers=WORKERS,
                        seed=0, phase_len=8)
    got, _ = engine.run(_params(), _batches(X, y), num_workers=WORKERS,
                        seed=0, phase_len=block)
    np.testing.assert_array_equal(np.asarray(ref["w"]["inner"]),
                                  np.asarray(got["w"]["inner"]))


def test_engine_unroll_is_equivalent():
    """scan_unroll (the CPU-backend speed knob) must not change numerics,
    including on partial final blocks."""
    X, y = _convex_problem()
    sch = AveragingSchedule("periodic", 8)
    ref, h_ref = PhaseEngine(_loss_fn, SGD(lr=0.05), sch).run(
        _params(), _batches(X, y), num_workers=WORKERS, seed=1,
        record_every=1)
    got, h_got = PhaseEngine(_loss_fn, SGD(lr=0.05), sch,
                             scan_unroll=True).run(
        _params(), _batches(X, y), num_workers=WORKERS, seed=1,
        record_every=1)
    np.testing.assert_allclose(np.asarray(ref["w"]["inner"]),
                               np.asarray(got["w"]["inner"]),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose([v for _, v in h_ref["loss"]],
                               [v for _, v in h_got["loss"]],
                               rtol=1e-6, atol=1e-7)


def test_engine_with_outer_optimizer_matches_host():
    """The DiLoCo-style outer optimizer state threads through the scan
    carry exactly as through the host loop."""
    X, y = _convex_problem()
    engine = PhaseEngine(_loss_fn, Momentum(lr=0.05, mu=0.9),
                         AveragingSchedule("periodic", 8),
                         outer=OuterOptimizer(lr=0.8, momentum=0.5))
    f_eng, h_eng = engine.run(_params(), _batches(X, y), seed=5,
                              num_workers=WORKERS, record_every=1)
    f_host, h_host = engine.run_host(_params(), _batches(X, y), seed=5,
                                     num_workers=WORKERS, record_every=1)
    np.testing.assert_allclose(np.asarray(f_eng["w"]["inner"]),
                               np.asarray(f_host["w"]["inner"]),
                               rtol=1e-6, atol=1e-7)
    assert h_eng["averages"] == h_host["averages"] == STEPS // 8


def test_engine_state_resumable():
    """run_phase is a pure state transition: splitting one run into two
    run_phase calls equals one big call (checkpoint/resume safety)."""
    X, y = _convex_problem()
    engine = PhaseEngine(_loss_fn, SGD(lr=0.05),
                         AveragingSchedule("stochastic", zeta=0.3))
    blocks = list(_batches(X, y, steps=24))
    s1 = engine.init(_params(), WORKERS, seed=9)
    s1, tr_a = engine.run_phase(s1, tree_stack(blocks[:10]))
    s1, tr_b = engine.run_phase(s1, tree_stack(blocks[10:]))
    s2 = engine.init(_params(), WORKERS, seed=9)
    s2, tr = engine.run_phase(s2, tree_stack(blocks))
    assert isinstance(s1, EngineState) and int(s1.step) == int(s2.step) == 24
    np.testing.assert_array_equal(
        np.asarray(consensus(s1.worker_params)["w"]["inner"]),
        np.asarray(consensus(s2.worker_params)["w"]["inner"]))
    np.testing.assert_array_equal(
        np.concatenate([tr_a["avg_code"], tr_b["avg_code"]]),
        np.asarray(tr["avg_code"]))


def test_engine_history_semantics():
    """Averaging count, dispersion timestamps and loss records follow the
    schedule; dispersion is measured BEFORE the average collapses it."""
    X, y = _convex_problem()
    engine = PhaseEngine(_loss_fn, SGD(lr=0.05),
                         AveragingSchedule("periodic", 10))
    _, hist = engine.run(_params(), _batches(X, y, steps=40),
                         num_workers=WORKERS, seed=0, record_every=10)
    assert hist["averages"] == 4
    assert [t for t, _ in hist["dispersion"]] == [10, 20, 30, 40]
    assert [t for t, _ in hist["loss"]] == [10, 20, 30, 40]
    assert all(v > 0 for _, v in hist["dispersion"])


def test_engine_eval_fns_at_record_boundaries():
    X, y = _convex_problem()
    engine = PhaseEngine(_loss_fn, SGD(lr=0.05),
                         AveragingSchedule("periodic", 8))
    calls = []

    def eval_fn(p):
        calls.append(p["w"]["inner"].shape)
        return 1.0

    def worker_eval_fn(wp):
        assert jax.tree.leaves(wp)[0].shape[0] == WORKERS
        return 2.0

    _, hist = engine.run(_params(), _batches(X, y, steps=50),
                         num_workers=WORKERS, seed=0, record_every=20,
                         eval_fn=eval_fn, worker_eval_fn=worker_eval_fn)
    assert [t for t, _ in hist["eval"]] == [20, 40]
    assert [t for t, _ in hist["worker_eval"]] == [20, 40]
    assert calls == [(DIM,), (DIM,)]  # consensus params, no worker axis


def test_localsgd_average_without_outer_state_falls_back_to_mean():
    """Legacy contract: with an outer optimizer configured but no state
    yet, average() applies the paper's plain mean instead of crashing."""
    algo = LocalSGD(_loss_fn, SGD(lr=0.05), AveragingSchedule("periodic", 8),
                    outer=OuterOptimizer(lr=0.8, momentum=0.5))
    wp = {"w": {"inner": jnp.arange(WORKERS * DIM, dtype=jnp.float32)
                .reshape(WORKERS, DIM)}}
    avg_wp, outer_state, disp = algo.average(wp, None)
    assert outer_state is None
    np.testing.assert_allclose(
        np.asarray(avg_wp["w"]["inner"]),
        np.broadcast_to(np.asarray(wp["w"]["inner"]).mean(0), (WORKERS, DIM)),
        rtol=1e-6)
    assert float(disp) > 0


def test_localsgd_wrapper_delegates_to_engine():
    """LocalSGD.run is a thin wrapper: identical output to PhaseEngine.run
    with the same seed and schedule."""
    X, y = _convex_problem()
    sch = AveragingSchedule("periodic", 8)
    algo = LocalSGD(_loss_fn, SGD(lr=0.05), sch)
    f_a, h_a = algo.run(_params(), _batches(X, y), num_workers=WORKERS,
                        seed=2, record_every=5)
    f_b, h_b = algo.engine.run(_params(), _batches(X, y),
                               num_workers=WORKERS, seed=2, record_every=5)
    np.testing.assert_array_equal(np.asarray(f_a["w"]["inner"]),
                                  np.asarray(f_b["w"]["inner"]))
    assert h_a["loss"] == h_b["loss"]
    assert h_a["averages"] == h_b["averages"]

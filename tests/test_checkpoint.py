"""Checkpoint/resume of a full EngineState.

A run interrupted at any phase boundary, checkpointed with
``save_engine_state`` and resumed with ``run(state=...)`` must be
bit-identical to the uninterrupted run — params, optimizer moments,
outer state, PRNG streams and averaging decisions all carry over (the
stochastic schedule's draws are pure functions of (dec_key, step)).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (load_checkpoint, load_engine_state,
                              save_checkpoint, save_engine_state)
from repro.core import AveragingSchedule, OuterOptimizer, PhaseEngine
from repro.optim import AdamW, Momentum

DIM, SAMPLES, WORKERS, STEPS = 12, 256, 4, 64


def _problem(seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((SAMPLES, DIM))
    y = X @ rng.standard_normal(DIM)
    idx = rng.integers(0, SAMPLES, (STEPS, WORKERS, 8))
    Xj, yj = jnp.asarray(X), jnp.asarray(y)

    def batches(a, b):
        return [{"x": Xj[idx[t]], "y": yj[idx[t]]} for t in range(a, b)]

    return batches


def _loss(params, batch, rng):
    r = batch["x"] @ params["w"] - batch["y"]
    return 0.5 * jnp.mean(r * r), {}


@pytest.mark.parametrize("opt,outer", [
    (Momentum(lr=0.05, mu=0.9), None),
    (AdamW(lr=0.01), None),
    (Momentum(lr=0.05, mu=0.9), OuterOptimizer(lr=0.9, momentum=0.5)),
], ids=["momentum", "adamw", "outer"])
def test_resume_equals_uninterrupted(tmp_path, opt, outer):
    batches = _problem()
    params = {"w": jnp.zeros(DIM)}
    sch = AveragingSchedule("stochastic", zeta=0.2)
    mk = lambda: PhaseEngine(_loss, opt, sch, outer=outer)

    f_full, h_full = mk().run(params, batches(0, STEPS),
                              num_workers=WORKERS, seed=7, record_every=8)

    cut = 32
    f_half, h1, st = mk().run(params, batches(0, cut), num_workers=WORKERS,
                              seed=7, record_every=8, return_state=True)
    path = os.path.join(tmp_path, "ck")
    save_engine_state(path, st, extra={"phase": "mid-run"})

    like = mk().init(params, WORKERS, 7)
    loaded, step = load_engine_state(path, like)
    assert step == cut and int(loaded.step) == cut
    # every EngineState field restored bit-exactly
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    f_res, h2 = mk().run(None, batches(cut, STEPS), num_workers=WORKERS,
                         record_every=8, state=loaded)
    np.testing.assert_array_equal(np.asarray(f_full["w"]),
                                  np.asarray(f_res["w"]))
    assert h_full["loss"] == h1["loss"] + h2["loss"]
    assert h_full["dispersion"] == h1["dispersion"] + h2["dispersion"]
    assert h_full["averages"] == h1["averages"] + h2["averages"]


@pytest.mark.parametrize("wire", ["int8", "one_bit"])
def test_resume_equals_uninterrupted_compressed(tmp_path, wire):
    """Compressed runs resume bit-exactly too: the error-feedback
    residual plane rides the checkpoint (layout v3), and the int8
    stochastic-rounding draws are pure functions of (dec_key, step),
    so the post-resume events replay identically."""
    from repro.core import Compression
    batches = _problem()
    params = {"w": jnp.zeros(DIM)}
    sch = AveragingSchedule("stochastic", zeta=0.2)
    mk = lambda: PhaseEngine(_loss, Momentum(lr=0.05, mu=0.9), sch,
                             compression=Compression(wire))

    f_full, h_full = mk().run(params, batches(0, STEPS),
                              num_workers=WORKERS, seed=7, record_every=8)

    cut = 32
    _, h1, st = mk().run(params, batches(0, cut), num_workers=WORKERS,
                         seed=7, record_every=8, return_state=True)
    path = os.path.join(tmp_path, "ck")
    save_engine_state(path, st)

    loaded, step = load_engine_state(path, mk().init(params, WORKERS, 7))
    assert step == cut
    np.testing.assert_array_equal(np.asarray(st.resid),
                                  np.asarray(loaded.resid))

    f_res, h2 = mk().run(None, batches(cut, STEPS), num_workers=WORKERS,
                         record_every=8, state=loaded)
    np.testing.assert_array_equal(np.asarray(f_full["w"]),
                                  np.asarray(f_res["w"]))
    assert h_full["loss"] == h1["loss"] + h2["loss"]
    assert h_full["averages"] == h1["averages"] + h2["averages"]


def test_resume_with_device_dataset(tmp_path):
    """steps= counts steps for THIS call when resuming; record
    boundaries stay on absolute steps."""
    from repro.data.pipeline import DeviceDataset
    rng = np.random.default_rng(0)
    X = rng.standard_normal((SAMPLES, DIM))
    y = X @ rng.standard_normal(DIM)
    idx = rng.integers(0, SAMPLES, (STEPS, WORKERS, 8))
    mk = lambda: PhaseEngine(_loss, Momentum(lr=0.05, mu=0.9),
                             AveragingSchedule("periodic", 8))
    params = {"w": jnp.zeros(DIM)}

    ds = DeviceDataset({"x": X, "y": y}, WORKERS, indices=idx)
    f_full, h_full = mk().run(params, ds, num_workers=WORKERS, seed=2,
                              record_every=8)

    ds1 = DeviceDataset({"x": X, "y": y}, WORKERS, indices=idx)
    _, h1, st = mk().run(params, ds1, num_workers=WORKERS, seed=2,
                         record_every=8, steps=24, return_state=True)
    path = os.path.join(tmp_path, "ck")
    save_engine_state(path, st)
    loaded, _ = load_engine_state(path, mk().init(params, WORKERS, 2))
    # ds1's index cursor sits at 24; the resumed run continues from there
    f_res, h2 = mk().run(None, ds1, num_workers=WORKERS, record_every=8,
                         state=loaded)
    np.testing.assert_array_equal(np.asarray(f_full["w"]),
                                  np.asarray(f_res["w"]))
    assert [t for t, _ in h1["loss"] + h2["loss"]] == \
        [t for t, _ in h_full["loss"]]


def test_consensus_checkpoint_roundtrip(tmp_path):
    """The plain pytree checkpoint API still round-trips (regression)."""
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": (np.float32(2.5),)}
    path = os.path.join(tmp_path, "m")
    save_checkpoint(path, tree, step=5)
    back, step = load_checkpoint(path, tree)
    assert step == 5
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# EngineState checkpoint layout versions: one round-trip per version
# --------------------------------------------------------------------------

class TestEngineStateVersions:
    """``save_engine_state`` declares an explicit
    ``engine_state_version`` in the checkpoint metadata and
    ``load_engine_state`` dispatches on it — v0 (pre-SchedState) and
    v1 (SchedState, version field not yet written) checkpoints keep
    loading, and a version from the future is refused instead of
    mis-restored."""

    def _state(self, seed=1):
        batches = _problem()
        engine = PhaseEngine(_loss, Momentum(lr=0.05, mu=0.9),
                             AveragingSchedule("periodic", 8))
        _, _, st = engine.run({"w": jnp.zeros(DIM)}, batches(0, 16),
                              num_workers=WORKERS, seed=seed,
                              return_state=True)
        like = engine.init({"w": jnp.zeros(DIM)}, WORKERS, seed)
        return st, like

    def _assert_restored(self, st, loaded):
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(loaded)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def _state_compressed(self, seed=1):
        from repro.core import Compression
        batches = _problem()
        engine = PhaseEngine(_loss, Momentum(lr=0.05, mu=0.9),
                             AveragingSchedule("periodic", 8),
                             compression=Compression("int8"))
        _, _, st = engine.run({"w": jnp.zeros(DIM)}, batches(0, 16),
                              num_workers=WORKERS, seed=seed,
                              return_state=True)
        like = engine.init({"w": jnp.zeros(DIM)}, WORKERS, seed)
        return st, like

    def test_v2_roundtrip_declares_version(self, tmp_path):
        # uncompressed states keep the resid-less v2 layout (and stay
        # loadable by the builds that wrote it), even though this build
        # can write v3
        import json
        st, like = self._state()
        path = os.path.join(tmp_path, "v2")
        save_engine_state(path, st, extra={"note": "kept"})
        meta = json.load(open(path + ".json"))
        assert meta["extra"]["engine_state_version"] == 2
        assert meta["extra"]["note"] == "kept"  # caller extras survive
        loaded, step = load_engine_state(path, like)
        assert step == 16
        self._assert_restored(st, loaded)

    def test_v3_roundtrip_residual_plane(self, tmp_path):
        import json
        from repro.checkpoint.io import ENGINE_STATE_VERSION
        st, like = self._state_compressed()
        assert np.asarray(st.resid).any(), \
            "the int8 run should have accumulated a nonzero residual"
        path = os.path.join(tmp_path, "v3")
        save_engine_state(path, st)
        meta = json.load(open(path + ".json"))
        # compressed no-fault states keep the v3 layout even though the
        # build's latest version has moved on (v4 fault rows, v5
        # elastic saves)
        assert meta["extra"]["engine_state_version"] == 3
        assert ENGINE_STATE_VERSION == 5
        loaded, step = load_engine_state(path, like)
        assert step == 16
        self._assert_restored(st, loaded)
        np.testing.assert_array_equal(np.asarray(st.resid),
                                      np.asarray(loaded.resid))

    def test_pre_resid_versions_load_with_fresh_residuals(self, tmp_path):
        # v0/v1/v2 checkpoints predate the residual plane: they load
        # into a compressed engine with zero residuals (error feedback
        # restarts at the first post-resume event)
        st, _ = self._state()
        _, like = self._state_compressed()
        bare = jax.device_get(st)
        cases = {
            "v2": {"engine_state_version": 2},
            "v1": None,  # versionless SchedState build
        }
        for name, extra in cases.items():
            path = os.path.join(tmp_path, name)
            save_checkpoint(path, bare, step=int(st.step), extra=extra)
            loaded, step = load_engine_state(path, like)
            assert step == 16
            self._assert_restored(st._replace(resid=like.resid), loaded)
            assert not np.asarray(loaded.resid).any()
        path = os.path.join(tmp_path, "v0")
        save_checkpoint(path, jax.device_get(st._replace(sched=())),
                        step=int(st.step),
                        extra={"engine_state_version": 0})
        loaded, step = load_engine_state(path, like)
        assert step == 16
        self._assert_restored(
            st._replace(sched=like.sched, resid=like.resid), loaded)
        assert not np.asarray(loaded.resid).any()

    def test_v3_into_uncompressed_engine_refused(self, tmp_path):
        st, _ = self._state_compressed()
        _, like = self._state()  # engine without compression
        path = os.path.join(tmp_path, "v3")
        save_engine_state(path, st)
        with pytest.raises(ValueError, match="no active compression"):
            load_engine_state(path, like)

    def test_v1_roundtrip_versionless_schedstate(self, tmp_path):
        # a PR 4 build: SchedState leaves present, no version field
        st, like = self._state()
        path = os.path.join(tmp_path, "v1")
        save_checkpoint(path, jax.device_get(st), step=int(st.step))
        loaded, step = load_engine_state(path, like)
        assert step == 16
        self._assert_restored(st, loaded)

    def test_v0_roundtrip_pre_schedstate(self, tmp_path):
        # a PR 3 build: no SchedState leaves, no version field — the
        # sched bookkeeping is taken fresh (all zero) from like_state
        st, like = self._state()
        bare = jax.device_get(st._replace(sched=()))
        for path, extra in ((os.path.join(tmp_path, "v0"), None),
                            (os.path.join(tmp_path, "v0x"),
                             {"engine_state_version": 0})):
            save_checkpoint(path, bare, step=int(st.step), extra=extra)
            loaded, step = load_engine_state(path, like)
            assert step == 16
            self._assert_restored(st._replace(sched=like.sched), loaded)
            assert int(loaded.sched.comm_spent) == 0

    @pytest.mark.parametrize("future", [6, 99])
    def test_future_version_refused(self, tmp_path, future):
        st, like = self._state()
        path = os.path.join(tmp_path, f"v{future}")
        save_checkpoint(path, jax.device_get(st), step=int(st.step),
                        extra={"engine_state_version": future})
        with pytest.raises(ValueError, match=f"version {future}"):
            load_engine_state(path, like)

    def test_malformed_version_refused_cleanly(self, tmp_path):
        # hand-edited metadata: a non-int or negative version gets the
        # clean invalid-version error, not a TypeError or a misleading
        # "newer than this build"
        st, like = self._state()
        for bad in ("2", -1, False, True):
            path = os.path.join(tmp_path, f"bad-{bad}")
            save_checkpoint(path, jax.device_get(st), step=int(st.step),
                            extra={"engine_state_version": bad})
            with pytest.raises(ValueError, match="invalid engine-state"):
                load_engine_state(path, like)

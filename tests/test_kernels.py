"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas vs
pure-jnp oracle; plus the model-internal XLA paths vs the same oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels.ref import (flash_attention_ref, rglru_scan_ref,
                               rwkv6_scan_ref)

KEY = jax.random.PRNGKey(0)


class TestFlashAttention:
    @pytest.mark.parametrize("b,s,h,hkv,hd", [
        (2, 256, 4, 2, 64),
        (1, 128, 4, 4, 32),
        (1, 384, 8, 1, 128),   # MQA
        (2, 96, 6, 3, 64),     # padding path (96 < block)
    ])
    @pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                               (False, 0)])
    def test_matches_ref(self, b, s, h, hkv, hd, causal, window):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, hkv, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, hkv, hd), jnp.float32)
        out = flash_attention(q, k, v, causal=causal, window=window)
        ref = flash_attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_dtypes(self, dtype):
        dt = jnp.dtype(dtype)
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 128, 2, 64)).astype(dt)
        k = jax.random.normal(ks[1], (1, 128, 2, 64)).astype(dt)
        v = jax.random.normal(ks[2], (1, 128, 2, 64)).astype(dt)
        out = flash_attention(q, k, v, causal=True)
        ref = flash_attention_ref(q, k, v, causal=True, window=0)
        assert out.dtype == dt
        tol = 3e-2 if dtype == "bfloat16" else 2e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)

    def test_block_shape_invariance(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 256, 2, 64))
        k = jax.random.normal(ks[1], (1, 256, 1, 64))
        v = jax.random.normal(ks[2], (1, 256, 1, 64))
        a = flash_attention(q, k, v, causal=True, block_q=64, block_k=128)
        b = flash_attention(q, k, v, causal=True, block_q=128, block_k=64)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


class TestRGLRU:
    @pytest.mark.parametrize("b,s,w,bs", [
        (2, 64, 512, 64), (1, 300, 1024, 128), (3, 17, 512, 256),
    ])
    def test_matches_ref(self, b, s, w, bs):
        ka, kb = jax.random.split(KEY)
        a = jax.random.uniform(ka, (b, s, w), minval=0.2, maxval=0.999)
        bb = jax.random.normal(kb, (b, s, w)) * 0.3
        out = rglru_scan(a, bb, block_s=bs)
        ref = rglru_scan_ref(a, bb)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_model_xla_path_matches_ref(self):
        """The associative-scan training path == sequential oracle."""
        from repro.models.recurrent import rglru_scan as assoc
        ka, kb = jax.random.split(KEY)
        a = jax.random.uniform(ka, (2, 37, 256), minval=0.2, maxval=0.999)
        b = jax.random.normal(kb, (2, 37, 256))
        np.testing.assert_allclose(np.asarray(assoc(a, b)),
                                   np.asarray(rglru_scan_ref(a, b)),
                                   rtol=1e-5, atol=1e-5)


class TestRWKV6:
    @pytest.mark.parametrize("b,s,h,n,bs", [
        (2, 64, 4, 32, 32), (1, 100, 2, 64, 64), (1, 48, 1, 16, 16),
    ])
    def test_matches_ref(self, b, s, h, n, bs):
        ks = jax.random.split(KEY, 5)
        r, k, v = (jax.random.normal(ks[i], (b, s, h, n)) * 0.5
                   for i in range(3))
        lw = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (b, s, h, n))),
                      -5.0, -1e-5)
        u = jax.random.normal(ks[4], (h * n,)) * 0.1
        out = rwkv6_scan(r, k, v, lw, u, block_s=bs)
        ref = rwkv6_scan_ref(r, k, v, lw, u)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_model_chunked_path_matches_ref(self):
        """The chunked 'decay attention' XLA path == sequential oracle."""
        from repro.configs import get_config
        import dataclasses
        from repro.models.rwkv import rwkv_attention
        cfg = dataclasses.replace(get_config("rwkv6-7b", reduced=True),
                                  dtype="float32")
        ks = jax.random.split(KEY, 5)
        b, s, h, n = 2, 64, 2, 32
        r, k, v = (jax.random.normal(ks[i], (b, s, h, n)) * 0.5
                   for i in range(3))
        lw = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (b, s, h, n))),
                      -5.0, -1e-5)
        u = jax.random.normal(ks[4], (h * n,)) * 0.1
        out = rwkv_attention(cfg, r, k, v, lw, u)
        ref = rwkv6_scan_ref(r, k, v, lw, u)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


class TestAttentionVariants:
    """Perf-variant paths (EXPERIMENTS.md §Perf) == baseline numerics."""

    def test_banded_equals_naive(self):
        import dataclasses
        from repro.configs import get_config
        from repro.models import init_params, forward
        cfg = dataclasses.replace(get_config("gemma3-27b", reduced=True),
                                  dtype="float32", sliding_window=16)
        params = init_params(cfg, KEY)
        batch = {"tokens": jax.random.randint(KEY, (2, 64), 0,
                                              cfg.vocab_size)}
        l1, _ = forward(cfg, params, batch)
        l2, _ = forward(dataclasses.replace(cfg, attn_banded=True),
                        params, batch)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-4, atol=1e-4)

    def test_bf16_scores_close(self):
        import dataclasses
        from repro.configs import get_config
        from repro.models import init_params, forward
        cfg = dataclasses.replace(get_config("smollm-360m", reduced=True),
                                  dtype="float32")
        params = init_params(cfg, KEY)
        batch = {"tokens": jax.random.randint(KEY, (2, 64), 0,
                                              cfg.vocab_size)}
        l1, _ = forward(cfg, params, batch)
        l2, _ = forward(dataclasses.replace(cfg, score_dtype="bfloat16"),
                        params, batch)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=0.1, atol=0.1)


class TestGroupedMoE:
    """Grouped dispatch (perf variant, §Perf HC1) == global-capacity
    baseline when capacity is not binding."""

    def test_equivalence(self):
        import dataclasses
        from repro.configs import get_config
        from repro.models import init_params, forward
        cfg = dataclasses.replace(
            get_config("phi3.5-moe-42b-a6.6b", reduced=True),
            dtype="float32", capacity_factor=8.0)
        params = init_params(cfg, KEY)
        batch = {"tokens": jax.random.randint(KEY, (2, 64), 0,
                                              cfg.vocab_size)}
        l1, _ = forward(cfg, params, batch)
        for g in (16, 32, 100):  # incl. non-dividing group size (padding)
            l2, _ = forward(dataclasses.replace(cfg, moe_group_size=g),
                            params, batch)
            np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                       rtol=2e-5, atol=2e-5, err_msg=str(g))

    def test_capacity_drops_bounded(self):
        """With tight capacity, grouped routing drops a bounded fraction
        and stays finite (over-capacity tokens pass through residual)."""
        import dataclasses
        from repro.configs import get_config
        from repro.models import init_params, forward
        cfg = dataclasses.replace(
            get_config("llama4-maverick-400b-a17b", reduced=True),
            dtype="float32", capacity_factor=1.0, moe_group_size=16)
        params = init_params(cfg, KEY)
        batch = {"tokens": jax.random.randint(KEY, (2, 64), 0,
                                              cfg.vocab_size)}
        logits, _ = forward(cfg, params, batch)
        assert bool(jnp.isfinite(logits).all())


class TestModelPallasPath:
    """impl='pallas' through the actual model layers == impl='xla'."""

    def test_attention_layer(self):
        import dataclasses
        from repro.configs import get_config
        from repro.models import init_params, forward
        cfg = dataclasses.replace(get_config("smollm-360m", reduced=True),
                                  dtype="float32")
        params = init_params(cfg, KEY)
        batch = {"tokens": jax.random.randint(KEY, (2, 64), 0, cfg.vocab_size)}
        lx, _ = forward(cfg, params, batch, impl="xla")
        lp, _ = forward(cfg, params, batch, impl="pallas")
        np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                                   rtol=2e-4, atol=2e-4)

    def test_hybrid_and_ssm_layers(self):
        import dataclasses
        from repro.configs import get_config
        from repro.models import init_params, forward
        for arch in ("recurrentgemma-2b", "rwkv6-7b"):
            cfg = dataclasses.replace(get_config(arch, reduced=True),
                                      dtype="float32")
            params = init_params(cfg, KEY)
            batch = {"tokens": jax.random.randint(KEY, (2, 64), 0,
                                                  cfg.vocab_size)}
            lx, _ = forward(cfg, params, batch, impl="xla")
            lp, _ = forward(cfg, params, batch, impl="pallas")
            np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                                       rtol=5e-4, atol=5e-4, err_msg=arch)

"""End-to-end behaviour tests: the paper's claims reproduced through the
full LocalSGD runtime on real (synthetic) problems."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper import PCAConfig
from repro.core import AveragingSchedule, LocalSGD, measure_beta2, rho
from repro.core.variance_model import empirical_variance_fn
from repro.data import convex_dataset
from repro.models.convex import ls_objective
from repro.optim import SGD


def run_ls(phase_len, X, y, *, workers=8, steps=600, lr=0.02, seed=0):
    """SGD on least squares with per-worker sampling-with-replacement."""
    n, d = X.shape
    Xj, yj = jnp.asarray(X), jnp.asarray(y)

    def loss_fn(params, batch, rng):
        xi, yi = batch["x"], batch["y"]
        r = xi @ params["w"] - yi
        return 0.5 * jnp.mean(r * r), {}

    sch = (AveragingSchedule("oneshot") if phase_len == 0
           else AveragingSchedule("periodic", phase_len))
    algo = LocalSGD(loss_fn, SGD(lr=lr), sch)
    rng = np.random.default_rng(seed)

    def batches():
        for _ in range(steps):
            idx = rng.integers(0, n, (workers, 1))
            yield {"x": Xj[idx], "y": yj[idx]}

    final, hist = algo.run({"w": jnp.zeros(d)}, batches(),
                           num_workers=workers, seed=seed)
    return float(ls_objective(final["w"], Xj, yj)), hist


class TestConvexEndToEnd:
    def test_periodic_beats_oneshot_when_rho_large(self):
        """Sparse features (tf-idf regime, paper Table 1 E2006 rows):
        β²-term dominates -> frequent averaging converges further."""
        X, y, _ = convex_dataset("ls", 512, 64, sparsity=0.05, noise=0.01,
                                 seed=1)
        obj_periodic, _ = run_ls(8, X, y)
        obj_oneshot, _ = run_ls(0, X, y)
        assert obj_periodic < obj_oneshot * 0.9, (obj_periodic, obj_oneshot)

    def test_rho_small_gap_small(self):
        """Dense + noisy labels (YearPrediction regime): σ² dominates;
        periodic and one-shot differ much less than in the sparse case."""
        Xs, ys, _ = convex_dataset("ls", 512, 64, sparsity=0.05,
                                   noise=0.01, seed=1)
        Xd, yd, _ = convex_dataset("ls", 512, 64, sparsity=1.0, noise=2.0,
                                   seed=1)
        sp_p, _ = run_ls(8, Xs, ys)
        sp_o, _ = run_ls(0, Xs, ys)
        dn_p, _ = run_ls(8, Xd, yd)
        dn_o, _ = run_ls(0, Xd, yd)
        gap_sparse = sp_o / max(sp_p, 1e-12)
        gap_dense = dn_o / max(dn_p, 1e-12)
        assert gap_sparse > gap_dense, (gap_sparse, gap_dense)


class TestVarianceModel:
    def test_recovers_known_envelope(self):
        """On a synthetic problem with analytically-known Δ(w) =
        β²||w-w*||² + σ², the §3.1 measurement recovers both terms."""
        dim, beta2_true, sigma2_true = 8, 3.0, 0.5
        key = jax.random.PRNGKey(0)
        m = 4096
        w_star = jnp.zeros(dim)
        b = jax.random.normal(key, (m,)) * np.sqrt(beta2_true)
        h = jax.random.normal(jax.random.PRNGKey(1), (m, dim)) * \
            np.sqrt(sigma2_true / dim)

        def variance_fn(w):
            per = b[:, None] * (w - w_star)[None, :] + h
            g = jnp.mean(per, axis=0)
            return jnp.mean(jnp.sum((per - g) ** 2, axis=1))

        beta2, sigma2 = measure_beta2(variance_fn, w_star,
                                      key=jax.random.PRNGKey(2))
        assert sigma2 == pytest.approx(sigma2_true, rel=0.1)
        assert beta2 == pytest.approx(beta2_true, rel=0.15)
        r = rho(beta2, sigma2, jnp.ones(dim), w_star)
        assert r == pytest.approx(beta2_true * dim / sigma2_true, rel=0.3)

    def test_empirical_ls_rho_ordering(self):
        """Sparse LS must measure a (much) larger ρ than dense noisy LS —
        the paper's Table 1 pattern."""
        Xs, ys, ws = convex_dataset("ls", 512, 32, sparsity=0.05,
                                    noise=0.01, seed=0)
        Xd, yd, wd = convex_dataset("ls", 512, 32, sparsity=1.0, noise=2.0,
                                    seed=0)
        rhos = {}
        for name, (X, y, wt) in {"sparse": (Xs, ys, ws),
                                 "dense": (Xd, yd, wd)}.items():
            Xj, yj = jnp.asarray(X), jnp.asarray(y)
            w_star = jnp.linalg.solve(Xj.T @ Xj + 1e-6 * jnp.eye(X.shape[1]),
                                      Xj.T @ yj)
            vfn = empirical_variance_fn("ls", Xj, yj)
            b2, s2 = measure_beta2(vfn, w_star, key=jax.random.PRNGKey(3),
                                   num_lines=4)
            rhos[name] = rho(b2, s2, jnp.zeros(X.shape[1]), w_star)
        assert rhos["sparse"] > 10 * rhos["dense"], rhos


class TestPCA:
    def test_periodic_averaging_fixes_oja(self):
        """Paper Fig. 1: one-shot averaging of Oja's rule across workers
        is poor (sign/rotation ambiguity); periodic averaging fixes it."""
        cfg = PCAConfig(num_workers=12, num_samples=1500, alpha=0.02)
        rng = np.random.default_rng(0)
        spec = np.full(cfg.dim, cfg.tail_eig)
        spec[0] = cfg.top_eig
        C = np.diag(spec)
        v1 = np.eye(cfg.dim)[0]

        def run(phase_len):
            w = rng.standard_normal((cfg.num_workers, cfg.dim))
            w /= np.linalg.norm(w, axis=1, keepdims=True)
            rs = np.random.default_rng(42)
            for t in range(cfg.num_samples):
                x = rs.multivariate_normal(np.zeros(cfg.dim), C,
                                           cfg.num_workers)
                wx = np.einsum("md,md->m", w, x)
                w = w + cfg.alpha * wx[:, None] * x
                w /= np.maximum(np.linalg.norm(w, axis=1, keepdims=True), 1e-9)
                if phase_len and (t + 1) % phase_len == 0:
                    w = np.broadcast_to(w.mean(0), w.shape).copy()
                    w /= np.maximum(np.linalg.norm(w, axis=1, keepdims=True), 1e-9)
            wbar = w.mean(0)
            return 1.0 - abs(wbar @ v1) / (np.linalg.norm(wbar) + 1e-12)

        err_oneshot = run(0)
        err_periodic = run(25)
        assert err_periodic < err_oneshot
        assert err_periodic < 0.1


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        from repro.checkpoint import load_checkpoint, save_checkpoint
        from repro.configs import get_config
        from repro.models import init_params
        import dataclasses
        cfg = dataclasses.replace(get_config("smollm-360m", reduced=True),
                                  dtype="float32")
        params = init_params(cfg, jax.random.PRNGKey(0))
        save_checkpoint(str(tmp_path / "ckpt"), params, step=7)
        like = jax.tree.map(jnp.zeros_like, params)
        restored, step = load_checkpoint(str(tmp_path / "ckpt"), like)
        assert step == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestData:
    def test_token_stream_deterministic(self):
        from repro.data import token_stream
        a = next(token_stream(128, 4, 16, seed=5))
        b = next(token_stream(128, 4, 16, seed=5))
        np.testing.assert_array_equal(a, b)
        c = next(token_stream(128, 4, 16, seed=6))
        assert (a != c).any()

    def test_worker_sharder_distinct_permutations(self):
        from repro.data import WorkerSharder
        sh = WorkerSharder(100, 4, seed=0, mode="permute")
        idx = sh.next_indices(100)
        for i in range(4):
            assert sorted(idx[i]) == list(range(100))
        assert (idx[0] != idx[1]).any()

    def test_convex_dataset_shapes(self):
        X, y, w = convex_dataset("lr", 64, 8, sparsity=0.5)
        assert X.shape == (64, 8) and y.shape == (64,) and w.shape == (8,)
        assert set(np.unique(y)) <= {-1.0, 1.0}

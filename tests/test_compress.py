"""Compressed communication planes: wire formats, error feedback, and
the bytes-on-the-wire budget.

Four layers of guarantees:
  1. The wire formats (``repro.core.compress``) hold their contracts:
     bf16 is the round-to-nearest-even cast, int8 stochastic rounding
     stays within one per-row quantization step, one_bit is
     sign x mean|v|, and the error-feedback identity v = q + resid'
     holds exactly in f32. The stochastic-rounding uniforms are a pure
     function of (dec_key, step, row) — row subsets reproduce the
     full-plane rows, which is what makes sharded encoding bit-equal
     to single-device encoding.
  2. The Pallas ``compressed_mix`` / compressed ``opt_step`` kernels
     (interpret mode on CPU) match the kernels/ref.py jnp twins across
     wires, event modes, padding and rounding codes.
  3. The engine: the ``f32`` wire IS the uncompressed path (bit-exact
     across schedules and topologies), the quantizing wires replay
     bit-identically across all four engine paths (flat-native / flat /
     tree / host loop), and error feedback keeps the long-run consensus
     close to the uncompressed trajectory.
  4. The ``adaptive_bytes`` schedule never overspends its byte budget,
     prices events via ``comm_bytes`` (topology x wire), and refuses to
     run without an event cost.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AveragingSchedule, Compression, OuterOptimizer, \
    PhaseEngine, wire_row_bytes
from repro.core.compress import encode_decode, quantize, row_uniforms
from repro.kernels.avg_disp import compressed_mix
from repro.kernels.opt_step import opt_step
from repro.kernels.ref import compressed_avg_ref, compressed_mix_ref, \
    opt_step_ref
from repro.optim import SGD, Momentum
from repro.topology import Topology, comm_bytes

KEY = jax.random.PRNGKey(0)
WORKERS, STEPS, DIM, SAMPLES = 4, 33, 12, 256


def _plane(m=8, p=50, seed=0, scale=1.0):
    k = jax.random.fold_in(KEY, seed)
    return scale * jax.random.normal(k, (m, p), jnp.float32)


def _u(m, p, step=3):
    return row_uniforms(KEY, step, jnp.arange(m, dtype=jnp.int32), p)


# --------------------------------------------------------------------------
# 1. wire-format contracts
# --------------------------------------------------------------------------

class TestWireFormats:
    def test_f32_is_identity(self):
        v = _plane()
        np.testing.assert_array_equal(np.asarray(quantize(v, "f32")),
                                      np.asarray(v))

    def test_bf16_is_the_cast(self):
        v = _plane()
        want = v.astype(jnp.bfloat16).astype(jnp.float32)
        np.testing.assert_array_equal(np.asarray(quantize(v, "bf16")),
                                      np.asarray(want))

    def test_int8_error_within_one_step(self):
        v = _plane(scale=3.0)
        q = quantize(v, "int8", u=_u(*v.shape))
        s = np.abs(np.asarray(v)).max(1) / 127.0
        assert (np.abs(np.asarray(q - v)) <= s[:, None] + 1e-7).all()

    def test_int8_zero_row_stable(self):
        v = jnp.zeros((3, 9), jnp.float32)
        q = quantize(v, "int8", u=_u(3, 9))
        np.testing.assert_array_equal(np.asarray(q), 0.0)

    def test_int8_stochastic_rounding_unbiased_ish(self):
        # many rows of the same value: the mean of the quantized image
        # approaches the value (stochastic, not round-to-nearest)
        v = jnp.full((512, 4), 0.37, jnp.float32)
        v = v.at[:, 0].set(1.0)  # pins the row scale to 1/127
        q = quantize(v, "int8", u=_u(512, 4, step=9))
        got = float(np.asarray(q)[:, 1].mean())
        assert abs(got - 0.37) < 2e-3

    def test_one_bit_is_sign_times_row_mean(self):
        v = _plane()
        q = np.asarray(quantize(v, "one_bit"))
        s = np.abs(np.asarray(v)).mean(1, keepdims=True)
        np.testing.assert_allclose(q, np.sign(np.asarray(v)) * s,
                                   rtol=1e-6, atol=1e-7)

    @pytest.mark.parametrize("wire", ["bf16", "int8", "one_bit"])
    def test_error_feedback_identity(self, wire):
        # the residual is EXACTLY what the wire dropped: r' = (v+r) - q
        v, r = _plane(), 0.1 * _plane(seed=5)
        u = _u(*v.shape) if wire == "int8" else None
        q, r2 = encode_decode(v, r, wire=wire, u=u)
        np.testing.assert_array_equal(np.asarray(r2),
                                      np.asarray((v + r) - q))
        np.testing.assert_allclose(np.asarray(q + r2), np.asarray(v + r),
                                   rtol=1e-6, atol=1e-6)

    def test_no_error_feedback_passes_residual_through(self):
        v, r = _plane(), 0.1 * _plane(seed=5)
        q, r2 = encode_decode(v, r, wire="bf16", error_feedback=False)
        assert r2 is r
        np.testing.assert_array_equal(
            np.asarray(q), np.asarray(quantize(v, "bf16")))

    def test_row_uniform_subsets_match_full(self):
        # the sharded encoder draws uniforms for ITS rows only — they
        # must equal the corresponding rows of the full-plane draw
        full = _u(8, 17, step=4)
        part = row_uniforms(KEY, 4, jnp.arange(3, 7, dtype=jnp.int32), 17)
        np.testing.assert_array_equal(np.asarray(full[3:7]),
                                      np.asarray(part))

    def test_row_uniforms_vary_by_step(self):
        assert not np.array_equal(np.asarray(_u(4, 9, step=1)),
                                  np.asarray(_u(4, 9, step=2)))

    def test_wire_row_bytes(self):
        assert wire_row_bytes(64, "f32") == 256
        assert wire_row_bytes(64, "bf16") == 128
        assert wire_row_bytes(64, "int8") == 64 + 4   # payload + scale
        assert wire_row_bytes(64, "one_bit") == 8 + 4  # bitmap + scale
        assert wire_row_bytes(50, "one_bit") == 7 + 4  # ceil(50/8)

    def test_compression_validation(self):
        assert Compression("f32").is_identity
        assert not Compression("bf16").is_identity
        with pytest.raises(ValueError, match="unknown wire"):
            Compression("fp8")
        for wire in ("int8", "one_bit"):
            with pytest.raises(ValueError, match="error-feedback"):
                Compression(wire, error_feedback=False)
        # bf16 may run open-loop (its error is bounded by the cast)
        assert not Compression("bf16", error_feedback=False).error_feedback

    def test_comm_bytes_prices_topology_and_wire(self):
        full, ring = Topology.full(8), Topology.ring(8)
        assert comm_bytes(full, 1, 64, "f32") == 7 * 256
        assert comm_bytes(ring, 1, 64, "f32") == 2 * 256
        assert comm_bytes(ring, 5, 64, "int8") == 10 * 68
        # gossip pairs: one partner per event
        assert comm_bytes(Topology.gossip_pairs(8), 3, 64, "one_bit") == \
            3 * wire_row_bytes(64, "one_bit")


# --------------------------------------------------------------------------
# 2. Pallas kernels (interpret mode) vs jnp refs
# --------------------------------------------------------------------------

class TestCompressedKernels:
    @pytest.mark.parametrize("wire", ["bf16", "int8", "one_bit"])
    @pytest.mark.parametrize("mode", ["mean", "group", "mix"])
    def test_compressed_mix_matches_ref(self, wire, mode):
        m, p = 8, 50  # p=50 exercises column-block padding (block_p=16)
        plane, resid = _plane(m, p), 0.1 * _plane(m, p, seed=7)
        u = _u(m, p) if wire == "int8" else None
        W = Topology.ring(m).mixing_matrix() if mode == "mix" else None
        groups = 2 if mode == "group" else 1
        out, r2, d = compressed_mix(plane, resid, wire=wire, mode=mode,
                                    groups=groups, W=W, u=u, block_p=16,
                                    interpret=True)
        if mode == "mix":
            ro, rr, rd = compressed_mix_ref(plane, resid, W, wire=wire,
                                            u=u)
        else:
            ro, rr, rd = compressed_avg_ref(plane, resid, wire=wire,
                                            groups=groups, u=u)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ro),
                                   rtol=2e-6, atol=2e-6)
        np.testing.assert_allclose(np.asarray(r2), np.asarray(rr),
                                   rtol=2e-6, atol=2e-6)
        np.testing.assert_allclose(float(d), float(rd), rtol=2e-5)

    @pytest.mark.parametrize("wire", ["bf16", "int8", "one_bit"])
    @pytest.mark.parametrize("kind,mode", [
        ("sgd", "mean"), ("momentum", "mean"), ("momentum", "mix"),
        ("adamw", "group"),
    ])
    def test_opt_step_compressed_matches_ref(self, wire, kind, mode):
        m, p = 8, 50
        plane, grads = _plane(m, p), _plane(m, p, seed=3)
        resid = 0.1 * _plane(m, p, seed=7)
        nstate = {"sgd": 0, "momentum": 1, "adamw": 2}[kind]
        planes = tuple(0.01 * _plane(m, p, seed=10 + i)
                       for i in range(nstate))
        scalars = jnp.asarray([0.05, 1.0, 1.0, 0.0], jnp.float32)
        u = _u(m, p) if wire == "int8" else None
        W = Topology.ring(m).mixing_matrix() if mode == "mix" else None
        groups = 2 if mode == "group" else 1
        out, pl, r2, d = opt_step(plane, grads, planes, scalars,
                                  kind=kind, mode=mode, groups=groups,
                                  W=W, wire=wire, resid=resid, u=u,
                                  block_p=16, interpret=True)
        ro, rpl, rr, rd = opt_step_ref(plane, grads, planes, scalars,
                                       kind=kind, mode=mode,
                                       groups=groups, W=W, wire=wire,
                                       resid=resid, u=u)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ro),
                                   rtol=2e-6, atol=2e-6)
        for a, b in zip(pl, rpl):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-6, atol=2e-6)
        np.testing.assert_allclose(np.asarray(r2), np.asarray(rr),
                                   rtol=2e-6, atol=2e-6)
        np.testing.assert_allclose(float(d), float(rd), rtol=2e-5)


# --------------------------------------------------------------------------
# 3. engine integration
# --------------------------------------------------------------------------

def _problem(seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((SAMPLES, DIM))
    y = X @ rng.standard_normal(DIM) + 0.1 * rng.standard_normal(SAMPLES)
    idx = rng.integers(0, SAMPLES, (STEPS, WORKERS, 8))
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    return lambda: [{"x": Xj[idx[t]], "y": yj[idx[t]]}
                    for t in range(STEPS)]


def _loss(params, batch, rng):
    r = batch["x"] @ params["w"] - batch["y"]
    return 0.5 * jnp.mean(r * r), {}


def _params():
    return {"w": jnp.zeros(DIM)}


SCHEDULES = {
    "periodic": AveragingSchedule("periodic", 8),
    "stochastic": AveragingSchedule("stochastic", zeta=0.2),
    "hierarchical": AveragingSchedule("hierarchical", inner_phase_len=5,
                                      outer_phase_len=20, inner_groups=2),
    "adaptive_budget": AveragingSchedule("adaptive_budget", comm_budget=5,
                                         budget_horizon=STEPS),
}


class TestEngineCompressed:
    @pytest.mark.parametrize("name", list(SCHEDULES))
    def test_f32_wire_is_bit_exact(self, name):
        """Acceptance: the f32 wire lowers to the existing paths."""
        batches = _problem()
        for kw in (dict(), dict(fused_opt=False), dict(flat=False)):
            base = PhaseEngine(_loss, Momentum(lr=0.05, mu=0.9),
                               SCHEDULES[name], **kw)
            f32w = PhaseEngine(_loss, Momentum(lr=0.05, mu=0.9),
                               SCHEDULES[name],
                               compression=Compression("f32"), **kw)
            a, ha = base.run(_params(), batches(), num_workers=WORKERS,
                             seed=3, record_every=1)
            b, hb = f32w.run(_params(), batches(), num_workers=WORKERS,
                             seed=3, record_every=1)
            np.testing.assert_array_equal(np.asarray(a["w"]),
                                          np.asarray(b["w"]))
            assert ha == hb

    def test_f32_wire_is_bit_exact_with_topology(self):
        batches = _problem()
        topo = Topology.ring(WORKERS)
        mk = lambda c: PhaseEngine(_loss, SGD(lr=0.05),
                                   AveragingSchedule("periodic", 8),
                                   topology=topo, compression=c)
        a, _ = mk(None).run(_params(), batches(), num_workers=WORKERS,
                            seed=3)
        b, _ = mk(Compression("f32")).run(_params(), batches(),
                                          num_workers=WORKERS, seed=3)
        np.testing.assert_array_equal(np.asarray(a["w"]),
                                      np.asarray(b["w"]))

    @pytest.mark.parametrize("wire", ["bf16", "int8", "one_bit"])
    def test_paths_bitwise_identical(self, wire):
        """flat-native / flat / tree / host loop replay the identical
        compressed trajectory (CPU: all four use the jnp refs)."""
        batches = _problem()
        sch = SCHEDULES["stochastic"]
        mk = lambda **kw: PhaseEngine(_loss, Momentum(lr=0.05, mu=0.9),
                                      sch,
                                      compression=Compression(wire), **kw)
        f0, h0 = mk().run(_params(), batches(), num_workers=WORKERS,
                          seed=3, record_every=1)
        for kw in (dict(fused_opt=False), dict(flat=False)):
            f, _ = mk(**kw).run(_params(), batches(), num_workers=WORKERS,
                                seed=3)
            np.testing.assert_array_equal(np.asarray(f0["w"]),
                                          np.asarray(f["w"]))
        fh, hh = mk().run_host(_params(), batches(), num_workers=WORKERS,
                               seed=3, record_every=1)
        np.testing.assert_array_equal(np.asarray(f0["w"]),
                                      np.asarray(fh["w"]))
        assert h0["averages"] == hh["averages"]

    def test_phase_blocking_invariance_compressed(self):
        batches = _problem()
        mk = lambda: PhaseEngine(_loss, SGD(lr=0.05),
                                 AveragingSchedule("periodic", 8),
                                 compression=Compression("int8"))
        ref, _ = mk().run(_params(), batches(), num_workers=WORKERS,
                          seed=0, phase_len=8)
        for block in (1, 7, 100):
            got, _ = mk().run(_params(), batches(), num_workers=WORKERS,
                              seed=0, phase_len=block)
            np.testing.assert_array_equal(np.asarray(ref["w"]),
                                          np.asarray(got["w"]))

    def test_error_feedback_tracks_uncompressed(self):
        """int8 is a ~4x wire cut; with error feedback the consensus
        trajectory stays near the uncompressed one on the convex
        problem (the residual re-injects what quantization dropped —
        measured drift here is ~0.2% of the solution norm)."""
        batches = _problem()
        sch = AveragingSchedule("periodic", 4)
        f0, _ = PhaseEngine(_loss, SGD(lr=0.05), sch).run(
            _params(), batches(), num_workers=WORKERS, seed=3)
        f1, _ = PhaseEngine(_loss, SGD(lr=0.05), sch,
                            compression=Compression("int8")).run(
            _params(), batches(), num_workers=WORKERS, seed=3)
        ref = np.linalg.norm(np.asarray(f0["w"]))
        err = np.linalg.norm(np.asarray(f1["w"]) - np.asarray(f0["w"]))
        assert err < 0.05 * ref, (err, ref)

    def test_outer_optimizer_requires_f32_wire(self):
        with pytest.raises(ValueError, match="outer optimizer"):
            PhaseEngine(_loss, SGD(lr=0.05),
                        AveragingSchedule("periodic", 8),
                        outer=OuterOptimizer(),
                        compression=Compression("int8")).run(
                _params(), _problem()(), num_workers=WORKERS)
        # the f32 wire is the uncompressed path — outer is fine there
        PhaseEngine(_loss, SGD(lr=0.05), AveragingSchedule("periodic", 8),
                    outer=OuterOptimizer(),
                    compression=Compression("f32")).run(
            _params(), _problem()(), num_workers=WORKERS)

    def test_unflattenable_tree_rejected(self):
        def loss(params, batch, rng):
            r = batch["x"] @ params["w"] - batch["y"]
            return 0.5 * jnp.mean(r * r), {}

        params = {"w": jnp.zeros(DIM), "steps": jnp.zeros((), jnp.int32)}
        with pytest.raises(ValueError, match="FlatSpec cannot embed"):
            PhaseEngine(loss, SGD(lr=0.05),
                        AveragingSchedule("periodic", 8),
                        compression=Compression("int8")).run(
                params, _problem()(), num_workers=WORKERS)


# --------------------------------------------------------------------------
# 4. the adaptive_bytes schedule
# --------------------------------------------------------------------------

class TestAdaptiveBytes:
    def test_validation(self):
        with pytest.raises(ValueError, match="adaptive_bytes"):
            AveragingSchedule("adaptive_bytes")
        with pytest.raises(ValueError, match="adaptive_bytes"):
            AveragingSchedule("adaptive_bytes", byte_budget=100)
        s = AveragingSchedule("adaptive_bytes", byte_budget=100,
                              budget_horizon=10)
        assert s.is_adaptive
        assert np.isnan(s.expected_phase_len())

    def test_needs_event_cost(self):
        s = AveragingSchedule("adaptive_bytes", byte_budget=100,
                              budget_horizon=10)
        with pytest.raises(ValueError, match="event_cost"):
            s.decision_state(1, s.init_sched_state(), jnp.float32(0.5))

    @pytest.mark.parametrize("wire,topo", [
        ("f32", None), ("int8", None), ("int8", "ring")])
    def test_never_overspends_budget(self, wire, topo):
        """averages x comm_bytes(topology, 1, P, wire) <= byte_budget,
        and a cheaper wire/topology buys MORE events from the same
        budget."""
        batches = _problem()
        topology = Topology.ring(WORKERS) if topo else None
        comp = None if wire == "f32" else Compression(wire)
        budget = 4 * comm_bytes(Topology.full(WORKERS), 1, DIM, "f32")
        sch = AveragingSchedule("adaptive_bytes", byte_budget=budget,
                                budget_horizon=STEPS)
        eng = PhaseEngine(_loss, SGD(lr=0.05), sch, topology=topology,
                          compression=comp)
        _, h = eng.run(_params(), batches(), num_workers=WORKERS, seed=3,
                       record_every=1)
        cost = comm_bytes(topology or Topology.full(WORKERS), 1, DIM,
                          wire)
        assert h["averages"] * cost <= budget
        assert h["averages"] >= 1

    def test_cheaper_wire_buys_more_events(self):
        batches = _problem()
        budget = 4 * comm_bytes(Topology.full(WORKERS), 1, DIM, "f32")
        counts = {}
        for wire in ("f32", "int8"):
            comp = None if wire == "f32" else Compression(wire)
            sch = AveragingSchedule("adaptive_bytes", byte_budget=budget,
                                    budget_horizon=STEPS)
            _, h = PhaseEngine(_loss, SGD(lr=0.05), sch,
                               compression=comp).run(
                _params(), batches(), num_workers=WORKERS, seed=3,
                record_every=1)
            counts[wire] = h["averages"]
        assert counts["int8"] > counts["f32"], counts

"""Dry-run machinery on a small host-device mesh (subprocess so the
XLA device-count flag doesn't leak into other tests), plus unit tests of
the sharding rules."""
import json
import os
import subprocess
import sys
import textwrap

from jax.sharding import PartitionSpec as P

from repro.sharding.specs import first_divisible_spec, leaf_spec


class TestSpecRules:
    def test_leaf_spec_largest_divisible(self):
        assert leaf_spec((49152, 960), 16) == P("model", None)
        assert leaf_spec((960, 2560), 16) == P(None, "model")
        assert leaf_spec((7,), 16) == P(None)
        assert leaf_spec((4, 960, 2560), 16, prefix=("data",)) == \
            P("data", None, "model")

    def test_leaf_spec_prefer_axis(self):
        # expert-parallel preference: shard dim 0 (experts) even if smaller
        assert leaf_spec((16, 4096, 6400), 16, prefer_axis=0) == \
            P("model", None, None)

    def test_first_divisible(self):
        assert first_divisible_spec((16, 4096), 16) == P("model", None)
        # non-divisible batch: replicate within the group — deliberately
        # NOT seq-sharding (see EXPERIMENTS.md §Perf HC3 iteration 3)
        assert first_divisible_spec((10, 4096), 16) == P(None, None)
        assert first_divisible_spec((10, 33), 16) == P(None, None)


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    import dataclasses

    from repro.configs import get_config, ShapeConfig
    from repro.launch import steps
    from repro.launch.mesh import make_host_mesh
    from repro.roofline.analysis import roofline_report
    from repro.sharding import specs as S

    mesh = make_host_mesh(data=2, model=2, pod=2)   # 8 host "chips"
    msize = 2
    cfg = get_config("smollm-360m", reduced=True)
    shape = ShapeConfig("t", "train", 64, 8)        # 8 seqs of 64
    W = 4                                           # pod x data
    opt = steps.make_optimizer()
    wp_t, os_t = steps.abstract_worker_state(cfg, opt, W)
    batch_t = steps.input_specs(cfg, shape, num_workers=W)
    fn = steps.make_train_step(cfg, do_avg=True)
    went = ("pod", "data")
    ns = lambda t: jax.tree.map(lambda sp: NamedSharding(mesh, sp), t,
                                is_leaf=lambda x: isinstance(x, P))
    in_sh = (ns(S.param_specs(wp_t, msize, worker_axes=went)),
             ns(S.param_specs(os_t, msize, worker_axes=went)),
             ns(S.batch_specs(batch_t, msize, worker_axes=went)),
             NamedSharding(mesh, P()))
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh).lower(
            wp_t, os_t, batch_t, steps.sds((), jnp.int32))
        compiled = lowered.compile()
    rep = roofline_report(compiled, chips=8)
    rep["ok"] = True
    print(json.dumps({k: v for k, v in rep.items()
                      if isinstance(v, (int, float, str, bool))}))
""")


class TestHostMeshDryrun:
    def test_train_step_lowers_on_8_device_mesh(self):
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run([sys.executable, "-c", MINI_DRYRUN],
                             capture_output=True, text=True, env=env,
                             cwd=os.path.dirname(os.path.dirname(__file__)),
                             timeout=600)
        assert out.returncode == 0, out.stderr[-3000:]
        rep = json.loads(out.stdout.strip().splitlines()[-1])
        assert rep["ok"]
        assert rep["flops_per_device"] > 0
        # do_avg=True must produce cross-worker collectives
        assert rep["collective_bytes_per_device"] > 0

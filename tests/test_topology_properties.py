"""Property-based (hypothesis) tests for the topology builders.

``hypothesis`` is an optional dev dependency (requirements-dev.txt);
when it is absent this module skips itself and the deterministic sweeps
in tests/test_topology.py cover the same invariants.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.topology import Topology, gossip_matrix


def _valid_m(kind, m):
    try:
        Topology.build(kind, m, groups=2)
        return True
    except ValueError:
        return False


kinds = st.sampled_from(["full", "ring", "torus", "hypercube", "groups",
                         "gossip_pairs", "disconnected"])


@settings(max_examples=40, deadline=None)
@given(kind=kinds, m=st.integers(2, 40))
def test_every_builder_is_symmetric_doubly_stochastic(kind, m):
    if not _valid_m(kind, m):
        return  # the builder rejects this (kind, M) combination eagerly
    t = Topology.build(kind, m, groups=2)
    W = t.expected_matrix()
    np.testing.assert_allclose(W, W.T, atol=1e-12)
    np.testing.assert_allclose(W.sum(1), np.ones(m), atol=1e-12)
    assert (W >= -1e-12).all()
    # declared gap == 1 - SLEM of the matrix
    ev = np.linalg.eigvalsh(W)
    slem = min(1.0, max(abs(ev[0]), ev[-2], 0.0))
    np.testing.assert_allclose(t.spectral_gap, 1.0 - slem, atol=1e-9)
    assert 0.0 <= t.spectral_gap <= 1.0


@settings(max_examples=25, deadline=None)
@given(kind=st.sampled_from(["ring", "torus", "hypercube"]),
       m=st.integers(3, 32), seed=st.integers(0, 1000))
def test_mix_contracts_deviation_by_slem(kind, m, seed):
    """||W x_perp|| <= slem * ||x_perp||: one event contracts the Eq. 4
    dispersion by at most slem² (the theory hook the gap feeds)."""
    if not _valid_m(kind, m):
        return
    t = Topology.build(kind, m)
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, 5))
    xp = x - x.mean(0)  # consensus-orthogonal component
    out = t.expected_matrix() @ xp
    assert np.linalg.norm(out) <= (1.0 - t.spectral_gap) \
        * np.linalg.norm(xp) * (1 + 1e-9)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 16).map(lambda k: 2 * k),
       step=st.integers(1, 10_000), seed=st.integers(0, 1000))
def test_gossip_matrix_is_a_deterministic_pair_matching(m, step, seed):
    key = jax.random.PRNGKey(seed)
    W = np.asarray(gossip_matrix(key, step, m), np.float64)
    # symmetric doubly-stochastic projection: a perfect matching of
    # pair means — diagonal exactly 1/2, one off-diagonal 1/2 per row
    np.testing.assert_array_equal(W, W.T)
    np.testing.assert_allclose(W.sum(1), np.ones(m), atol=1e-6)
    np.testing.assert_array_equal(np.diag(W), np.full(m, 0.5))
    assert ((np.abs(W) > 0).sum(1) == 2).all()
    np.testing.assert_allclose(W @ W, W, atol=1e-6)
    # pure function of (key, step): bitwise replay
    np.testing.assert_array_equal(W, np.asarray(gossip_matrix(key, step,
                                                              m)))

"""Telemetry plane: enabling it must never change training.

The load-bearing invariant (docs/TELEMETRY.md): ``telemetry=True``
threads a metrics accumulator through the phase scan carry and flushes
it with the phase's existing trace fetch — so telemetry ON vs OFF is
bit-identical in the final EngineState across every engine path, every
schedule, compression, faults, checkpoint/resume, and the sharded
collectives (subprocess), and adds ZERO extra host syncs (the
device_get count per run is unchanged). On top of that: the metrics
themselves must agree with the independently recorded history, the
JSONL schema round-trips (with future-version refusal), ``RunLog``
reconstructs the legacy hist dict key for key, and the report CLI
renders a phase table.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AveragingSchedule, Compression, PhaseEngine
from repro.elastic import ElasticPlan, run_elastic
from repro.faults import FaultPlan
from repro.optim import Momentum
from repro.telemetry import (JsonlSink, MemorySink, NullSink, RunLog,
                             TELEMETRY_VERSION, init_history, make_record,
                             parse_record, run_meta_record)
from repro.telemetry.report import render
from repro.telemetry.timing import time_run, timed
from repro.topology import Topology, comm_bytes

WORKERS, STEPS, DIM, SAMPLES = 4, 40, 12, 256


def _problem(seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((SAMPLES, DIM))
    y = X @ rng.standard_normal(DIM)
    idx = rng.integers(0, SAMPLES, (STEPS, WORKERS, 8))
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    return lambda: [{"x": Xj[idx[t]], "y": yj[idx[t]]}
                    for t in range(STEPS)]


def _loss(params, batch, rng):
    r = batch["x"] @ params["w"] - batch["y"]
    return 0.5 * jnp.mean(r * r), {}


def _params():
    return {"w": jnp.zeros(DIM)}


SCHEDULES = {
    "oneshot": AveragingSchedule("oneshot"),
    "minibatch": AveragingSchedule("minibatch"),
    "periodic": AveragingSchedule("periodic", 8),
    "stochastic": AveragingSchedule("stochastic", zeta=0.2),
    "hierarchical": AveragingSchedule("hierarchical", inner_phase_len=5,
                                      outer_phase_len=20, inner_groups=2),
    "adaptive_threshold": AveragingSchedule("adaptive_threshold",
                                            disp_threshold=0.05,
                                            disp_ema_beta=0.5),
    "adaptive_budget": AveragingSchedule("adaptive_budget", comm_budget=6,
                                         budget_horizon=STEPS),
}


def _pair(sch, **kw):
    """(telemetry-off, telemetry-on) engines, otherwise identical."""
    off = PhaseEngine(_loss, Momentum(lr=0.05, mu=0.9), sch, **kw)
    on = PhaseEngine(_loss, Momentum(lr=0.05, mu=0.9), sch,
                     telemetry=True, **kw)
    return off, on


def _assert_state_identical(s_off, s_on):
    la, lb = jax.tree.leaves(s_off), jax.tree.leaves(s_on)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _run_both(off, on, sink=None, batches=None, **kw):
    batches = batches or _problem()
    kw.setdefault("num_workers", WORKERS)
    kw.setdefault("seed", 3)
    kw.setdefault("record_every", 1)
    f0, h0, s0 = off.run(_params(), batches(), return_state=True, **kw)
    f1, h1, s1 = on.run(_params(), batches(), return_state=True,
                        sink=sink, **kw)
    _assert_state_identical(s0, s1)
    np.testing.assert_array_equal(np.asarray(f0["w"]), np.asarray(f1["w"]))
    assert h0 == h1
    return h1


# ------------------------------------------------------------- invariance

@pytest.mark.parametrize("name", list(SCHEDULES))
def test_invariant_across_schedules(name):
    off, on = _pair(SCHEDULES[name])
    _run_both(off, on, sink=MemorySink())


@pytest.mark.parametrize("path,kw", [
    ("flat", {"fused_opt": False}),
    ("tree", {"flat": False}),
    ("host", {}),
], ids=["flat", "tree", "host"])
def test_invariant_across_paths(path, kw):
    if path == "host":
        # run_host never carries the accumulator; its engine flag must
        # still be inert
        off, on = _pair(SCHEDULES["periodic"])
        f0, h0 = off.run_host(_params(), _problem()(),
                              num_workers=WORKERS, seed=3, record_every=1)
        f1, h1 = on.run_host(_params(), _problem()(),
                             num_workers=WORKERS, seed=3, record_every=1)
        np.testing.assert_array_equal(np.asarray(f0["w"]),
                                      np.asarray(f1["w"]))
        assert h0 == h1
    else:
        off, on = _pair(SCHEDULES["periodic"], **kw)
        _run_both(off, on, sink=MemorySink())


def test_invariant_with_compression_and_topology():
    off, on = _pair(SCHEDULES["periodic"],
                    compression=Compression("int8"),
                    topology=Topology.build("ring", WORKERS))
    _run_both(off, on, sink=MemorySink())


def test_invariant_with_faults():
    plan = FaultPlan.parse("crash:m=2@t=10,rejoin:m=2@t=25", WORKERS,
                           straggle_prob=0.25)
    off, on = _pair(SCHEDULES["periodic"], faults=plan)
    sink = MemorySink()
    _run_both(off, on, sink=sink)
    fe = [(r["kind"], r["worker"], r["step"]) for r in sink.records
          if r["type"] == "fault_event"]
    assert fe == [("crash", 2, 10), ("rejoin", 2, 25)]
    pm = [r for r in sink.records if r["type"] == "phase_metrics"]
    # the crash window (steps 11..25) has 3 alive workers
    assert min(r["alive_min"] for r in pm) == 3.0
    assert any(r["straggle_rate"] > 0 for r in pm)


def test_invariant_across_resume():
    """Telemetry never touches the checkpoint: a resumed telemetry run
    matches the uninterrupted telemetry-off run bit-for-bit, and the
    resumed phases flush fresh accumulators."""
    from repro.checkpoint import load_engine_state, save_engine_state
    import tempfile
    batches = _problem()
    off, on = _pair(SCHEDULES["stochastic"])
    f_full, h_full, s_full = off.run(
        _params(), batches(), num_workers=WORKERS, seed=7,
        record_every=8, return_state=True)
    cut = 24
    sink = MemorySink()
    _, h1, st = on.run(_params(), batches()[:cut], num_workers=WORKERS,
                       seed=7, record_every=8, return_state=True,
                       sink=sink)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck")
        save_engine_state(path, st)
        loaded, at = load_engine_state(path, on.init(_params(), WORKERS, 7))
    assert at == cut
    f_res, h2, s_res = on.run(None, batches()[cut:], num_workers=WORKERS,
                              record_every=8, state=loaded,
                              return_state=True, sink=sink)
    _assert_state_identical(s_full, s_res)
    np.testing.assert_array_equal(np.asarray(f_full["w"]),
                                  np.asarray(f_res["w"]))
    assert h_full["loss"] == h1["loss"] + h2["loss"]
    pm = [r for r in sink.records if r["type"] == "phase_metrics"]
    assert sum(r["steps"] for r in pm) == STEPS
    # phase windows are contiguous across the resume cut
    spans = [(r["t0"], r["t1"]) for r in pm]
    assert spans[0][0] == 1 and spans[-1][1] == STEPS
    assert all(a2 == b1 + 1 for (_, b1), (a2, _) in zip(spans, spans[1:]))


def test_no_extra_host_syncs(monkeypatch):
    """One device_get per phase, telemetry on or off — the metrics ride
    the existing trace fetch instead of adding their own."""
    counts = []
    real = jax.device_get

    def counting(x):
        counts.append(1)
        return real(x)

    off, on = _pair(SCHEDULES["periodic"])
    monkeypatch.setattr(jax, "device_get", counting)
    off.run(_params(), _problem()(), num_workers=WORKERS, seed=3,
            phase_len=10)
    n_off = len(counts)
    counts.clear()
    on.run(_params(), _problem()(), num_workers=WORKERS, seed=3,
           phase_len=10, sink=MemorySink())
    n_on = len(counts)
    assert n_on == n_off == STEPS // 10


# ------------------------------------------------- metrics vs history

def test_metrics_match_history():
    off, on = _pair(SCHEDULES["periodic"])
    sink = MemorySink()
    hist = _run_both(off, on, sink=sink, phase_len=10)
    pm = [r for r in sink.records if r["type"] == "phase_metrics"]
    assert [r["steps"] for r in pm] == [10] * 4
    assert sum(r["events"] for r in pm) == hist["averages"]
    losses = [v for _, v in hist["loss"]]
    disps = [v for _, v in hist["disp_trace"]]
    for i, r in enumerate(pm):
        seg_l, seg_d = losses[i * 10:(i + 1) * 10], disps[i * 10:(i + 1) * 10]
        np.testing.assert_allclose(r["loss_mean"], np.mean(seg_l),
                                   rtol=1e-5)
        np.testing.assert_allclose(r["loss_max"], np.max(seg_l), rtol=1e-6)
        np.testing.assert_allclose(r["disp_max"], np.max(seg_d), rtol=1e-5)
    # nominal wire bytes = events x topology.comm_bytes pricing
    per_event = comm_bytes(Topology.full(WORKERS), 1, DIM, "f32")
    assert sum(r["comm_bytes"] for r in pm) == hist["averages"] * per_event


def test_metrics_price_compressed_wire():
    off, on = _pair(SCHEDULES["periodic"], compression=Compression("int8"))
    sink = MemorySink()
    hist = _run_both(off, on, sink=sink)
    per_event = comm_bytes(Topology.full(WORKERS), 1, DIM, "int8")
    total = sum(r["comm_bytes"] for r in sink.records
                if r["type"] == "phase_metrics")
    assert total == hist["averages"] * per_event


# ---------------------------------------------------- schema + RunLog

def test_record_schema_round_trip(tmp_path):
    records = [
        run_meta_record(config={"workers": 4}),
        make_record("phase_metrics", t0=1, t1=10, steps=10, events=1),
        make_record("averaging_event", step=8, dispersion=0.1, scope="all"),
        make_record("fault_event", step=3, kind="crash", worker=1),
        make_record("resize_event", step=5, old_m=4, new_m=6),
        make_record("checkpoint_event", step=10, path="ck.state",
                    layout_version=5),
    ]
    path = tmp_path / "run.jsonl"
    with JsonlSink(path) as sink:
        for r in records:
            sink.emit(r)
    log = RunLog.load(path)
    assert [r["type"] for r in log.records] == [r["type"] for r in records]
    for orig, back in zip(records, log.records):
        assert orig == back
    assert all(r["v"] == TELEMETRY_VERSION for r in log.records)


def test_reader_refuses_future_version_and_unknown_type():
    with pytest.raises(ValueError, match="newer than this reader"):
        parse_record({"v": TELEMETRY_VERSION + 1, "type": "run_meta"})
    with pytest.raises(ValueError, match="unknown telemetry record type"):
        parse_record({"v": TELEMETRY_VERSION, "type": "mystery"})
    with pytest.raises(ValueError, match="no integer 'v'"):
        parse_record({"type": "run_meta"})
    with pytest.raises(ValueError, match="unknown telemetry record type"):
        make_record("mystery")
    # MemorySink validates on emit
    with pytest.raises(ValueError):
        MemorySink().emit({"type": "run_meta"})
    NullSink().emit({"anything": "goes-nowhere"})


def test_runlog_history_matches_engine_hist(tmp_path):
    off, on = _pair(SCHEDULES["stochastic"])
    path = tmp_path / "run.jsonl"
    with JsonlSink(path) as sink:
        hist = _run_both(off, on, sink=sink)
    rebuilt = RunLog.load(path).history()
    assert rebuilt["loss"] == hist["loss"]
    assert rebuilt["disp_trace"] == hist["disp_trace"]
    assert rebuilt["dispersion"] == hist["dispersion"]
    assert rebuilt["averages"] == hist["averages"]
    assert rebuilt["eval"] == [] and rebuilt["worker_eval"] == []


def test_init_history_is_the_shared_constructor():
    hist = init_history()
    assert hist == {"loss": [], "dispersion": [], "disp_trace": [],
                    "averages": 0, "eval": [], "worker_eval": []}
    assert init_history(resizes=True)["resizes"] == []
    # fresh lists every call — a shared-mutable constructor would let
    # one run's history leak into the next
    a, b = init_history(), init_history()
    a["loss"].append((1, 0.0))
    assert b["loss"] == []


# ------------------------------------------------------------- elastic

def test_elastic_emits_resize_events():
    rng = np.random.default_rng(0)
    X = rng.standard_normal((SAMPLES, DIM))
    y = X @ rng.standard_normal(DIM)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)

    def factory(m, t0, k):
        g = np.random.default_rng(1000 + t0)
        idx = g.integers(0, SAMPLES, (k, m, 8))
        return [{"x": Xj[idx[t]], "y": yj[idx[t]]} for t in range(k)]

    plan = ElasticPlan.parse(WORKERS, grow_at=("21:6",))
    off, on = _pair(AveragingSchedule("periodic", 5))
    f0, h0 = run_elastic(off, _params(), factory, plan, steps=STEPS,
                         seed=3, record_every=1)
    sink = MemorySink()
    f1, h1 = run_elastic(on, _params(), factory, plan, steps=STEPS,
                         seed=3, record_every=1, sink=sink)
    np.testing.assert_array_equal(np.asarray(f0["w"]), np.asarray(f1["w"]))
    assert h0 == h1
    rz = [r for r in sink.records if r["type"] == "resize_event"]
    assert [(r["step"], r["old_m"], r["new_m"]) for r in rz] == [(21, 4, 6)]
    assert RunLog(sink.records).history()["resizes"] == h1["resizes"]
    # phase_metrics keep flowing across the resize
    assert sum(r["steps"] for r in sink.records
               if r["type"] == "phase_metrics") == STEPS


def test_sink_requires_telemetry_engine():
    off, _ = _pair(SCHEDULES["periodic"])
    with pytest.raises(ValueError, match="telemetry=True"):
        off.run(_params(), _problem()(), num_workers=WORKERS,
                sink=MemorySink())


# ------------------------------------------------------------- sharded

_SHARDED_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import AveragingSchedule, PhaseEngine
from repro.optim import Momentum
from repro.telemetry import MemorySink

assert len(jax.devices()) == 8, jax.devices()
DIM, SAMPLES, WORKERS, STEPS = 12, 256, 16, 41
rng = np.random.default_rng(0)
X = rng.standard_normal((SAMPLES, DIM))
y = X @ rng.standard_normal(DIM)
Xj, yj = jnp.asarray(X), jnp.asarray(y)
idx = rng.integers(0, SAMPLES, (STEPS, WORKERS, 8))

def loss_fn(params, batch, rng):
    r = batch["x"] @ params["w"] - batch["y"]
    return 0.5 * jnp.mean(r * r), {}

params = {"w": jnp.zeros(DIM)}
batches = lambda: [{"x": Xj[idx[t]], "y": yj[idx[t]]} for t in range(STEPS)]
mesh = jax.make_mesh((8,), ("data",))
sch = AveragingSchedule("periodic", 8)
kw = dict(num_workers=WORKERS, seed=3, record_every=1, phase_len=16)
for coll in ("psum", "gather"):
    off = PhaseEngine(loss_fn, Momentum(lr=0.05, mu=0.9), sch,
                      mesh=mesh, collective=coll)
    on = PhaseEngine(loss_fn, Momentum(lr=0.05, mu=0.9), sch,
                     mesh=mesh, collective=coll, telemetry=True)
    f0, h0, s0 = off.run(params, batches(), return_state=True, **kw)
    sink = MemorySink()
    f1, h1, s1 = on.run(params, batches(), return_state=True,
                        sink=sink, **kw)
    for a, b in zip(jax.tree.leaves(s0), jax.tree.leaves(s1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert h0 == h1
    pm = [r for r in sink.records if r["type"] == "phase_metrics"]
    assert sum(r["steps"] for r in pm) == STEPS
    assert sum(r["events"] for r in pm) == h1["averages"]
    assert all(r["alive_mean"] == WORKERS for r in pm)
    print("ok", coll)
print("ALL-OK")
"""


def test_sharded_telemetry_invariant():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL-OK" in out.stdout


# -------------------------------------------------------------- timing

def test_timed_and_time_run():
    calls = []

    def fn():
        calls.append(1)

    assert timed(fn) >= 0.0
    calls.clear()
    ms = time_run(fn, steps=10, reps=3, warmup=2)
    assert ms >= 0.0
    assert len(calls) == 5  # 2 warmup + 3 timed
    with pytest.raises(ValueError):
        time_run(fn, steps=0)
    with pytest.raises(ValueError):
        time_run(fn, steps=1, reps=0)


def test_time_run_blocks_device_output():
    x = jnp.arange(8.0)
    f = jax.jit(lambda v: v * 2)
    assert time_run(lambda: f(x), steps=1, block=True) >= 0.0


def test_profile_trace_noop_without_dir():
    from repro.telemetry.timing import profile_trace
    with profile_trace(None):
        pass
    with profile_trace(""):
        pass


# -------------------------------------------------------------- report

def test_report_renders_phase_table(tmp_path):
    _, on = _pair(SCHEDULES["periodic"])
    path = tmp_path / "run.jsonl"
    with JsonlSink(path) as sink:
        sink.emit(run_meta_record(config={
            "workers": WORKERS, "lr": 0.05, "momentum": 0.9,
            "avg": "periodic", "phase_len": 8}))
        on.run(_params(), _problem()(), num_workers=WORKERS, seed=3,
               record_every=1, phase_len=10, sink=sink)
    text = render(RunLog.load(path))
    assert "disp_mean" in text and "B/event" in text
    assert f"total: {STEPS} steps" in text
    # the variance-model prediction column calibrates from the recipe
    assert "disp_pred" in text
    lines = [ln for ln in text.splitlines() if ln.strip().startswith("0 ")]
    assert lines, text


def test_report_cli(tmp_path, capsys):
    from repro.telemetry.report import main
    path = tmp_path / "run.jsonl"
    with JsonlSink(path) as sink:
        sink.emit(make_record("phase_metrics", t0=1, t1=10, steps=10,
                              events=2, comm_bytes=96.0, loss_mean=1.0,
                              disp_mean=0.1, disp_max=0.2,
                              alive_mean=4.0, straggle_rate=0.0,
                              wall_s=0.5))
    assert main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "total: 10 steps, 2 events" in out

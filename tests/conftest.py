import dataclasses

import jax
import pytest

from repro.configs import ARCHS, get_config

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True, scope="module")
def _release_compiled_executables():
    """Drop jit caches after every test module. The suite compiles
    hundreds of distinct engine programs in one process; on XLA:CPU the
    accumulated live executables eventually crash the compiler itself
    (segfault inside backend_compile, ~400 tests in) — modules don't
    share compiled programs, so freeing between them costs nothing."""
    yield
    jax.clear_caches()


def reduced_f32(arch: str, **kw):
    """Reduced config in float32 (CPU numerics) for smoke tests."""
    cfg = get_config(arch, reduced=True)
    return dataclasses.replace(cfg, dtype="float32", **kw)


@pytest.fixture(params=ARCHS)
def arch(request):
    return request.param

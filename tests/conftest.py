import dataclasses

import jax
import pytest

from repro.configs import ARCHS, get_config

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True, scope="module")
def _release_compiled_executables():
    """jit-cache hygiene convention (docs/INVARIANTS.md §6).

    Every test module ends with ``jax.clear_caches()``: the suite
    compiles hundreds of distinct engine programs in one process, and on
    XLA:CPU the accumulated live executables eventually crash the
    compiler itself (segfault inside backend_compile, ~400 tests in).
    Modules don't share compiled programs, so the leak budget carried
    across module boundaries is 0 live executables — this autouse
    module-scoped fixture is the single owner of cache lifetime. The
    ``jit-cache-hygiene`` rule of ``repro.analysis`` enforces the shape:
    this fixture must exist here, and test modules must not call
    ``jax.clear_caches()`` ad hoc or launch jit work at import time."""
    yield
    jax.clear_caches()


def reduced_f32(arch: str, **kw):
    """Reduced config in float32 (CPU numerics) for smoke tests."""
    cfg = get_config(arch, reduced=True)
    return dataclasses.replace(cfg, dtype="float32", **kw)


@pytest.fixture(params=ARCHS)
def arch(request):
    return request.param

import dataclasses

import jax
import pytest

from repro.configs import ARCHS, get_config

jax.config.update("jax_enable_x64", False)


def reduced_f32(arch: str, **kw):
    """Reduced config in float32 (CPU numerics) for smoke tests."""
    cfg = get_config(arch, reduced=True)
    return dataclasses.replace(cfg, dtype="float32", **kw)


@pytest.fixture(params=ARCHS)
def arch(request):
    return request.param

"""Sharded (M, P) plane: shard_map phase over the mesh worker axes.

The heavyweight validation runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (jax fixes its
device count at import, so the parent process can't flip it):

  - gather collective: bit-identical params AND history vs the
    single-device engine for the paper's Momentum recipe, across all
    5 static + 2 adaptive (dispersion-driven, stateful) averaging
    schedules (+ the outer optimizer, the indexed on-device data
    plane, and the sparse mixing topologies — ring / torus / random
    gossip pairs — whose W-mix events all_gather the row shards);
  - psum collective: identical decision streams / averaging counts —
    including the adaptive kinds, whose decisions consume the psum'd
    per-step dispersion — params and traces equal to f32 roundoff.

In-process tests cover the sharding spec helpers.
"""
import os
import subprocess
import sys

import jax
import numpy as np

from jax.sharding import PartitionSpec as P

from repro.sharding.specs import (engine_state_sharding, mesh_worker_axes,
                                  plane_sharding)

_SCRIPT = r"""
import jax
import jax.numpy as jnp
import numpy as np
from repro.core import AveragingSchedule, PhaseEngine, OuterOptimizer
from repro.data.pipeline import DeviceDataset
from repro.optim import Momentum

assert len(jax.devices()) == 8, jax.devices()
DIM, SAMPLES, WORKERS, STEPS = 12, 256, 16, 41
rng = np.random.default_rng(0)
X = rng.standard_normal((SAMPLES, DIM))
y = X @ rng.standard_normal(DIM)
Xj, yj = jnp.asarray(X), jnp.asarray(y)
idx = rng.integers(0, SAMPLES, (STEPS, WORKERS, 8))

def loss_fn(params, batch, rng):
    r = batch["x"] @ params["w"] - batch["y"]
    return 0.5 * jnp.mean(r * r), {}

params = {"w": jnp.zeros(DIM)}
batches = lambda: [{"x": Xj[idx[t]], "y": yj[idx[t]]} for t in range(STEPS)]
mesh = jax.make_mesh((8,), ("data",))
kw = dict(num_workers=WORKERS, seed=3, record_every=1)
opt = lambda: Momentum(lr=0.05, mu=0.9)

scheds = {
    "oneshot": AveragingSchedule("oneshot"),
    "minibatch": AveragingSchedule("minibatch"),
    "periodic": AveragingSchedule("periodic", 8),
    "stochastic": AveragingSchedule("stochastic", zeta=0.2),
    "hierarchical": AveragingSchedule("hierarchical", inner_phase_len=5,
                                      outer_phase_len=20, inner_groups=2),
    # stateful kinds: decisions ride SchedState on the per-step
    # dispersion, which the psum collective reduces with one extra psum
    "adaptive_threshold": AveragingSchedule("adaptive_threshold",
                                            disp_threshold=0.5,
                                            disp_ema_beta=0.5),
    "adaptive_budget": AveragingSchedule("adaptive_budget", comm_budget=6,
                                         budget_horizon=STEPS),
}
for name, sch in scheds.items():
    f0, h0 = PhaseEngine(loss_fn, opt(), sch).run(params, batches(), **kw)
    # gather collective: bit-identical
    f1, h1 = PhaseEngine(loss_fn, opt(), sch, mesh=mesh,
                         collective="gather").run(params, batches(), **kw)
    np.testing.assert_array_equal(np.asarray(f0["w"]), np.asarray(f1["w"]))
    assert h0 == h1, name
    # psum collective: same decisions, f32-roundoff params/traces
    f2, h2 = PhaseEngine(loss_fn, opt(), sch, mesh=mesh,
                         collective="psum").run(params, batches(), **kw)
    np.testing.assert_allclose(np.asarray(f0["w"]), np.asarray(f2["w"]),
                               rtol=1e-5, atol=1e-7)
    assert h0["averages"] == h2["averages"], name
    assert [t for t, _ in h0["dispersion"]] == \
        [t for t, _ in h2["dispersion"]], name
    np.testing.assert_allclose([v for _, v in h0["loss"]],
                               [v for _, v in h2["loss"]],
                               rtol=1e-5, atol=1e-7)
    print("ok", name)

# outer optimizer, sharded
sch = AveragingSchedule("periodic", 8)
mk = lambda **e: PhaseEngine(loss_fn, opt(), sch,
                             outer=OuterOptimizer(lr=0.8, momentum=0.5), **e)
f0, h0 = mk().run(params, batches(), **kw)
f1, h1 = mk(mesh=mesh, collective="gather").run(params, batches(), **kw)
np.testing.assert_array_equal(np.asarray(f0["w"]), np.asarray(f1["w"]))
assert h0 == h1
print("ok outer")

# indexed on-device data plane, sharded
f0, h0 = PhaseEngine(loss_fn, opt(), sch).run(
    params, DeviceDataset({"x": Xj, "y": yj}, WORKERS, indices=idx), **kw)
f1, h1 = PhaseEngine(loss_fn, opt(), sch, mesh=mesh,
                     collective="gather").run(
    params, DeviceDataset({"x": Xj, "y": yj}, WORKERS, indices=idx), **kw)
np.testing.assert_array_equal(np.asarray(f0["w"]), np.asarray(f1["w"]))
assert h0 == h1
print("ok indexed")

# gossip-topology mixing events (repro.topology): gather bit-identical,
# psum same decisions / f32-roundoff params — incl. the per-event
# random gossip matching, replayed identically on every shard from the
# replicated (dec_key, step)
from repro.topology import Topology
for kind in ("ring", "torus", "gossip_pairs"):
    topo = Topology.build(kind, WORKERS)
    f0, h0 = PhaseEngine(loss_fn, opt(), sch, topology=topo).run(
        params, batches(), **kw)
    f1, h1 = PhaseEngine(loss_fn, opt(), sch, topology=topo, mesh=mesh,
                         collective="gather").run(params, batches(), **kw)
    np.testing.assert_array_equal(np.asarray(f0["w"]), np.asarray(f1["w"]))
    assert h0 == h1, kind
    f2, h2 = PhaseEngine(loss_fn, opt(), sch, topology=topo, mesh=mesh,
                         collective="psum").run(params, batches(), **kw)
    assert h0["averages"] == h2["averages"], kind
    assert [t for t, _ in h0["dispersion"]] == \
        [t for t, _ in h2["dispersion"]], kind
    np.testing.assert_allclose(np.asarray(f0["w"]), np.asarray(f2["w"]),
                               rtol=1e-5, atol=1e-7)
    print("ok topology", kind)

# compressed communication planes: the gather collective all_gathers the
# error-feedback residual rows too and must stay bit-identical to the
# single-device run; psum encodes shard-locally (per-row scales and
# fold_in uniforms keyed by GLOBAL row ids) and reduces the encoded
# sums — same decision stream, f32-roundoff params
from repro.core import Compression
for wire in ("bf16", "int8", "one_bit"):
    for sname in ("periodic", "stochastic", "adaptive_budget"):
        sch_c, comp = scheds[sname], Compression(wire)
        f0, h0 = PhaseEngine(loss_fn, opt(), sch_c, compression=comp).run(
            params, batches(), **kw)
        f1, h1 = PhaseEngine(loss_fn, opt(), sch_c, compression=comp,
                             mesh=mesh, collective="gather").run(
            params, batches(), **kw)
        np.testing.assert_array_equal(np.asarray(f0["w"]),
                                      np.asarray(f1["w"]))
        assert h0 == h1, (wire, sname)
        f2, h2 = PhaseEngine(loss_fn, opt(), sch_c, compression=comp,
                             mesh=mesh, collective="psum").run(
            params, batches(), **kw)
        assert h0["averages"] == h2["averages"], (wire, sname)
        assert [t for t, _ in h0["dispersion"]] == \
            [t for t, _ in h2["dispersion"]], (wire, sname)
        np.testing.assert_allclose(np.asarray(f0["w"]),
                                   np.asarray(f2["w"]),
                                   rtol=1e-5, atol=1e-7)
    print("ok compressed", wire)

# compressed W-mix events under both collectives
topo = Topology.build("ring", WORKERS)
comp = Compression("int8")
f0, h0 = PhaseEngine(loss_fn, opt(), sch, topology=topo,
                     compression=comp).run(params, batches(), **kw)
f1, h1 = PhaseEngine(loss_fn, opt(), sch, topology=topo, compression=comp,
                     mesh=mesh, collective="gather").run(
    params, batches(), **kw)
np.testing.assert_array_equal(np.asarray(f0["w"]), np.asarray(f1["w"]))
assert h0 == h1
f2, h2 = PhaseEngine(loss_fn, opt(), sch, topology=topo, compression=comp,
                     mesh=mesh, collective="psum").run(
    params, batches(), **kw)
assert h0["averages"] == h2["averages"]
np.testing.assert_allclose(np.asarray(f0["w"]), np.asarray(f2["w"]),
                           rtol=1e-5, atol=1e-7)
print("ok compressed ring mix")
print("ALL-OK")
"""


def test_sharded_engine_matches_single_device():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL-OK" in out.stdout


def test_mesh_worker_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert mesh_worker_axes(mesh) == ("data",)
    mesh3 = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    assert mesh_worker_axes(mesh3) == ("pod", "data")


def test_plane_sharding_spec():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    s = plane_sharding(mesh)
    assert s.spec == P(("data",))
    s2 = plane_sharding(mesh, axes=("model",))
    assert s2.spec == P(("model",))


def test_engine_state_sharding_tree():
    from repro.core import EngineState
    mesh = jax.make_mesh((1,), ("data",))
    from repro.core import AveragingSchedule
    state = EngineState(
        worker_params={"w": np.zeros((4, 3))},
        opt_state={"v": np.zeros((4, 3))},
        outer_state=(),
        key=np.zeros(2, np.uint32), dec_key=np.zeros(2, np.uint32),
        step=np.int32(0),
        sched=AveragingSchedule("periodic", 8).init_sched_state(),
        resid=np.zeros((4, 3), np.float32))
    sh = engine_state_sharding(mesh, state)
    assert sh.worker_params["w"].spec == P(("data",))
    assert sh.opt_state["v"].spec == P(("data",))
    assert sh.key.spec == P()
    assert sh.step.spec == P()
    assert all(s.spec == P() for s in sh.sched)
    # the error-feedback residual plane shards with the worker rows
    assert sh.resid.spec == P(("data",))

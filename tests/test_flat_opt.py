"""Flat-native optimizer planes: FlatOptSpec + fused opt_step kernel.

Three layers of guarantees:
  1. The plane-resident optimizer update (``plane_update_ref`` /
     ``opt_step``) is BIT-EXACT against the pytree ``optimizer.apply``
     for SGD / Momentum(+nesterov) / AdamW across f32/bf16/f16 params
     and all lr schedules (constant, inverse, exponential_epoch) — the
     plane always holds the exact float32 image of the tree.
  2. The Pallas opt_step kernel (interpret mode on CPU) matches the
     kernels/ref.py jnp twin across kinds, modes, padding and rounding
     codes.
  3. The flat-native engine (fused_opt=True, the default) reproduces
     the PR 2 flat path and the tree path for Momentum/AdamW across
     averaging schedules, incl. mixed-dtype trees and the outer
     optimizer.

Plus the satellite regressions: lr schedules produce strong float32 for
Python-int steps, and in-memory list sources skip the Prefetcher.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AveragingSchedule, FlatOptSpec, FlatSpec,
                        OuterOptimizer, PhaseEngine)
from repro.core import engine as engine_mod
from repro.kernels.opt_step import opt_step
from repro.kernels.ref import opt_step_ref, plane_update_ref
from repro.optim import SGD, AdamW, Momentum, schedules

KEY = jax.random.PRNGKey(0)
WORKERS, STEPS, DIM, SAMPLES = 4, 49, 12, 256

OPTIMIZERS = {
    "sgd": lambda lr: SGD(lr=lr),
    "momentum": lambda lr: Momentum(lr=lr, mu=0.9),
    "nesterov": lambda lr: Momentum(lr=lr, mu=0.9, nesterov=True),
    "adamw": lambda lr: AdamW(lr=lr, weight_decay=0.01),
}
LRS = {
    "const": 0.05,
    "inverse": schedules.inverse(1.0, 10.0),
    "exp_epoch": schedules.exponential_epoch(0.1, 0.9, 5),
}


def _worker_tree(dt, m=WORKERS):
    ks = jax.random.split(KEY, 2)
    return {"a": jax.random.normal(ks[0], (m, 3, 5)).astype(dt),
            "b": (jax.random.normal(ks[1], (m, 7)).astype(dt),)}


# --------------------------------------------------------------------------
# 1. FlatOptSpec layout
# --------------------------------------------------------------------------

class TestFlatOptSpec:
    def test_state_plane_counts(self):
        tree = _worker_tree(jnp.float32)
        spec = FlatSpec.of(tree)
        for name, mk in OPTIMIZERS.items():
            opt = mk(0.1)
            ospec = FlatOptSpec.of(spec, jax.vmap(opt.init)(tree))
            assert ospec is not None
            assert ospec.num_planes == opt.state_planes, name

    def test_pack_unpack_roundtrip(self):
        tree = _worker_tree(jnp.float32)
        spec = FlatSpec.of(tree)
        opt = AdamW(lr=0.1)
        state = jax.vmap(opt.init)(tree)
        ospec = FlatOptSpec.of(spec, state)
        planes = ospec.pack(state)
        assert len(planes) == 2
        assert all(p.shape == (WORKERS, spec.width) for p in planes)
        back = ospec.unpack(planes)
        assert jax.tree.structure(back) == jax.tree.structure(state)
        for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_misaligned_state_rejected(self):
        tree = _worker_tree(jnp.float32)
        spec = FlatSpec.of(tree)
        # wrong shape
        assert FlatOptSpec.of(
            spec, {"v": jnp.zeros((WORKERS, 9))}) is None
        # wrong dtype
        bad = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.int32), tree)
        assert FlatOptSpec.of(spec, bad) is None
        # SGD's empty state is fine (0 planes)
        ospec = FlatOptSpec.of(spec, ())
        assert ospec is not None and ospec.num_planes == 0
        assert ospec.pack(()) == ()

    def test_rounding_codes(self):
        f32 = FlatSpec.of(_worker_tree(jnp.float32))
        assert f32.rounding_codes() is None
        mixed = FlatSpec.of({
            "a": jnp.zeros((2, 3)),
            "b": jnp.zeros((2, 4), jnp.bfloat16),
            "c": jnp.zeros((2, 2), jnp.float16)})
        codes = mixed.rounding_codes()
        np.testing.assert_array_equal(codes, [0, 0, 0, 1, 1, 1, 1, 2, 2])


# --------------------------------------------------------------------------
# 2. plane update == pytree optimizer.apply, bit-exact
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("lr_name", list(LRS))
@pytest.mark.parametrize("opt_name", list(OPTIMIZERS))
def test_plane_update_bit_exact(dt, lr_name, opt_name):
    opt = OPTIMIZERS[opt_name](LRS[lr_name])
    tree = _worker_tree(dt)
    spec = FlatSpec.of(tree)
    state = jax.vmap(opt.init)(tree)
    ospec = FlatOptSpec.of(spec, state)
    grads = jax.tree.map(
        lambda x: (jax.random.normal(jax.random.fold_in(KEY, 1),
                                     x.shape) * 0.1).astype(x.dtype), tree)
    plane, planes = spec.pack(tree), ospec.pack(state)
    for step in (1, 2, 3):  # multi-step: moments accumulate
        step_j = jnp.asarray(step, jnp.int32)
        tree, state = opt.apply(tree, grads, state, step_j)
        plane, planes = plane_update_ref(
            plane, spec.pack(grads), planes, opt.plane_scalars(step_j),
            kind=opt.plane_kind, codes=spec.rounding_codes(),
            **opt.plane_hypers())
    for a, b in zip(jax.tree.leaves(spec.unpack(plane)),
                    jax.tree.leaves(tree)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    for a, b in zip(jax.tree.leaves(ospec.unpack(planes)),
                    jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# 3. opt_step Pallas kernel == jnp ref twin
# --------------------------------------------------------------------------

KERNEL_CASES = [
    ("sgd", 0, {}),
    ("momentum", 1, dict(mu=0.9, nesterov=True)),
    ("momentum", 1, dict(mu=0.9, nesterov=False)),
    ("adamw", 2, dict(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.01)),
]


@pytest.mark.parametrize("m,p,bp,groups", [
    (4, 300, 128, 1),    # padding path
    (8, 1024, 256, 2),
    (16, 33, 1024, 4),   # single partial block
])
@pytest.mark.parametrize("kind,nstate,hyp", KERNEL_CASES,
                         ids=[f"{k}{i}" for i, (k, _, _)
                              in enumerate(KERNEL_CASES)])
def test_opt_step_kernel_matches_ref(kind, nstate, hyp, m, p, bp, groups):
    ks = jax.random.split(jax.random.PRNGKey(p), 3 + nstate)
    x = jax.random.normal(ks[0], (m, p))
    g = jax.random.normal(ks[1], (m, p)) * 0.1
    # second moments must stay >= 0 for adamw
    planes = tuple(jnp.abs(jax.random.normal(ks[3 + i], (m, p))) * 0.01
                   for i in range(nstate))
    scal = jnp.asarray([0.05, 1 - 0.9 ** 3, 1 - 0.95 ** 3, 0.0],
                       jnp.float32)
    codes = np.zeros(p, np.float32)
    codes[p // 3:2 * p // 3] = 1
    codes[2 * p // 3:] = 2
    for mode in ("none", "mean", "group"):
        for cd in (None, codes):
            got = opt_step(x, g, planes, scal, kind=kind, mode=mode,
                           groups=groups, codes=cd, block_p=bp, **hyp)
            want = opt_step_ref(
                x, g, planes, scal, kind=kind, mode=mode, groups=groups,
                codes=None if cd is None else jnp.asarray(cd), **hyp)
            for a, b in zip([got[0], *got[1], got[2]],
                            [want[0], *want[1], want[2]]):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# 4. engine: flat-native == PR 2 flat == tree across schedules
# --------------------------------------------------------------------------

def _convex_problem(seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((SAMPLES, DIM))
    y = X @ rng.standard_normal(DIM) + 0.1 * rng.standard_normal(SAMPLES)
    return jnp.asarray(X), jnp.asarray(y)


def _loss_fn(params, batch, rng):
    r = batch["x"] @ params["w"] - batch["y"]
    return 0.5 * jnp.mean(r * r), {}


def _batches(X, y, seed=1, steps=STEPS):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, SAMPLES, (steps, WORKERS, 8))
    return [{"x": X[idx[t]], "y": y[idx[t]]} for t in range(steps)]


ENGINE_SCHEDULES = {
    "minibatch": AveragingSchedule("minibatch"),
    "periodic": AveragingSchedule("periodic", 8),
    "stochastic": AveragingSchedule("stochastic", zeta=0.2),
    "hierarchical": AveragingSchedule("hierarchical", inner_phase_len=5,
                                      outer_phase_len=20, inner_groups=2),
    "adaptive_threshold": AveragingSchedule("adaptive_threshold",
                                            disp_threshold=0.05,
                                            disp_ema_beta=0.5),
    "adaptive_budget": AveragingSchedule("adaptive_budget", comm_budget=6,
                                         budget_horizon=STEPS),
}


@pytest.mark.parametrize("sched", list(ENGINE_SCHEDULES))
@pytest.mark.parametrize("opt_name", ["nesterov", "adamw"])
def test_flat_native_engine_matches_flat_and_tree(opt_name, sched):
    X, y = _convex_problem()
    kw = dict(num_workers=WORKERS, seed=3, record_every=1)
    mk = lambda **e: PhaseEngine(
        _loss_fn, OPTIMIZERS[opt_name](schedules.inverse(2.0, 20.0)),
        ENGINE_SCHEDULES[sched], **e)
    f_nat, h_nat = mk().run({"w": jnp.zeros(DIM)}, _batches(X, y), **kw)
    f_pr2, h_pr2 = mk(fused_opt=False).run({"w": jnp.zeros(DIM)},
                                           _batches(X, y), **kw)
    f_tree, h_tree = mk(flat=False).run({"w": jnp.zeros(DIM)},
                                        _batches(X, y), **kw)
    # flat-native vs PR 2 flat: identical f32 plane math -> bit-exact
    np.testing.assert_array_equal(np.asarray(f_nat["w"]),
                                  np.asarray(f_pr2["w"]))
    np.testing.assert_allclose(np.asarray(f_nat["w"]),
                               np.asarray(f_tree["w"]),
                               rtol=1e-6, atol=1e-7)
    for h in (h_pr2, h_tree):
        assert h_nat["averages"] == h["averages"]
        assert [t for t, _ in h_nat["dispersion"]] == \
            [t for t, _ in h["dispersion"]]
        np.testing.assert_allclose([v for _, v in h_nat["loss"]],
                                   [v for _, v in h["loss"]],
                                   rtol=1e-6, atol=1e-7)


def test_flat_native_engine_bf16_matches_tree():
    """Mixed-dtype trees: the plane path rounds through the leaf dtypes
    after every update AND at averaging events, tracking the tree path
    to f32 roundoff (the update math itself is bit-exact — see
    test_plane_update_bit_exact — residual ulps come from XLA fusing
    the two vjp programs differently)."""
    X, y = _convex_problem()

    def loss(params, batch, rng):
        w = params["w"].astype(jnp.float32) + params["wb"].astype(jnp.float32)
        r = batch["x"].astype(jnp.float32) @ w - batch["y"]
        return 0.5 * jnp.mean(r * r), {}

    p0 = {"w": jnp.zeros(DIM), "wb": jnp.zeros(DIM, jnp.bfloat16)}
    kw = dict(num_workers=WORKERS, seed=3, record_every=1)
    mk = lambda **e: PhaseEngine(loss, Momentum(lr=0.05, mu=0.9),
                                 AveragingSchedule("periodic", 8), **e)
    f_nat, h_nat = mk().run(p0, _batches(X, y), **kw)
    f_tree, h_tree = mk(flat=False).run(p0, _batches(X, y), **kw)
    for k in p0:
        np.testing.assert_allclose(np.asarray(f_nat[k], np.float32),
                                   np.asarray(f_tree[k], np.float32),
                                   rtol=1e-5, atol=1e-6)
    assert h_nat["averages"] == h_tree["averages"]


def test_flat_native_engine_bf16_outer_matches_tree():
    """Mixed-dtype params + OuterOptimizer: the outer averaging event
    must round the consensus target and the updated average through the
    leaf dtypes like ``OuterOptimizer.apply`` does — without it the
    flat path drifts from the tree path a little more at every
    averaging event (review regression)."""
    X, y = _convex_problem()

    def loss(params, batch, rng):
        w = params["w"].astype(jnp.float32) + params["wb"].astype(jnp.float32)
        r = batch["x"].astype(jnp.float32) @ w - batch["y"]
        return 0.5 * jnp.mean(r * r), {}

    p0 = {"w": jnp.zeros(DIM), "wb": jnp.zeros(DIM, jnp.bfloat16)}
    kw = dict(num_workers=WORKERS, seed=3, record_every=1)
    mk = lambda **e: PhaseEngine(
        loss, Momentum(lr=0.05, mu=0.9), AveragingSchedule("periodic", 4),
        outer=OuterOptimizer(lr=0.9, momentum=0.5), **e)
    f_nat, h_nat = mk().run(p0, _batches(X, y), **kw)
    f_tree, h_tree = mk(flat=False).run(p0, _batches(X, y), **kw)
    for k in p0:
        np.testing.assert_allclose(np.asarray(f_nat[k], np.float32),
                                   np.asarray(f_tree[k], np.float32),
                                   rtol=1e-5, atol=1e-6)
    assert h_nat["averages"] == h_tree["averages"]


def test_flat_native_with_outer_matches_pr2():
    X, y = _convex_problem()
    kw = dict(num_workers=WORKERS, seed=5, record_every=1)
    mk = lambda **e: PhaseEngine(
        _loss_fn, Momentum(lr=0.05, mu=0.9),
        AveragingSchedule("periodic", 8),
        outer=OuterOptimizer(lr=0.8, momentum=0.5), **e)
    f_a, h_a = mk().run({"w": jnp.zeros(DIM)}, _batches(X, y), **kw)
    f_b, h_b = mk(fused_opt=False).run({"w": jnp.zeros(DIM)},
                                       _batches(X, y), **kw)
    np.testing.assert_array_equal(np.asarray(f_a["w"]),
                                  np.asarray(f_b["w"]))
    assert h_a == h_b


def test_unsupported_optimizer_falls_back():
    """An optimizer without the plane protocol still runs under
    flat=True (per-step pack/unpack path)."""
    class Plain:
        def init(self, params):
            return ()

        def apply(self, params, grads, state, step):
            return jax.tree.map(lambda p, g: p - 0.05 * g, params,
                                grads), state

    X, y = _convex_problem()
    eng = PhaseEngine(_loss_fn, Plain(), AveragingSchedule("periodic", 8))
    f, hist = eng.run({"w": jnp.zeros(DIM)}, _batches(X, y),
                      num_workers=WORKERS, seed=0)
    assert hist["averages"] == STEPS // 8
    assert np.isfinite(np.asarray(f["w"])).all()


# --------------------------------------------------------------------------
# Satellites: schedule dtypes, prefetch auto-select
# --------------------------------------------------------------------------

def test_schedules_cast_python_int_step_to_strong_f32():
    """Host-path calls (Python int step) must produce the same strong
    float32 value as the engine's traced int32 step — no weak types, no
    float64 promotion."""
    for fn in (schedules.constant(0.1), schedules.inverse(1.0, 10.0),
               schedules.exponential_epoch(0.1, 0.9, 5)):
        host = fn(7)
        assert host.dtype == jnp.float32 and not host.weak_type
        traced = fn(jnp.asarray(7, jnp.int32))
        assert traced.dtype == jnp.float32 and not traced.weak_type
        np.testing.assert_array_equal(np.asarray(host), np.asarray(traced))


def test_list_source_skips_prefetcher(monkeypatch):
    """run(prefetch=True) must not spawn a Prefetcher thread for a
    materialized list source — only true streams pay for staging."""
    X, y = _convex_problem()
    batches = _batches(X, y, steps=16)

    def boom(*a, **k):
        raise AssertionError("Prefetcher built for an in-memory list")

    monkeypatch.setattr(engine_mod, "Prefetcher", boom)
    eng = PhaseEngine(_loss_fn, SGD(lr=0.05), AveragingSchedule("periodic", 8))
    f, hist = eng.run({"w": jnp.zeros(DIM)}, batches, num_workers=WORKERS,
                      seed=0, prefetch=True)
    assert hist["averages"] == 2
    # a generator source still uses it
    used = {}
    monkeypatch.undo()

    class Spy(engine_mod.Prefetcher):
        def __init__(self, it, **kw):
            used["yes"] = True
            super().__init__(it, **kw)

    monkeypatch.setattr(engine_mod, "Prefetcher", Spy)
    f2, h2 = eng.run({"w": jnp.zeros(DIM)}, iter(batches),
                     num_workers=WORKERS, seed=0, prefetch=True)
    assert used.get("yes")
    np.testing.assert_array_equal(np.asarray(f["w"]), np.asarray(f2["w"]))
    assert hist == h2

"""Property-based (hypothesis) tests for the averaging operators.

``hypothesis`` is an optional dev dependency (requirements-dev.txt); when
it is absent this module skips itself and the deterministic fallbacks in
test_averaging.py cover the same invariants.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.averaging import (AveragingSchedule, average_all,
                                  average_inner, worker_dispersion)
from repro.core.local_sgd import consensus

shapes = st.sampled_from([(4, 3), (2, 5, 2), (8, 1)])


def tree_from(seed, m, shape):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (m,) + shape),
            "b": {"c": jax.random.normal(k2, (m, 7))}}


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), m=st.sampled_from([2, 4, 8]),
       shape=shapes)
def test_average_all_idempotent_and_mean_preserving(seed, m, shape):
    t = tree_from(seed, m, shape)
    avg = average_all(t)
    # all workers equal after averaging
    for leaf in jax.tree.leaves(avg):
        np.testing.assert_allclose(np.asarray(leaf),
                                   np.asarray(leaf[:1]).repeat(m, 0), rtol=1e-6)
    # idempotent
    for a, b in zip(jax.tree.leaves(average_all(avg)), jax.tree.leaves(avg)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    # preserves the mean (consensus invariance)
    for a, b in zip(jax.tree.leaves(consensus(avg)), jax.tree.leaves(consensus(t))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    # dispersion collapses to ~0
    assert float(worker_dispersion(avg)) < 1e-8


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), groups=st.sampled_from([2, 4]))
def test_hierarchical_inner_average(seed, groups):
    m = 8
    t = tree_from(seed, m, (3,))
    inner = average_inner(t, groups)
    x = np.asarray(jax.tree.leaves(t)[0])
    got = np.asarray(jax.tree.leaves(inner)[0])
    per = m // groups
    for g in range(groups):
        expect = x[g * per:(g + 1) * per].mean(0)
        for i in range(per):
            np.testing.assert_allclose(got[g * per + i], expect, rtol=1e-5)
    # full average of inner-averaged == full average of original
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(consensus(inner))[0]),
        np.asarray(jax.tree.leaves(consensus(t))[0]), rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(k=st.sampled_from([1, 3, 8]), steps=st.sampled_from([9, 16]))
def test_schedule_periodic_counts(k, steps):
    sch = AveragingSchedule(kind="periodic", phase_len=k)
    n = sum(sch.wants_average(s) == "all" for s in range(1, steps + 1))
    assert n == steps // k


@settings(max_examples=10, deadline=None)
@given(k=st.sampled_from([1, 2, 5]), steps=st.sampled_from([11, 20]),
       seed=st.integers(0, 100))
def test_decision_code_periodic_agrees_with_host(k, steps, seed):
    sch = AveragingSchedule(kind="periodic", phase_len=k)
    key = jax.random.PRNGKey(seed)
    for s in range(1, steps + 1):
        code = int(sch.decision_code(s, key))
        assert (code == 2) == (sch.wants_average(s) == "all")

"""Phase-engine benchmark: host-driven per-step dispatch vs the compiled
phase engine, on the reduced convex (least-squares) workload.

The host loop (PhaseEngine.run_host) is the seed runtime: one jit
dispatch per step, averaging decided on host, blocking float() reads.
The engine (PhaseEngine.run) compiles each averaging phase — K local
steps + the fused average — into one donated scan. Both paths run the
same periodic(K) schedule on identical data, so the ms/step ratio is
pure dispatch/fusion win.

Sweeps K in {1, 8, 64, 512} x workers in {4, 16}; emits JSON via
benchmarks/common.py (results/bench_engine.json).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save
from repro.core import AveragingSchedule, PhaseEngine
from repro.data import convex_dataset
from repro.optim import SGD

DIM, SAMPLES, STEPS = 64, 1024, 512
PHASE_LENS = (1, 8, 64, 512)
WORKER_COUNTS = (4, 16)


def make_engine(phase_len: int):
    def loss_fn(params, batch, rng):
        return 0.5 * jnp.square(batch["x"] @ params["w"] - batch["y"]), {}
    sch = AveragingSchedule("periodic", phase_len)
    return PhaseEngine(loss_fn, SGD(lr=0.01), sch)


def make_batches(X, y, workers: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, X.shape[0], size=(STEPS, workers))
    return [{"x": X[idx[t]], "y": y[idx[t]]} for t in range(STEPS)]


def time_run(fn, *, reps: int = 3) -> float:
    """ms/step, best of ``reps`` after a compile warmup run."""
    fn()  # warmup: compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best / STEPS * 1e3


def run():
    X, y, _ = convex_dataset("ls", SAMPLES, DIM, sparsity=0.2, noise=0.1,
                             seed=0)
    X, y = jnp.asarray(X), jnp.asarray(y)
    w0 = {"w": jnp.zeros(DIM)}
    results = []
    for workers in WORKER_COUNTS:
        batches = make_batches(X, y, workers)
        for k in PHASE_LENS:
            engine = make_engine(k)
            # small-K schedules still scan big blocks: averaging decisions
            # are per-step and on-device, so one compiled block may span
            # many averaging periods
            block = max(k, 64)
            host_ms = time_run(lambda: engine.run_host(
                w0, batches, num_workers=workers, seed=0))
            engine_ms = time_run(lambda: engine.run(
                w0, batches, num_workers=workers, seed=0,
                phase_len=block))
            row = {"workers": workers, "phase_len": k, "steps": STEPS,
                   "host_ms_per_step": host_ms,
                   "engine_ms_per_step": engine_ms,
                   "speedup": host_ms / engine_ms}
            results.append(row)
            emit(f"engine_K{k}_M{workers}", engine_ms * 1e3,
                 f"host_ms/step={host_ms:.3f};engine_ms/step={engine_ms:.3f};"
                 f"speedup={row['speedup']:.1f}x")
    save("bench_engine", {"workload": {"dim": DIM, "samples": SAMPLES,
                                       "steps": STEPS, "kind": "ls"},
                          "rows": results})
    worst = min(r["speedup"] for r in results if r["phase_len"] >= 64)
    print(f"min speedup at K>=64: {worst:.1f}x")
    return results


if __name__ == "__main__":
    run()
